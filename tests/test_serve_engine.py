"""Continuous-batching serve engine: correctness vs direct decode, slot
reuse, admission queue."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.serve import (AdmissionError, DeadlineExceededError, Engine,
                         QueueFullError, Request)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_matches_direct_decode(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=7).astype(np.int32)

    engine = Engine(cfg, params, batch_slots=2, max_len=32)
    req = Request(prompt=prompt, max_new=5)
    engine.submit(req)
    engine.run_until_done()

    # direct greedy decode
    import jax.numpy as jnp
    cache = model.init_cache(1, 32)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache, _ = model.apply(params, toks, caches=cache)
    out = []
    pos = len(prompt)
    # engine feeds the prompt's last token first, so replicate that
    cur_tok = int(prompt[-1])
    for _ in range(5):
        l, cache = model.decode_step(
            params, cache, jnp.asarray([[cur_tok]], jnp.int32), pos)
        cur_tok = int(jnp.argmax(l[0, -1]))
        out.append(cur_tok)
        pos += 1
    assert req.out == out


def test_engine_many_requests_slot_reuse(setup):
    cfg, _, params = setup
    rng = np.random.default_rng(1)
    engine = Engine(cfg, params, batch_slots=2, max_len=48)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=p).astype(np.int32),
                    max_new=4) for p in (5, 9, 3, 7, 11)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_done()
    assert all(r.done and len(r.out) == 4 for r in reqs)


def test_admission_rejects_impossible_requests(setup):
    """Regression: an over-long prompt used to be enqueued and prefill
    past the KV cache; impossible requests must be rejected with a typed
    error at submit() time, never enqueued."""
    cfg, _, params = setup
    rng = np.random.default_rng(2)
    engine = Engine(cfg, params, batch_slots=2, max_len=16)

    def prompt(p):
        return rng.integers(0, cfg.vocab, size=p).astype(np.int32)

    with pytest.raises(AdmissionError, match="max_new"):
        engine.submit(Request(prompt=prompt(4), max_new=0))
    with pytest.raises(AdmissionError, match="empty prompt"):
        engine.submit(Request(prompt=prompt(0), max_new=4))
    # max_len=16 leaves room for at most 15 prompt tokens + 1 decode step
    with pytest.raises(AdmissionError, match="max_len"):
        engine.submit(Request(prompt=prompt(16), max_new=4))
    assert engine._queue.empty()          # nothing impossible enqueued

    # the boundary case (P = max_len - 1) and a normal request still admit
    ok = [Request(prompt=prompt(15), max_new=1),
          Request(prompt=prompt(5), max_new=3)]
    for r in ok:
        engine.submit(r)
    engine.run_until_done()
    assert ok[0].done and len(ok[0].out) == 1
    assert ok[1].done and len(ok[1].out) == 3


def test_bounded_queue_rejects_with_typed_error(setup):
    cfg, _, params = setup
    rng = np.random.default_rng(3)
    engine = Engine(cfg, params, batch_slots=1, max_len=32, max_queue=2)

    def req():
        return Request(prompt=rng.integers(0, cfg.vocab, size=4)
                       .astype(np.int32), max_new=2)

    admitted = [req(), req()]
    for r in admitted:
        engine.submit(r)
    with pytest.raises(QueueFullError):
        engine.submit(req())
    engine.run_until_done()               # admitted requests still finish
    assert all(r.done and len(r.out) == 2 for r in admitted)


def test_deadline_expires_queued_request(setup):
    """A request whose deadline lapses while queued finishes with
    ``done=True`` and a typed ``error`` instead of decoding forever;
    requests without deadlines are unaffected."""
    cfg, _, params = setup
    rng = np.random.default_rng(4)
    engine = Engine(cfg, params, batch_slots=1, max_len=32)

    late = Request(prompt=rng.integers(0, cfg.vocab, size=4)
                   .astype(np.int32), max_new=2, deadline_s=0.0)
    ok = Request(prompt=rng.integers(0, cfg.vocab, size=4)
                 .astype(np.int32), max_new=2)
    engine.submit(late)
    engine.submit(ok)
    import time
    time.sleep(0.01)                      # let the deadline lapse
    engine.run_until_done()
    assert late.done and isinstance(late.error, DeadlineExceededError)
    assert late.out == []
    assert ok.done and ok.error is None and len(ok.out) == 2


@pytest.mark.parametrize("layout", ["fixed", "auto"])
def test_engine_sharded_matches_unsharded(setup, layout):
    """The mesh/layout serving path (planner- or fixed-rule-sharded
    params + cache) must decode exactly what the unsharded engine does."""
    cfg, model, params = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)

    plain = Engine(cfg, params, batch_slots=2, max_len=32)
    sharded = Engine(cfg, params, batch_slots=2, max_len=32,
                     mesh=mesh, layout=layout)
    if layout == "auto":
        assert sharded.layout is not None       # planner actually ran
    for eng in (plain, sharded):
        req = Request(prompt=prompt, max_new=4)
        eng.submit(req)
        eng.run_until_done()
        eng.result = req.out
    assert plain.result == sharded.result
