"""Per-kernel validation: Pallas skeletons (interpret mode) vs the ref.py
pure-jnp oracle, swept over shapes, dtypes, variants and programs."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ir
from repro.core.cplan import build_cplan
from repro.core.select import plan
from repro.kernels import ref
from repro.kernels.blocksparse import BCSR, DictCompressed, pad_to_blocks
from repro.kernels.cellwise import cell_pallas
from repro.kernels.multiagg import multiagg_pallas
from repro.kernels.outerprod import outer_pallas
from repro.kernels.rowwise import row_pallas

rng = np.random.default_rng(3)


def _fused_cplan(build_expr, bindings, mode="gen", want=None):
    """Plan the expression and return (cplan, env) of the fused operator.
    ``want`` forces a template type at the output root (kernel sweeps test
    a specific skeleton regardless of what the cost model would pick)."""
    exprs = {k: ir.matrix(k, v.shape if not isinstance(v, BCSR) else v.shape,
                          sparsity=(v.block_sparsity if isinstance(v, BCSR)
                                    else 1.0))
             for k, v in bindings.items()}
    outs = build_expr(**exprs)
    g = ir.Graph.build([outs] if not isinstance(outs, (tuple, list))
                       else list(outs))
    if want is not None:
        from repro.core.cost import _build_spec
        from repro.core.explore import explore
        memo = explore(g)
        root = g.outputs[0]
        entry = next(e for e in memo.entries(root.nid)
                     if e.ttype == want and e.can_root)
        spec = _build_spec(g, memo, root.nid, entry, set())
    else:
        p = plan(g, mode)
        fused = [s for s in p.specs if getattr(s, "fused", False)]
        assert fused, "expression did not produce a fused operator"
        spec = fused[-1]
    cp = build_cplan(g, spec)
    name_by_nid = {n.nid: n.name for n in g.inputs()}
    env = {b.nid: bindings[name_by_nid[b.nid]] for b in cp.binds}
    return cp, env


def _dense_env(env):
    return {k: (v.todense() if hasattr(v, "todense") else v)
            for k, v in env.items()}


SHAPES = [(8, 8), (16, 128), (33, 7), (128, 256), (256, 96)]
DTYPES = [jnp.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("variant", ["full", "row", "col", "none"])
def test_cell_kernel_sweep(shape, dtype, variant):
    X = jnp.asarray(rng.normal(size=shape), dtype)
    Y = jnp.asarray(rng.normal(size=shape), dtype)
    v = jnp.asarray(rng.normal(size=(shape[0], 1)), dtype)

    def expr(X, Y, v):
        c = ir.abs_(X) * Y + v * 2.0
        return {"full": c.sum(), "row": c.rowsums(),
                "col": c.colsums(), "none": c}[variant]

    cp, env = _fused_cplan(expr, dict(X=X, Y=Y, v=v))
    got = cell_pallas(cp, env, interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(16, 16), (64, 48), (128, 128)])
@pytest.mark.parametrize("aggs", [("sum", "sum"), ("sum", "max"),
                                  ("min", "max", "sum")])
def test_multiagg_kernel_sweep(shape, aggs):
    X = jnp.asarray(rng.normal(size=shape), jnp.float32)
    Y = jnp.asarray(rng.normal(size=shape), jnp.float32)

    def expr(X, Y):
        outs = []
        chains = [X * Y, X ** 2, ir.abs_(Y)]
        for a, c in zip(aggs, chains):
            outs.append({"sum": c.sum(), "min": c.min_(),
                         "max": c.max_()}[a])
        return tuple(outs)

    cp, env = _fused_cplan(expr, dict(X=X, Y=Y))
    if not cp.extra:
        pytest.skip("planner did not combine (single agg)")
    got = multiagg_pallas(cp, env, interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m", [32, 100, 256])
@pytest.mark.parametrize("k", [1, 4, 16])
def test_row_kernel_mmchain_sweep(m, k):
    X = jnp.asarray(rng.normal(size=(m, 24)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(24, k)), jnp.float32)

    def expr(X, v):
        return X.T @ (X @ v)

    cp, env = _fused_cplan(expr, dict(X=X, v=v))
    got = row_pallas(cp, env, interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("variant", ["rowsum_chain", "full", "noagg"])
def test_row_kernel_variants(variant):
    X = jnp.asarray(rng.normal(size=(64, 20)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(20, 3)), jnp.float32)

    def expr(X, v):
        q = (X @ v)
        if variant == "rowsum_chain":
            return (q * 2.0).rowsums()
        if variant == "full":
            return (q ** 2).sum()
        return q * q.rowsums()

    cp, env = _fused_cplan(expr, dict(X=X, v=v))
    got = row_pallas(cp, env, interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got).reshape(np.asarray(exp).shape),
                               np.asarray(exp), rtol=1e-3, atol=1e-3)


def _random_bcsr(mb, nb, bs, density, rng):
    mask = rng.random((mb, nb)) < density
    mask.flat[0] = True
    dense = rng.normal(size=(mb * bs, nb * bs)).astype(np.float32)
    dense *= np.kron(mask, np.ones((bs, bs), np.float32))
    return BCSR.from_dense(dense, bs=bs), jnp.asarray(dense)


@pytest.mark.parametrize("bs", [128])
@pytest.mark.parametrize("grid", [(2, 2), (4, 3)])
# 0.0 = empty grid except the one forced block (empty-block parity)
@pytest.mark.parametrize("density", [0.0, 0.3, 0.7, 1.0])
@pytest.mark.parametrize("variant", ["right_mm", "full"])
def test_outer_kernel_sweep(bs, grid, density, variant):
    Xs, Xd = _random_bcsr(grid[0], grid[1], bs, density, rng)
    m, n = Xs.shape
    U = jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)

    def expr(X, U, V):
        c = ir.neq0(X) * (U @ V.T)
        return c @ V if variant == "right_mm" else c.sum()

    from repro.core.templates import TType
    cp, env = _fused_cplan(expr, dict(X=Xs, U=U, V=V), want=TType.OUTER)
    got = outer_pallas(cp, env, interpret=True)
    dense_env = {k: (Xd if isinstance(v, BCSR) else v)
                 for k, v in env.items()}
    exp = ref.execute_dense(cp, dense_env)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


def test_bcsr_roundtrip():
    Xs, Xd = _random_bcsr(3, 4, 128, 0.4, rng)
    np.testing.assert_array_equal(np.asarray(Xs.todense()), np.asarray(Xd))
    Xt = Xs.T
    np.testing.assert_array_equal(np.asarray(Xt.todense()),
                                  np.asarray(Xd).T)
    # transposed copy stays row-major sorted
    rows = np.asarray(Xt.rows)
    assert all(rows[i] <= rows[i + 1] for i in range(len(rows) - 1))


def test_dict_compressed_roundtrip():
    x = np.round(rng.normal(size=(500, 6)) * 3).astype(np.float32)
    c = DictCompressed.from_dense(x)
    np.testing.assert_array_equal(np.asarray(c.todense()), x)
    assert c.compression_ratio > 1.0


def test_pad_to_blocks():
    x = jnp.ones((130, 200))
    p = pad_to_blocks(x, 128)
    assert p.shape == (256, 256)
    assert float(jnp.sum(p)) == 130 * 200


# ---------------------------------------------------------------------------
# template-parity harness: every Pallas skeleton (interpret mode) vs the
# ref.py oracle on dense, sparse (BCSR), and empty-block inputs
# ---------------------------------------------------------------------------

PARITY_KINDS = ["dense", "sparse", "empty"]
_BS = 128


def _parity_matrix(kind, mb=2, nb=3):
    """(bind value, dense mirror): dense array, BCSR at 40% block
    density, or a BCSR whose grid is empty except one forced block."""
    if kind == "dense":
        d = jnp.asarray(rng.normal(size=(mb * _BS, nb * _BS)), jnp.float32)
        return d, d
    density = 0.4 if kind == "sparse" else 0.0
    return _random_bcsr(mb, nb, _BS, density, rng)


@pytest.mark.parametrize("kind", PARITY_KINDS)
@pytest.mark.parametrize("variant", ["none", "row", "col", "full"])
def test_cell_parity_kinds(kind, variant):
    X, Xd = _parity_matrix(kind)
    Y = jnp.asarray(rng.normal(size=Xd.shape), jnp.float32)

    def expr(X, Y):
        c = ir.abs_(X) * Y + 0.5
        return {"none": c, "row": c.rowsums(), "col": c.colsums(),
                "full": c.sum()}[variant]

    cp, env = _fused_cplan(expr, dict(X=X, Y=Y))
    got = cell_pallas(cp, _dense_env(env), interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", PARITY_KINDS)
def test_row_parity_kinds(kind):
    X, Xd = _parity_matrix(kind)
    v = jnp.asarray(rng.normal(size=(Xd.shape[1], 4)), jnp.float32)

    def expr(X, v):
        return X.T @ (X @ v)

    cp, env = _fused_cplan(expr, dict(X=X, v=v))
    got = row_pallas(cp, _dense_env(env), interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("kind", PARITY_KINDS)
def test_multiagg_parity_kinds(kind):
    X, Xd = _parity_matrix(kind)
    Y = jnp.asarray(rng.normal(size=Xd.shape), jnp.float32)

    def expr(X, Y):
        return (X * Y).sum(), (X ** 2).sum(), ir.abs_(Y).max_()

    cp, env = _fused_cplan(expr, dict(X=X, Y=Y))
    if not cp.extra:
        pytest.skip("planner did not combine (single agg)")
    got = multiagg_pallas(cp, _dense_env(env), interpret=True)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ["sparse", "empty"])
def test_bcsr_exploit_path_parity(kind):
    """The sparsity-exploiting jnp execution path (ops.execute on a BCSR
    driver) must agree with the dense oracle — including grids with
    entirely empty block-rows."""
    from repro.kernels.ops import execute
    X, Xd = _parity_matrix(kind)
    Y = jnp.asarray(rng.normal(size=Xd.shape), jnp.float32)

    def expr(X, Y):
        return (ir.abs_(X) * Y).sum()          # sparse-safe wrt X

    cp, env = _fused_cplan(expr, dict(X=X, Y=Y))
    got = execute(cp, env)
    exp = ref.execute_dense(cp, _dense_env(env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
