"""Rewrite-based plan exploration (SPORES-style): rule semantics, the
trace→plan sweep, RW verifier invariants, cache keying, and the
differential equivalence fuzzer.

The fuzzer is the PR's center of gravity: seeded random HOP DAGs
(``diffharness.random_case``) where every variant the bounded rule set
generates must (a) verify strict-clean (RW001–RW004 + the IR checks) and
(b) execute to 1e-5 parity with the original — forward and ``jax.grad``,
across fusion modes and dense/BCSR operand formats.  The smoke tier runs
50 cases in the fast CI job; the deep sweep (``@slow``) runs
``REPRO_FUZZ_CASES`` (default 200) in the full job.

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_rewrite.py
"""

import json
import os
from pathlib import Path

import numpy as np
import pytest

from diffharness import assert_equivalent, plan_and_execute, random_case
from repro.core import fused, fusion_mode, ir
from repro.core.rewrite import (RULES, MAX_VARIANTS, graph_digest,
                                rewrite_variants)
from repro.core.select import MODES
from repro.core.verify import verify_rewrite, verify_variant

GOLDEN = Path(__file__).parent / "golden" / "explain_rewrite_mlogreg.json"

rng = np.random.default_rng(11)


def arr(*shape, scale=0.3):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def _fit_graph(m=64, n=16, k=8):
    """sum(B ⊙ (XᵀY)) — the mlogreg sufficient-statistic form."""
    X, B, Y = ir.matrix("X", (m, n)), ir.matrix("B", (n, k)), \
        ir.matrix("Y", (m, k))
    return ir.Graph.build([(B * (X.T @ Y)).sum()])


# --------------------------------------------------------------------------
# rule-level: each rule generates the documented variant, numerically equal
# --------------------------------------------------------------------------

def _assert_all_variants_equivalent(graph, bindings, grad_wrt=(),
                                    mode="gen"):
    variants = rewrite_variants(graph)
    assert variants, "expected the rule set to fire on this DAG"
    for v in variants:
        assert verify_variant(graph, v.graph, level="strict").ok
        assert_equivalent(graph, v.graph, bindings, grad_wrt=grad_wrt,
                          mode=mode, label="+".join(v.rules))
    return variants


def test_spores_rotate_variants_and_parity():
    g = _fit_graph()
    b = {"X": arr(64, 16), "B": arr(16, 8), "Y": arr(64, 8)}
    variants = _assert_all_variants_equivalent(g, b, grad_wrt=["B"])
    rules = {r for v in variants for r in v.rules}
    assert any(r.startswith("spores_rotate@") for r in rules)
    # the rotation eliminating the (n,k) intermediate exists: a variant
    # whose largest mul runs at (m,k) — sum((X@B) ⊙ Y)
    assert any(any(n.op == "mul" and n.shape == (64, 8)
                   for n in v.graph.nodes) for v in variants)


def test_sum_transpose_removes_dead_t():
    A = ir.matrix("A", (24, 8))
    g = ir.Graph.build([A.T.sum()])
    variants = _assert_all_variants_equivalent(
        g, {"A": arr(24, 8)}, grad_wrt=["A"])
    assert any("sum_transpose@" in r for v in variants for r in v.rules)
    assert any(all(n.op != "t" for n in v.graph.nodes) for v in variants)


def test_sum_mm_factor_parity():
    A, B = ir.matrix("A", (32, 16)), ir.matrix("B", (16, 24))
    g = ir.Graph.build([(A @ B).sum()])
    variants = _assert_all_variants_equivalent(
        g, {"A": arr(32, 16), "B": arr(16, 24)}, grad_wrt=["A", "B"])
    assert any("sum_mm_factor@" in r for v in variants for r in v.rules)


def test_sum_add_split_matrix_and_scalar():
    A, B = ir.matrix("A", (16, 16)), ir.matrix("B", (16, 16))
    g = ir.Graph.build([(A + B).sum()])
    vs = _assert_all_variants_equivalent(
        g, {"A": arr(16, 16), "B": arr(16, 16)}, grad_wrt=["A"])
    assert any("sum_add_split@" in r for v in vs for r in v.rules)
    # scalar operand: sum(A − s) = sum(A) − ncells·s
    g2 = ir.Graph.build([(A - 1.25).sum()])
    _assert_all_variants_equivalent(g2, {"A": arr(16, 16)},
                                    grad_wrt=["A"])


def test_scalar_hoist_mul_and_div():
    A = ir.matrix("A", (16, 32))
    for expr in [(A * 2.5).sum(), (A / 1.5).sum()]:
        g = ir.Graph.build([expr])
        vs = _assert_all_variants_equivalent(g, {"A": arr(16, 32)},
                                             grad_wrt=["A"])
        assert any("scalar_hoist@" in r for v in vs for r in v.rules)


def test_engine_deterministic_across_traces():
    """Two independent builds of the same expression yield identical
    variant chains and digests (topo-index labels, not node ids)."""
    v1 = rewrite_variants(_fit_graph())
    v2 = rewrite_variants(_fit_graph())
    assert [v.rules for v in v1] == [v.rules for v in v2]
    assert [v.digest for v in v1] == [v.digest for v in v2]
    assert len({v.digest for v in v1}) == len(v1)      # digest-deduped
    assert len({v.rules for v in v1}) == len(v1)       # unique labels


def test_engine_bounded():
    vs = rewrite_variants(_fit_graph(), max_variants=2)
    assert len(vs) <= 2
    assert len(rewrite_variants(_fit_graph())) <= MAX_VARIANTS
    # rule-inert DAG: no variants, no wasted work
    A = ir.matrix("A", (8, 8))
    assert rewrite_variants(ir.Graph.build([ir.relu(A) @ A])) == []


# --------------------------------------------------------------------------
# the sweep: argmin across variants, explain(), winning-chain plumbing
# --------------------------------------------------------------------------

def test_sweep_selects_rotated_variant_with_lower_cost():
    """The acceptance-criterion win: for sum(B⊙(XᵀY)) at paper shapes the
    sweep selects a SPORES rotation with strictly lower modeled cost than
    the best plan of the DAG as written, and explain() names the chain."""
    f = fused(lambda X, B, Y: (B * (X.T @ Y)).sum())
    shaped = (np.zeros((10_000, 100), np.float32),
              np.zeros((100, 5), np.float32),
              np.zeros((10_000, 5), np.float32))
    planned = f.trace(*shaped).plan(mode="gen")
    rw = planned.explain()["rewrite"]
    assert rw["enabled"] and rw["n_variants"] >= 1
    assert rw["winner"]["rules"], "a rewrite must win at these shapes"
    assert rw["winner"]["cost"] < rw["winner"]["baseline_cost"]
    assert rw["winner"]["improvement"] > 0
    assert tuple(planned.eplan.rewrite) == tuple(rw["winner"]["rules"])
    # the report is internally consistent: exactly one selected variant,
    # and it is the cheapest planned entry
    sel = [e for e in rw["variants"] if e["selected"]]
    assert len(sel) == 1 and sel[0]["rules"] == rw["winner"]["rules"]
    assert sel[0]["cost"] == min(e["cost"] for e in rw["variants"])


def test_sweep_keeps_original_when_no_rule_wins():
    """A DAG the planner already handles optimally keeps chain () and
    reports the sweep faithfully."""
    f = fused(lambda X, w: (ir.relu(X @ w) ** 2).sum())
    planned = f.trace(np.zeros((256, 16), np.float32),
                      np.zeros((16, 1), np.float32)).plan(mode="gen")
    assert planned.eplan.rewrite == ()
    rw = planned.explain()["rewrite"]
    assert rw["enabled"]
    assert rw["winner"]["rules"] == []
    assert rw["winner"]["improvement"] == 0


def test_rewrite_disabled_context():
    f = fused(lambda X, B, Y: (B * (X.T @ Y)).sum())
    shaped = (np.zeros((10_000, 100), np.float32),
              np.zeros((100, 5), np.float32),
              np.zeros((10_000, 5), np.float32))
    with fusion_mode("gen", rewrite=False):
        planned = f.trace(*shaped).plan()
    assert planned.eplan.rewrite == ()
    assert planned.explain()["rewrite"] == {"enabled": False}


def test_winner_executes_and_differentiates():
    """End to end through the call sugar: the region whose plan is a
    rewritten variant computes the right numbers, fwd and grad."""
    import jax
    import jax.numpy as jnp
    X, B, Y = (jnp.asarray(arr(64, 16)), jnp.asarray(arr(16, 8)),
               jnp.asarray(arr(64, 8)))
    f = fused(lambda X, B, Y: (B * (X.T @ Y)).sum())
    planned = f.trace(X, B, Y).plan(mode="gen")
    assert planned.eplan.rewrite            # a variant won at these shapes
    c = planned.compile()
    np.testing.assert_allclose(np.asarray(c(X, B, Y)),
                               np.asarray(jnp.sum(B * (X.T @ Y))
                                          ).reshape(1, 1),
                               rtol=1e-5, atol=1e-5)
    g = jax.grad(lambda b: c(X, b, Y)[0, 0])(B)
    g_ref = jax.grad(lambda b: jnp.sum(b * (X.T @ Y)))(B)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


def test_algo_fit_terms_region_wins():
    """The shipped mlogreg._fit_terms region selects a rewritten plan at
    the paper shapes fusionlint uses."""
    from repro.algos import mlogreg
    eplan = mlogreg._fit_terms.plan_for(
        X=np.zeros((10_000, 100), np.float32),
        B=np.zeros((100, 5), np.float32),
        Y=np.zeros((10_000, 5), np.float32))
    assert eplan.rewrite != ()


# --------------------------------------------------------------------------
# RW verifier: corruption tests pinning each invariant code
# --------------------------------------------------------------------------

def _codes(diags):
    return {d.code for d in diags}


def test_rw001_output_arity():
    A = ir.matrix("A", (8, 8))
    g = ir.Graph.build([A.sum()])
    bad = ir.Graph.build([A.sum(), A.rowsums()])
    assert "RW001" in _codes(verify_rewrite(g, bad))


def test_rw002_shape_change():
    A = ir.matrix("A", (8, 8))
    g = ir.Graph.build([A.sum()])
    bad = ir.Graph.build([A.rowsums()])          # (8,1), not (1,1)
    diags = verify_rewrite(g, bad)
    assert "RW002" in _codes(diags)
    assert not verify_variant(g, bad).ok


def test_rw003_input_set_change():
    A, B = ir.matrix("A", (8, 8)), ir.matrix("B", (8, 8))
    g = ir.Graph.build([A.sum()])
    bad = ir.Graph.build([B.sum()])              # renamed input
    assert "RW003" in _codes(verify_rewrite(g, bad))
    # same name, different operand shape
    A2 = ir.matrix("A", (16, 8))
    bad2 = ir.Graph.build([A2.sum()])
    diags = verify_rewrite(g, bad2)
    assert "RW003" in _codes(diags)


def test_rw004_zero_preservation_lost():
    """Original sum(A⊙s) is zero-forced by A (and by s); a corrupt
    'rewrite' sum(A)+s loses both forcings → RW004."""
    A, s = ir.matrix("A", (8, 8)), ir.matrix("s", (1, 1))
    g = ir.Graph.build([(A * s).sum()])
    bad = ir.Graph.build([A.sum() + s])
    diags = verify_rewrite(g, bad)
    assert "RW004" in _codes(diags)
    assert not verify_variant(g, bad).ok


def test_clean_variant_passes_all_rw():
    g = _fit_graph()
    for v in rewrite_variants(g):
        rep = verify_variant(g, v.graph, level="strict")
        assert rep.ok, rep.pretty()


def test_illegal_rule_rejected_not_planned(monkeypatch):
    """A shape-changing rule application must be *rejected* by the sweep
    (recorded with its RW codes), never planned or selected."""
    from repro.core import rewrite as rw_mod

    def bad_rule(node):
        if node.is_agg and node.agg_axis == "full" and node.op == "sum":
            # "rewrite" the full sum into rowsums — shape-changing
            return [ir.Expr(node.inputs[0]).rowsums().node]
        return []

    real_variants = rw_mod.rewrite_variants

    def bad_variants(graph, *a, **k):
        return real_variants(graph, rules=(("bad", bad_rule),))

    monkeypatch.setattr("repro.core.rewrite.rewrite_variants",
                        bad_variants)
    f = fused(lambda A: (A * 2.0).sum())
    planned = f.trace(np.zeros((16, 16), np.float32)).plan(mode="gen")
    assert planned.eplan.rewrite == ()           # the original won
    rw = planned.explain()["rewrite"]
    assert rw["n_planned"] == 0 and rw["n_rejected"] >= 1
    assert any("RW002" in r["errors"] for r in rw["rejected"])
    # the planned graph is the original — a full (1,1) aggregate root
    assert planned.eplan.graph.outputs[0].shape == (1, 1)


# --------------------------------------------------------------------------
# cache keying: variant identity in the whole-plan key
# --------------------------------------------------------------------------

def test_variant_identity_in_whole_plan_key():
    from repro.core.codegen import staged_plan_key
    f = fused(lambda X, B, Y: (B * (X.T @ Y)).sum())
    shaped = (np.zeros((10_000, 100), np.float32),
              np.zeros((100, 5), np.float32),
              np.zeros((10_000, 5), np.float32))
    p_rw = f.trace(*shaped).plan(mode="gen")
    with fusion_mode("gen", rewrite=False):
        p_orig = f.trace(*shaped).plan()
    assert p_rw.eplan.rewrite != () and p_orig.eplan.rewrite == ()
    k_rw = staged_plan_key(p_rw.eplan)
    k_orig = staged_plan_key(p_orig.eplan)
    assert k_rw != k_orig
    assert k_rw[-1] == tuple(p_rw.eplan.rewrite)
    assert k_orig[-1] == ()


# --------------------------------------------------------------------------
# the differential fuzzer
# --------------------------------------------------------------------------

def _fuzz_one(seed: int):
    """One fuzzer case: every variant strict-clean + executes to parity
    with the original (fwd + grad), mode cycled per seed."""
    graph, bindings, grad_names = random_case(seed)
    mode = MODES[seed % len(MODES)]
    variants = rewrite_variants(graph, max_variants=8)
    for v in variants:
        rep = verify_variant(graph, v.graph, level="strict")
        assert rep.ok, f"seed {seed} {v.rules}: {rep.pretty()}"
        assert_equivalent(graph, v.graph, bindings, grad_wrt=grad_names,
                          mode=mode,
                          label=f"seed {seed} {'+'.join(v.rules)}")
    return len(variants)


def _fuzz_one_bcsr(seed: int):
    graph, bindings, _ = random_case(seed, fmt="bcsr")
    variants = rewrite_variants(graph, max_variants=4)
    for v in variants:
        rep = verify_variant(graph, v.graph, level="strict")
        assert rep.ok, f"bcsr seed {seed} {v.rules}: {rep.pretty()}"
        assert_equivalent(graph, v.graph, bindings, tol=2e-4,
                          label=f"bcsr seed {seed} {'+'.join(v.rules)}")
    return len(variants)


def test_fuzzer_smoke_dense():
    """Fast-CI tier: 50 seeded dense cases, zero parity or verification
    failures, and the sweep must actually exercise the rule set."""
    total = sum(_fuzz_one(seed) for seed in range(50))
    assert total >= 50, "rule set barely fired — generator regressed?"


def test_fuzzer_smoke_bcsr():
    """Block-sparse operands: the rotation/factoring variants of DAGs
    with a real BCSR matmul operand execute to parity (forward; the
    sparse dispatch path is not differentiable)."""
    total = sum(_fuzz_one_bcsr(seed) for seed in range(1000, 1008))
    assert total >= 8


@pytest.mark.slow
def test_fuzzer_deep_sweep():
    """Full-CI tier: REPRO_FUZZ_CASES seeded cases (default 200, ≥200 in
    CI) across fusion modes, dense + BCSR."""
    cases = int(os.environ.get("REPRO_FUZZ_CASES", "200"))
    total = sum(_fuzz_one(seed) for seed in range(cases))
    total += sum(_fuzz_one_bcsr(seed)
                 for seed in range(2000, 2000 + max(8, cases // 25)))
    assert total >= cases


# --------------------------------------------------------------------------
# golden pin: the winning rewrite + cost delta for mlogreg._fit_terms
# --------------------------------------------------------------------------

def test_explain_rewrite_golden_mlogreg():
    from repro.algos import mlogreg
    planned = mlogreg._fit_terms.trace(
        np.zeros((10_000, 100), np.float32),
        np.zeros((100, 5), np.float32),
        np.zeros((10_000, 5), np.float32)).plan(mode="gen")
    rw = planned.explain()["rewrite"]
    for e in rw["variants"]:
        e["cost"] = round(e["cost"], 14)
    for k in ("cost", "baseline_cost", "improvement"):
        rw["winner"][k] = round(rw["winner"][k], 14)
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.write_text(json.dumps(rw, indent=2, sort_keys=True) + "\n")
        pytest.skip(f"regenerated {GOLDEN}")
    assert GOLDEN.exists(), \
        "golden missing — run with REGEN_GOLDEN=1 to create it"
    expected = json.loads(GOLDEN.read_text())
    assert json.loads(json.dumps(rw, sort_keys=True)) == expected
    # the pinned winner is a genuine rewrite win, locked against drift
    assert expected["winner"]["rules"]
    assert expected["winner"]["improvement"] > 0
