"""Golden-plan regression harness: the fused-operator signatures the
cost-based planner (``mode="gen"``) selects for the paper algorithms are
pinned in ``tests/golden/plans.json``.  A cost-model or enumeration edit
that silently changes a selected plan fails here — intentional plan
changes regenerate the goldens:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_plans.py
"""

import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import fusion_mode
from repro.core.select import MultiAggSpec

GOLDEN = Path(__file__).parent / "golden" / "plans.json"


def _arr(*shape):
    return np.zeros(shape, np.float32)


def _cases():
    """(case name, Fused wrapper, shaped args) for every fusion site of
    the three pinned algorithms — paper-scale (m ≫ n) shapes."""
    from repro.algos import kmeans, l2svm, mlogreg

    X = _arr(10_000, 100)
    w = _arr(100, 1)
    y = _arr(10_000, 1)
    out = _arr(10_000, 1)
    lam = _arr(1, 1)

    Xk = _arr(10_000, 50)
    XC = _arr(10_000, 5)
    xsq = _arr(10_000, 1)
    csq = _arr(1, 5)

    B = _arr(100, 5)
    P = _arr(10_000, 5)
    Y = _arr(10_000, 5)
    v = _arr(100, 5)

    return [
        ("l2svm/hinge", l2svm._hinge, dict(X=X, w=w, y=y)),
        ("l2svm/grad", l2svm._grad, dict(X=X, out=out, y=y, w=w, lam=lam)),
        ("l2svm/search_terms", l2svm._search_terms,
         dict(out=out, yXs=_arr(10_000, 1))),
        ("l2svm/objective", l2svm._objective, dict(out=out, w=w)),
        ("kmeans/sq_rowsums", kmeans._sq_rowsums, dict(X=Xk)),
        ("kmeans/min_dist", kmeans._min_dist,
         dict(XC=XC, xsq=xsq, csq=csq)),
        ("mlogreg/probs", mlogreg._probs, dict(X=X, B=B)),
        ("mlogreg/hvp", mlogreg._hvp, dict(X=X, v=v, P=P)),
        ("mlogreg/grad", mlogreg._grad, dict(X=X, P=P, Y=Y)),
        ("mlogreg/nll_terms", mlogreg._nll_terms, dict(P=P, Y=Y)),
    ]


def _node_label(graph, nid):
    n = graph.by_id[nid]
    return n.name if n.name else n.op


def _signature(eplan):
    """Stable structural signature of every fused operator the plan
    selected: template type, root op, sorted input labels, sparse
    driver — the fields the issue pins down."""
    g = eplan.graph
    sigs = []
    for s in eplan.fused_specs():
        if isinstance(s, MultiAggSpec):
            sigs.append({
                "template": "MAGG(multi)",
                "root": [g.by_id[r].op for r in s.roots],
                "inputs": sorted(_node_label(g, i) for i in s.inputs),
                "driver": None,
            })
        else:
            sigs.append({
                "template": s.ttype.name,
                "root": g.by_id[s.root].op,
                "inputs": sorted(_node_label(g, i) for i in s.inputs),
                "driver": (_node_label(g, s.driver)
                           if s.driver is not None else None),
                "n_covered": len(s.cover),
            })
    # deterministic order for comparison regardless of selection order
    return sorted(sigs, key=lambda d: json.dumps(d, sort_keys=True))


def _compute_all():
    out = {}
    with fusion_mode("gen"):
        for name, wrapper, args in _cases():
            out[name] = _signature(wrapper.plan_for(**args))
    return out


def test_golden_plans_match():
    actual = _compute_all()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(actual, indent=1, sort_keys=True))
        pytest.skip(f"regenerated {GOLDEN}")
    assert GOLDEN.exists(), \
        "golden file missing — run with REGEN_GOLDEN=1 to create it"
    expected = json.loads(GOLDEN.read_text())
    assert set(actual) == set(expected)
    for name in sorted(expected):
        assert actual[name] == expected[name], (
            f"{name}: selected plan changed\n"
            f"  expected: {json.dumps(expected[name])}\n"
            f"  actual:   {json.dumps(actual[name])}\n"
            "If intentional, regenerate with REGEN_GOLDEN=1.")


def test_golden_plans_have_fusion():
    """Sanity on the harness itself: every pinned case selects at least
    one fused operator (otherwise the golden pins nothing)."""
    for name, sigs in _compute_all().items():
        assert sigs, f"{name}: no fused operator selected"


def test_plans_deterministic_across_runs():
    """Planning the same expression twice yields identical signatures —
    the property that makes golden pinning meaningful."""
    a = _compute_all()
    b = _compute_all()
    assert a == b
