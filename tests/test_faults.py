"""Chaos suite: seeded fault injection against the self-healing server.

The invariant under every schedule: **no request is lost** — every
submitted future resolves with either a result (1e-5 parity against
direct execution) or a typed :class:`FusionServeError`; the worker pool
recovers to full size; quarantined plans are reported.  Schedules are
seeded and deterministic (`repro.faults`), so every scenario here is a
reproducible test, not a flake generator.

Fault-test regions use distinct literal constants on purpose: the
whole-plan cache is process-global and keyed structurally, so a region
structurally identical to another test's would hit the cache and skip
the jit-build fault site entirely.

``REPRO_CHAOS_CASES`` scales the randomized sweep (default 6 smoke
cases; CI's full job runs 100).
"""

import os
import threading
import time

import numpy as np
import pytest

from repro import faults
from repro.core import fused, ir
from repro.serve import (DeadlineExceededError, FusionServeError,
                         FusionServer, NonFiniteOutputError,
                         PlanQuarantinedError, QueueFullError,
                         RequestFailedError, ServerClosedError)

rng = np.random.default_rng(23)


def _hinge(c=1.0):
    # l2svm scoring term; the literal c makes the plan structurally
    # unique per test (see module docstring)
    return fused(lambda X, w, y: ir.relu(c - y * (X @ w)))


def _probs():
    def probs(X, W):
        E = ir.exp(X @ W)
        return E / E.rowsums()
    return fused(probs)          # mlogreg class-probability region


def _hinge_args(m, k=16):
    X = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, 1)).astype(np.float32)
    y = np.sign(rng.normal(size=(m, 1))).astype(np.float32)
    return X, w, y


def _parity(got, ref):
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# the faults subsystem itself
# --------------------------------------------------------------------------

def test_registry_covers_the_stack():
    sites = {s.name: s for s in faults.ensure_registered()}
    for name in ("plan.jit_build", "kernels.pallas_call", "dist.segment",
                 "serve.batch_dispatch", "serve.worker"):
        assert name in sites, name
        assert sites[name].handler.strip(), f"{name} has no handler"
        assert sites[name].kinds


def test_schedule_is_deterministic():
    rules = [faults.FaultRule("s", kind="error", p=0.3, count=5),
             faults.FaultRule("s", kind="latency", at=(2, 4))]

    def run():
        sched = faults.FaultSchedule(rules, seed=42)
        fired = [sched.poke("s") is not None for _ in range(50)]
        return fired, sched.events()

    a, b = run(), run()
    assert a == b                       # same seed → same fault sequence
    assert any(a[0])                    # p=0.3 over 50 hits: fires
    other = faults.FaultSchedule(rules, seed=43)
    assert [other.poke("s") is not None for _ in range(50)] != a[0]


def test_fault_point_kinds_and_uninstall():
    assert faults.fault_point("anything") is None      # no schedule: free
    sched = faults.FaultSchedule([
        faults.FaultRule("a", kind="error", at=(0,), message="boom"),
        faults.FaultRule("b", kind="crash", at=(0,)),
        faults.FaultRule("c", kind="latency", at=(0,), delay_s=0.05),
        faults.FaultRule("d", kind="nonfinite", at=(0,)),
    ])
    with faults.inject(sched):
        with pytest.raises(faults.FaultInjected, match="boom"):
            faults.fault_point("a")
        with pytest.raises(faults.WorkerCrash):
            faults.fault_point("b")
        t0 = time.perf_counter()
        assert faults.fault_point("c") is None         # slept, no raise
        assert time.perf_counter() - t0 >= 0.04
        rule = faults.fault_point("d")
        assert rule is not None and rule.kind == "nonfinite"
        assert faults.fault_point("d") is None         # at=(0,) only
    assert faults.active() is None                     # uninstalled
    assert sched.events() == [("a", "error", 0), ("b", "crash", 0),
                              ("c", "latency", 0), ("d", "nonfinite", 0)]


def test_poison_structure():
    p = faults.poison((np.ones((2, 2), np.float32), np.float32(3.0)))
    assert isinstance(p, tuple) and np.isnan(p[0]).all() and np.isnan(p[1])


# --------------------------------------------------------------------------
# fault sites outside the server
# --------------------------------------------------------------------------

def test_dist_segment_fault_degrades_to_fallback():
    from repro.kernels.distributed import SegmentFallback, plan_segment
    sched = faults.FaultSchedule([
        faults.FaultRule("dist.segment", kind="error", at=(0,),
                         message="mesh gone")])
    with faults.inject(sched):
        fb = plan_segment([], mesh=None)
        assert isinstance(fb, SegmentFallback)
        assert "injected fault" in fb.reason           # recorded, not raised
        fb2 = plan_segment([], mesh=None)              # next hit: normal path
        assert "injected" not in fb2.reason


def test_pallas_call_fault_surfaces_and_recovers():
    region = _hinge(1.0731)
    X, w, y = _hinge_args(24)
    planned = region.trace(X=X, w=w, y=y).plan()
    sched = faults.FaultSchedule([
        faults.FaultRule("kernels.pallas_call", kind="error", at=(0,))])
    with faults.inject(sched):
        compiled = planned.compile(pallas="interpret")
        with pytest.raises(Exception):                 # build-time failure
            compiled(X, w, y)
    # the failed build was never cached: a clean retry succeeds
    compiled2 = planned.compile(pallas="interpret")
    _parity(compiled2(X, w, y), region(X, w, y))


# --------------------------------------------------------------------------
# server: build ladder, bisection, degradation, nonfinite
# --------------------------------------------------------------------------

def test_jit_build_fault_degrades_to_exact_shape_serving():
    region = _hinge(1.0417)
    X, w, y = _hinge_args(50)
    server = FusionServer(workers=1, max_batch=4, pad_to=32)
    try:
        sched = faults.FaultSchedule([
            faults.FaultRule("plan.jit_build", kind="error", at=(0,))])
        with faults.inject(sched):
            got = server.submit(region, X, w, y).result(timeout=300)
        _parity(got, region(X, w, y))
        assert sched.events(), "build fault never fired"
        snap = server.metrics.snapshot()
        sites = {r["site"] for r in snap["runtime_fallbacks"]}
        assert "plan.jit_build" in sites               # explicit, counted
        assert snap["requests"]["completed"] == 1
        assert snap["requests"]["failed"] == 0
    finally:
        server.close()


def test_batch_dispatch_error_bisects_and_isolates():
    """One injected tier-0 failure on a 4-request batch must not fail
    the co-batched requests wholesale (the pre-hardening behavior): the
    batch bisects and every request still resolves with parity."""
    region = _hinge(1.0523)
    cases = [_hinge_args(m) for m in (20, 25, 31, 32)]
    server = FusionServer(workers=1, max_batch=8, pad_to=32,
                          autostart=False)
    server._started = True              # enqueue deterministically
    try:
        futs = [server.submit(region, *args) for args in cases]
        server._started = False
        sched = faults.FaultSchedule([
            faults.FaultRule("serve.batch_dispatch", kind="error",
                             at=(0,))])
        with faults.inject(sched):
            server.start()
            results = [f.result(timeout=300) for f in futs]
        for args, got in zip(cases, results):
            _parity(got, region(*args))
        snap = server.metrics.snapshot()
        assert snap["requests"]["completed"] == 4
        assert snap["requests"]["failed"] == 0
        assert snap["resilience"]["bisections"] >= 1
        assert snap["batches"]["failed_dispatches"] >= 1
    finally:
        server.close()


def test_nonfinite_injection_degrades_with_parity():
    """check_finite=True: poisoned batched outputs are detected per
    request, re-executed down the ladder, and the degraded results are
    exact."""
    region = _hinge(1.0611)
    cases = [_hinge_args(m) for m in (20, 28)]
    server = FusionServer(workers=1, max_batch=4, pad_to=32,
                          check_finite=True, autostart=False)
    server._started = True
    try:
        futs = [server.submit(region, *args) for args in cases]
        server._started = False
        sched = faults.FaultSchedule([
            faults.FaultRule("serve.batch_dispatch", kind="nonfinite",
                             at=(0,))])
        with faults.inject(sched):
            server.start()
            results = [f.result(timeout=300) for f in futs]
        for args, got in zip(cases, results):
            _parity(got, region(*args))
        snap = server.metrics.snapshot()
        assert snap["resilience"]["nonfinite_detected"] >= 2
        assert snap["resilience"]["degraded"].get("exact", 0) >= 2
        assert snap["requests"]["failed"] == 0
    finally:
        server.close()


def test_nan_operand_fails_only_its_own_future():
    """A genuinely poisonous request (NaN operand) co-batched with
    healthy ones: vmap rows are independent, so with check_finite the
    poison request fails typed and the healthy ones stay exact."""
    region = _hinge(1.0337)
    good = [_hinge_args(m) for m in (20, 25, 31)]
    Xbad, wbad, ybad = _hinge_args(24)
    Xbad[3, 2] = np.nan
    server = FusionServer(workers=1, max_batch=8, pad_to=32,
                          check_finite=True, retry_budget=2,
                          autostart=False)
    server._started = True
    try:
        futs = [server.submit(region, *args) for args in good]
        bad = server.submit(region, Xbad, wbad, ybad)
        server._started = False
        server.start()
        for args, f in zip(good, futs):
            _parity(f.result(timeout=300), region(*args))
        with pytest.raises((NonFiniteOutputError, RequestFailedError)):
            bad.result(timeout=300)
        snap = server.metrics.snapshot()
        assert snap["requests"]["completed"] == 3
        assert snap["requests"]["failed"] == 1
    finally:
        server.close()


# --------------------------------------------------------------------------
# server: worker crash, deadlines, backpressure, close
# --------------------------------------------------------------------------

def test_worker_crash_requeues_and_respawns():
    region = _hinge(1.0129)
    cases = [_hinge_args(m) for m in (20, 25, 31, 32)]
    server = FusionServer(workers=2, max_batch=4, pad_to=32,
                          autostart=False)
    server._started = True
    try:
        futs = [server.submit(region, *args) for args in cases]
        server._started = False
        sched = faults.FaultSchedule([
            faults.FaultRule("serve.worker", kind="crash", at=(0,))])
        with faults.inject(sched):
            server.start()
            for args, f in zip(cases, futs):
                _parity(f.result(timeout=300), region(*args))
        snap = server.metrics.snapshot()
        assert snap["resilience"]["workers"]["crashes"] == 1
        assert snap["resilience"]["workers"]["respawns"] == 1
        assert snap["resilience"]["workers"]["requeued_requests"] >= 1
        # no worker stays dead: the pool is back at full strength
        alive = [t for t in server._threads if t.is_alive()]
        assert len(alive) == server.workers
    finally:
        server.close()


def test_deadline_exceeded_is_typed():
    region = _hinge(1.0251)
    X, w, y = _hinge_args(20)
    server = FusionServer(workers=1, max_batch=2, pad_to=32,
                          autostart=False)
    server._started = True
    try:
        fut = server.submit(region, X, w, y, deadline_s=0.001)
        time.sleep(0.05)                # expires while queued
        server._started = False
        server.start()
        with pytest.raises(DeadlineExceededError):
            fut.result(timeout=300)
        snap = server.metrics.snapshot()
        assert snap["requests"]["deadline_exceeded"] == 1
    finally:
        server.close()


def test_bounded_queue_backpressure():
    region = _hinge(1.0183)
    args = _hinge_args(20)
    server = FusionServer(workers=1, max_batch=2, pad_to=32,
                          max_queue=2, autostart=False)
    server._started = True
    try:
        futs = [server.submit(region, *args) for _ in range(2)]
        with pytest.raises(QueueFullError):
            server.submit(region, *args)
        snap = server.metrics.snapshot()
        assert snap["resilience"]["rejected"]["backpressure"] == 1
        server._started = False
        server.start()
        for f in futs:
            _parity(f.result(timeout=300), region(*args))
    finally:
        server.close()


def test_close_resolves_queued_futures():
    """Regression: close() used to leave queued futures pending
    forever; they must resolve with ServerClosedError."""
    region = _hinge(1.0457)
    args = _hinge_args(20)
    server = FusionServer(workers=1, max_batch=2, pad_to=32,
                          autostart=False)
    server._started = True
    futs = [server.submit(region, *args) for _ in range(3)]
    server.close()
    for f in futs:
        assert f.done()
        with pytest.raises(ServerClosedError):
            f.result(timeout=0)
    assert server.metrics.snapshot()["requests"]["cancelled"] == 3


# --------------------------------------------------------------------------
# circuit breaker: opens, half-opens, closes — deterministically
# --------------------------------------------------------------------------

def test_breaker_quarantines_and_recovers():
    region = _hinge(1.0871)
    X, w, y = _hinge_args(20)
    server = FusionServer(workers=1, max_batch=2, pad_to=32,
                          retry_budget=0, breaker_threshold=2,
                          breaker_cooldown_s=0.3)
    try:
        sched = faults.FaultSchedule([
            faults.FaultRule("serve.batch_dispatch", kind="error",
                             at=(0, 1, 2))])
        with faults.inject(sched):
            # two consecutive tier-0 failures (budget 0: no ladder) ...
            for _ in range(2):
                with pytest.raises(RequestFailedError):
                    server.submit(region, X, w, y).result(timeout=300)
            # ... open the breaker: typed rejection at submit
            with pytest.raises(PlanQuarantinedError):
                server.submit(region, X, w, y)
            # cooldown → half-open probe; the probe fails → re-open
            time.sleep(0.35)
            with pytest.raises(RequestFailedError):
                server.submit(region, X, w, y).result(timeout=300)
            with pytest.raises(PlanQuarantinedError):
                server.submit(region, X, w, y)
            # cooldown → probe succeeds (schedule exhausted) → closed
            time.sleep(0.35)
            got = server.submit(region, X, w, y).result(timeout=300)
        _parity(got, region(X, w, y))
        snap = server.metrics.snapshot()
        assert snap["resilience"]["breaker"]["opens"] == 2
        assert snap["resilience"]["breaker"]["probes"] == 2
        assert snap["resilience"]["breaker"]["closes"] == 1
        assert snap["resilience"]["rejected"]["quarantined"] == 2
        report = server.metrics.report(server)
        assert report["server"]["breaker"]["quarantined"] == []
        states = {r["key"]: r["state"]
                  for r in server.breaker.snapshot()}
        assert "closed" in states.values()
    finally:
        server.close()


# --------------------------------------------------------------------------
# randomized chaos sweep (REPRO_CHAOS_CASES scales it; CI full job: 100)
# --------------------------------------------------------------------------

N_CASES = int(os.environ.get("REPRO_CHAOS_CASES", "6"))


def _random_schedule(case_rng) -> faults.FaultSchedule:
    rules = []
    if case_rng.random() < 0.8:
        kind = case_rng.choice(["error", "nonfinite", "latency"])
        rules.append(faults.FaultRule(
            "serve.batch_dispatch", kind=str(kind),
            p=float(case_rng.uniform(0.05, 0.3)),
            count=int(case_rng.integers(1, 6)), delay_s=0.005))
    if case_rng.random() < 0.5:
        rules.append(faults.FaultRule(
            "serve.worker", kind="crash",
            p=float(case_rng.uniform(0.02, 0.12)),
            count=int(case_rng.integers(1, 3))))
    if case_rng.random() < 0.3:
        rules.append(faults.FaultRule(
            "serve.worker", kind="latency", p=0.2, count=3,
            delay_s=0.005))
    return faults.FaultSchedule(rules, seed=int(case_rng.integers(1 << 30)))


@pytest.mark.parametrize("case", range(N_CASES))
def test_chaos_no_request_lost(case):
    """THE invariant: under a random seeded multi-fault schedule every
    submitted request resolves — result (with parity) or typed error —
    and the worker pool ends at full strength."""
    case_rng = np.random.default_rng(1000 + case)
    hinge, probs = _hinge(1.0 + case / 512.0), _probs()
    W = rng.normal(size=(16, 5)).astype(np.float32)
    cases = []
    for m in (20, 40, 25, 33):
        cases.append((hinge, _hinge_args(m)))
        Xp = rng.normal(size=(m, 16)).astype(np.float32)
        cases.append((probs, (Xp, W)))
    refs = [r(*args) for r, args in cases]      # fault-free references
    sched = _random_schedule(case_rng)
    server = FusionServer(workers=2, max_batch=4, pad_to=32,
                          check_finite=True, retry_budget=4)
    try:
        with faults.inject(sched):
            futs = [server.submit(r, *args) for r, args in cases]
            outcomes = []
            for f in futs:
                try:
                    outcomes.append(("ok", f.result(timeout=300)))
                except FusionServeError as e:
                    outcomes.append(("err", e))
            for f in futs:
                assert f.done(), "request lost: future never resolved"
        for (kind, val), ref in zip(outcomes, refs):
            if kind == "ok":
                _parity(val, ref)               # degraded paths stay exact
        alive = [t for t in server._threads if t.is_alive()]
        assert len(alive) == server.workers, "a worker stayed dead"
        snap = server.metrics.snapshot()
        resolved = (snap["requests"]["completed"] +
                    snap["requests"]["failed"] +
                    snap["requests"]["deadline_exceeded"])
        assert resolved == len(cases)
    finally:
        server.close()
    # uninstalled: the same server config serves cleanly afterwards
    assert faults.active() is None
