"""Numeric equivalence: every fusion mode and execution path must agree
with a direct jnp evaluation, across an expression battery."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ir, fused, fusion_mode

rng = np.random.default_rng(7)


def arr(*shape, pos=False):
    a = rng.normal(size=shape).astype(np.float32)
    if pos:
        a = np.abs(a) + 0.5
    return jnp.asarray(a)


BATTERY = []


def case(fn):
    BATTERY.append(fn)
    return fn


@case
def _sum_mul3():
    X, Y, Z = arr(65, 33), arr(65, 33), arr(65, 33)
    f = fused(lambda X, Y, Z: (X * Y * Z).sum())
    return f, dict(X=X, Y=Y, Z=Z), jnp.sum(X * Y * Z)


@case
def _weighted_sigmoid():
    X, v = arr(40, 17), arr(40, 1)
    f = fused(lambda X, v: (ir.sigmoid(X) * v + 2.0).rowsums())
    return f, dict(X=X, v=v), jnp.sum(1 / (1 + jnp.exp(-X)) * v + 2.0,
                                      axis=1, keepdims=True)


@case
def _colsums_div():
    X = arr(30, 20, pos=True)
    f = fused(lambda X: (X / 2.0 - 1.0).colsums())
    return f, dict(X=X), jnp.sum(X / 2.0 - 1.0, axis=0, keepdims=True)


@case
def _min_max_agg():
    X, Y = arr(25, 25), arr(25, 25)
    f = fused(lambda X, Y: ir.maximum(X, Y).max_())
    return f, dict(X=X, Y=Y), jnp.max(jnp.maximum(X, Y)).reshape(1, 1)


@case
def _mmchain():
    X, v = arr(120, 16), arr(16, 1)
    f = fused(lambda X, v: X.T @ (X @ v))
    return f, dict(X=X, v=v), X.T @ (X @ v)


@case
def _mmchain_weighted():
    X, v, w = arr(120, 16), arr(16, 2), arr(120, 1)
    f = fused(lambda X, v, w: X.T @ (w * (X @ v)))
    return f, dict(X=X, v=v, w=w), X.T @ (w * (X @ v))


@case
def _mlogreg_inner():
    X, v, P = arr(96, 24), arr(24, 4), arr(96, 5)
    def expr(X, v, P):
        Q = P.cols(0, 4) * (X @ v)
        return X.T @ (Q - P.cols(0, 4) * Q.rowsums())
    Q = P[:, :4] * (X @ v)
    exp = X.T @ (Q - P[:, :4] * Q.sum(1, keepdims=True))
    return fused(expr), dict(X=X, v=v, P=P), exp


@case
def _multi_out():
    X, Y = arr(33, 44), arr(33, 44)
    f = fused(lambda X, Y: ((X * Y).sum(), (X ** 2).sum(), (Y ** 2).sum()))
    return f, dict(X=X, Y=Y), (jnp.sum(X * Y).reshape(1, 1),
                               jnp.sum(X * X).reshape(1, 1),
                               jnp.sum(Y * Y).reshape(1, 1))


@case
def _where_chain():
    X, Y = arr(20, 20), arr(20, 20)
    f = fused(lambda X, Y: ir.where(X > 0.0, X * Y, Y - 1.0).sum())
    exp = jnp.sum(jnp.where(X > 0, X * Y, Y - 1.0)).reshape(1, 1)
    return f, dict(X=X, Y=Y), exp


@pytest.mark.parametrize("mode", ["gen", "fa", "fnr", "none"])
@pytest.mark.parametrize("i", range(len(BATTERY)))
def test_modes_agree(i, mode):
    f, binds, exp = BATTERY[i]()
    with fusion_mode(mode):
        got = f(**binds)
    _assert_close(got, exp)


@pytest.mark.parametrize("i", range(len(BATTERY)))
def test_pallas_agrees(i):
    f, binds, exp = BATTERY[i]()
    with fusion_mode("gen", pallas="interpret"):
        got = f(**binds)
    _assert_close(got, exp)


def _assert_close(got, exp):
    if isinstance(exp, tuple):
        assert isinstance(got, tuple) and len(got) == len(exp)
        for g, e in zip(got, exp):
            _assert_close(g, e)
        return
    g = np.asarray(got).reshape(np.asarray(exp).shape)
    np.testing.assert_allclose(g, np.asarray(exp), rtol=2e-4, atol=2e-4)


def test_jit_compatible():
    import jax
    X, Y = arr(32, 32), arr(32, 32)
    f = fused(lambda X, Y: (X * Y + 1.0).sum())

    @jax.jit
    def step(a, b):
        return f(a, b) * 2.0

    got = step(X, Y)
    np.testing.assert_allclose(np.asarray(got).ravel(),
                               (jnp.sum(X * Y + 1.0) * 2.0).ravel(),
                               rtol=1e-5)


def test_plan_cache_hits():
    from repro.core.codegen import PLAN_CACHE
    PLAN_CACHE.clear()
    X, Y = arr(16, 16), arr(16, 16)
    f = fused(lambda X, Y: (X * Y).sum())
    with fusion_mode("gen"):
        f(X, Y)
        before = PLAN_CACHE.stats.misses
        g = fused(lambda X, Y: (X * Y).sum())   # same structure, new trace
        g(X, Y)
    assert PLAN_CACHE.stats.misses == before      # structural hash hit
    assert PLAN_CACHE.stats.hits >= 1


@pytest.mark.parametrize("mode", ["none", "gen"])
def test_bcsr_transposed_matmul_basic_op(mode):
    """Regression: a BCSR left operand with ta=True must run the
    transposed block-sparse path (not silent densification) and agree
    with the dense reference."""
    from repro.kernels.blocksparse import BCSR

    rng2 = np.random.default_rng(5)
    mask = np.kron(rng2.random((4, 3)) < 0.5, np.ones((16, 16)))
    Xd = (rng2.normal(size=(64, 48)) * mask).astype(np.float32)
    X = BCSR.from_dense(Xd, bs=16)
    B = arr(64, 8)
    # X.T @ B with the transpose folded into the matmul's ta attr
    f = fused(lambda X, B: X.T @ B)
    with fusion_mode(mode):
        got = f(X, B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(Xd.T @ B),
                               rtol=2e-4, atol=2e-4)
