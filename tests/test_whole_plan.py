"""Whole-plan staged execution: one jitted computation per ExecPlan.

Parity: the staged path and the per-operator debug path
(``compile(staged=False)``) must agree to 1e-5 across dense plans, BCSR
fallback plans, and hybrid layout plans — forward *and* ``jax.grad``.
Safety: inputs are never donated (re-calling with the same arrays is
valid and the arrays survive).  Caching: structurally-equal plans share
one staged function via the whole-plan cache, layered on the
operator-level plan cache.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from diffharness import assert_staged_parity
from repro.core import (FusionContext, fused, fusion_mode, ir,
                        plan_cache_stats, whole_plan_cache_stats)
from repro.core.codegen import WHOLE_PLAN_CACHE
from repro.dist.planner import LogicalMesh

rng = np.random.default_rng(21)


def arr(*shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _close(a, b, tol=1e-5):
    a = a if isinstance(a, tuple) else (a,)
    b = b if isinstance(b, tuple) else (b,)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# staged vs per-op parity: forward and jax.grad, per algorithm
# --------------------------------------------------------------------------

def _l2svm_case():
    from repro.algos import l2svm
    X, w = arr(300, 20), arr(20, 1)
    y = jnp.asarray(np.sign(rng.normal(size=(300, 1))), jnp.float32)
    lam = jnp.full((1, 1), 1e-3, jnp.float32)
    return l2svm._objective_full, (X, w, y, lam), 1


def _mlogreg_case():
    from repro.algos import mlogreg
    m, n, k = 200, 12, 4
    X, B = arr(m, n), arr(n, k) * 0.1
    lab = rng.integers(0, k, size=m)
    Y = jnp.asarray(np.eye(k, dtype=np.float32)[lab])
    lam = jnp.full((1, 1), 1e-3, jnp.float32)
    return mlogreg._nll_obj_reg, (X, B, Y, lam), 1


def _autoencoder_case():
    from repro.algos import autoencoder
    Xb = arr(128, 32)
    Ws = [arr(32, 16) * 0.2, arr(16, 2) * 0.2,
          arr(2, 16) * 0.2, arr(16, 32) * 0.2]
    bs = [jnp.zeros((1, d), jnp.float32) for d in (16, 2, 16, 32)]
    args = (Xb, Ws[0], bs[0], Ws[1], bs[1], Ws[2], bs[2], Ws[3], bs[3])
    return autoencoder._recon_loss, args, 1


CASES = {"l2svm": _l2svm_case, "mlogreg": _mlogreg_case,
         "autoencoder": _autoencoder_case}


@pytest.mark.parametrize("name", sorted(CASES))
def test_parity_staged_vs_per_op(name):
    """Forward + grad parity of the staged whole-plan path against the
    per-operator debug path, via the shared differential harness."""
    f, args, gi = CASES[name]()
    assert_staged_parity(f, args, grad_index=gi)


def test_hybrid_layout_parity_staged_vs_per_op():
    """Hybrid plans (abstract 1×8 mesh: distributed placements costed,
    bodies run locally) execute identically on both paths, forward and
    grad."""
    from repro.algos import mlogreg
    f, args, gi = _mlogreg_case()
    mesh = LogicalMesh({"data": 8})
    planned = assert_staged_parity(f, args, grad_index=gi, layout=mesh)
    assert any(o.get("placement") == "distributed"
               for o in planned.explain()["winner"]["operators"])
    # the call-sugar path under a scoped mesh context agrees too
    with FusionContext(mode="gen", layout=mesh):
        g_staged = jax.grad(
            lambda B: mlogreg._nll_obj_reg(args[0], B, args[2],
                                           args[3])[0, 0])(args[1])
    with FusionContext(mode="gen", layout=mesh, staged=False):
        g_per_op = jax.grad(
            lambda B: mlogreg._nll_obj_reg(args[0], B, args[2],
                                           args[3])[0, 0])(args[1])
    _close(g_staged, g_per_op)


def test_bcsr_compiles_staged_and_agrees():
    """Sparse operands compile staged like everything else (the BCSR
    program lowers inside the whole-plan jit) — same numbers as the
    dense reference, one dispatch per call, no recorded fallback."""
    from repro.kernels.blocksparse import BCSR
    rng2 = np.random.default_rng(5)
    mask = np.kron(rng2.random((4, 3)) < 0.5, np.ones((16, 16)))
    mask[:16, :16] = 1.0
    Xd = (rng2.normal(size=(64, 48)) * mask).astype(np.float32)
    X = BCSR.from_dense(Xd, bs=16)
    B = arr(64, 8)
    f = fused(lambda X, B: X.T @ B)
    planned = f.trace(X, B).plan(mode="gen")
    compiled = planned.compile(staged=True)
    got = compiled(X, B)
    _close(got, jnp.asarray(Xd.T) @ B, tol=2e-4)
    assert compiled._cplan._staged_fn is not None   # staged, not per-op
    assert compiled._cplan.fallbacks == []
    assert compiled.explain()["execution"]["fallbacks"] == []


def test_pallas_interpret_compiles_staged():
    """pallas="interpret" stages like any other mode: the interpreted
    Pallas kernels trace inside the whole-plan jit."""
    f = fused(lambda X, Y: (X * Y + 1.0).sum())
    X, Y = arr(32, 32), arr(32, 32)
    planned = f.trace(X, Y).plan(mode="gen")
    compiled = planned.compile(pallas="interpret")
    _close(compiled(X, Y), jnp.sum(X * Y + 1.0).reshape(1, 1), tol=2e-4)
    assert compiled._cplan._staged_fn is not None
    assert compiled._cplan.fallbacks == []


# --------------------------------------------------------------------------
# donation safety
# --------------------------------------------------------------------------

def test_inputs_not_donated_recall_is_valid():
    """The staged jit never donates inputs: calling twice with the same
    arrays is valid, returns identical results, and the input buffers
    survive unchanged."""
    f = fused(lambda X, Y: (ir.sigmoid(X) * Y).rowsums())
    X, Y = arr(64, 16), arr(64, 16)
    x_copy = np.asarray(X).copy()
    compiled = f.trace(X, Y).plan(mode="gen").compile(staged=True)
    out1 = compiled(X, Y)
    out2 = compiled(X, Y)                      # same arrays, second call
    _close(out1, out2, tol=0.0)
    # the inputs are still live, readable, bit-identical buffers
    np.testing.assert_array_equal(np.asarray(X), x_copy)


def test_explain_reports_staged_execution_and_donation():
    f = fused(lambda X, w, y: (ir.relu(1.0 - y * (X @ w)) ** 2).sum())
    planned = f.trace(np.zeros((64, 8), np.float32),
                      np.zeros((8, 1), np.float32),
                      np.zeros((64, 1), np.float32)).plan(mode="gen")
    ex = planned.explain()["execution"]
    assert ex["staged"] is True
    assert ex["dispatches_per_call"] == 1
    assert ex["donated_inputs"] == []
    assert ex["freed_intermediates"] >= 1


# --------------------------------------------------------------------------
# whole-plan cache (layered on the operator-level plan cache)
# --------------------------------------------------------------------------

def test_whole_plan_cache_structural_hit():
    """A structurally-equal plan from a different trace reuses the staged
    function (whole-plan hit) while still counting operator-level cache
    traffic underneath."""
    WHOLE_PLAN_CACHE.clear()
    X, Y = arr(16, 16), arr(16, 16)
    f = fused(lambda X, Y: (X * Y).sum())
    with fusion_mode("gen"):
        f(X, Y)
        st = whole_plan_cache_stats()
        assert st.misses >= 1
        misses_before, hits_before = st.misses, st.hits
        g = fused(lambda A, B: (A * B).sum())   # same structure, new trace
        g(X, Y)
    st = whole_plan_cache_stats()
    assert st.misses == misses_before           # no new staged build
    assert st.hits > hits_before
    assert plan_cache_stats().total > 0         # operator layer still hit


def test_per_op_fallback_signature_distinct():
    """staged and per-op compilations of one @fused wrapper are distinct
    context signatures — no silent cross-contamination."""
    f = fused(lambda X: (X * 2.0).rowsums())
    X = arr(24, 6)
    with fusion_mode("gen"):
        a = f(X)
    with fusion_mode("gen", staged=False):
        b = f(X)
    assert len(f._staged) == 2
    _close(a, b, tol=0.0)


def test_literals_are_trace_constants():
    """Literal (1,1) operands are folded into the staged trace — the
    jaxpr has no per-call literal rebuild (constants appear inline)."""
    f = fused(lambda X: (X * 3.5 + 1.25).sum())
    X = arr(8, 8)
    compiled = f.trace(X).plan(mode="gen").compile(staged=True)
    compiled(X)                                  # build
    _fn, raw = compiled._cplan.staged_callable()
    jaxpr = jax.make_jaxpr(raw)(X)
    # one input var only — the literals are not arguments
    assert len(jaxpr.jaxpr.invars) == 1
