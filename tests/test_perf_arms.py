"""§Perf optimization arms must be numerically faithful to their
baselines: chunked vs dense attention, grouped vs repeated GQA,
capacity/ragged vs dense-masked MoE."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.models.moe import moe_capacity, moe_dense, moe_params, moe_ragged


def test_chunked_attention_matches_dense():
    base = get_config("yi-34b").reduced()
    m_d = LM(replace(base, attn_chunk=0))
    m_c = LM(replace(base, attn_chunk=16))
    params = m_d.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, base.vocab)
    ld, _, _ = m_d.apply(params, toks)
    lc, _, _ = m_c.apply(params, toks)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ld),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_windowed():
    base = replace(get_config("gemma3-27b").reduced(), sliding_window=24)
    m_d = LM(replace(base, attn_chunk=0))
    m_c = LM(replace(base, attn_chunk=16))
    params = m_d.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, base.vocab)
    ld, _, _ = m_d.apply(params, toks)
    lc, _, _ = m_c.apply(params, toks)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ld),
                               rtol=1e-4, atol=1e-4)


def test_grouped_gqa_decode_matches():
    cfg = replace(get_config("yi-34b").reduced(), n_kv_heads=2)
    m = LM(cfg)
    mg = LM(replace(cfg, gqa_grouped=True))
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    c1, c2 = m.init_cache(2, 12), mg.init_cache(2, 12)
    _, c1, _ = m.apply(params, toks[:, :8], caches=c1)
    _, c2, _ = mg.apply(params, toks[:, :8], caches=c2)
    l1, _ = m.decode_step(params, c1, toks[:, 8:9], 8)
    l2, _ = mg.decode_step(params, c2, toks[:, 8:9], 8)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["ragged", "capacity"])
def test_moe_impls_match_dense(impl):
    cfg = get_config("olmoe-1b-7b").reduced()
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    yd, auxd = moe_dense(x, p, cfg)
    if impl == "ragged":
        y, aux = moe_ragged(x, p, cfg)
    else:
        y, aux = moe_capacity(x, p, cfg, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux), float(auxd), rtol=1e-5)


def test_moe_a2a_matches_dense_sharded():
    """shard_map all_to_all EP dispatch ≡ dense-masked (subprocess for an
    8-device mesh)."""
    import subprocess
    import sys
    import textwrap
    from pathlib import Path
    code = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, r"%s")
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.moe import moe_dense, moe_a2a, moe_params
        from repro.dist.sharding import activation_rules
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = replace(get_config("olmoe-1b-7b").reduced(),
                      n_experts=8, top_k=2)
        p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
        yd, _ = moe_dense(x, p, cfg)
        pin = {"router": NamedSharding(mesh, P(None, None)),
               "w1": NamedSharding(mesh, P("model", None, None)),
               "w2": NamedSharding(mesh, P("model", None, None)),
               "w3": NamedSharding(mesh, P("model", None, None))}
        with activation_rules(mesh, "dp"):
            jf = jax.jit(lambda x, p: moe_a2a(x, p, cfg,
                                              capacity_factor=4.0),
                         in_shardings=(NamedSharding(mesh,
                                                     P("data", None)), pin))
            ya, _ = jf(x, p)
        err = float(jnp.max(jnp.abs(ya - yd)))
        assert err < 1e-3, err
        print("A2A_OK", err)
        """) % (Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=600)
    assert "A2A_OK" in out.stdout, out.stderr[-2000:]


def test_moe_a2a_fallback_without_rules():
    """Outside activation_rules, a2a falls back to the local capacity
    dispatch (same numerics, no mesh needed)."""
    cfg = get_config("olmoe-1b-7b").reduced()
    from repro.models.moe import moe_a2a
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    yd, _ = moe_dense(x, p, cfg)
    ya, _ = moe_a2a(x, p, cfg, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_differentiable():
    cfg = get_config("olmoe-1b-7b").reduced()
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))

    def loss(p):
        y, aux = moe_capacity(x, p, cfg)
        return jnp.mean(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree_util.tree_leaves(g))
