"""Dry-run machinery tests (small host-device mesh via subprocess for
device-count isolation) + HLO parsing units."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def test_collective_parse_units():
    from repro.launch.costing import _result_bytes, trip_count, parse_hlo
    assert _result_bytes(" f32[8,64]{1,0} ") == 8 * 64 * 4
    assert _result_bytes(" (bf16[4,4], f32[2]) ") == 32 + 8
    hlo = textwrap.dedent("""\
        %cond (p: (s32[])) -> pred[] {
          %c = s32[] constant(7)
          ROOT %r = pred[] compare(%c, %c), direction=LT
        }
        ENTRY %main (p: f32[4]) -> f32[4] {
          ROOT %out = f32[4] add(%p, %p)
        }
        """)
    comps = parse_hlo(hlo)
    assert "%cond" in comps and "%main" in comps
    assert trip_count(comps, "%cond") == 7


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One full lower+compile on an 8-device host mesh — validates the
    whole dry-run path (shardings, specs, stats extraction)."""
    code = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, sys
        sys.path.insert(0, r"%s")
        import jax
        from repro.launch.dryrun_lib import run_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rec = run_cell("xlstm-1.3b", "decode_32k", mesh, "test4x2",
                       save=False)
        assert rec["flops_per_device"] > 0
        assert rec["memory"]["argument_bytes"] > 0
        assert rec["collective_bytes_per_device_trip_corrected"]["total"] \\
            >= rec["collective_bytes_per_device"]["total"]
        print("CELL_OK", rec["flops_per_device"])
        """) % (REPO / "src")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=600)
    assert "CELL_OK" in out.stdout, out.stderr[-2000:]


def test_all_40_cells_accounted():
    """33 live cells + 7 documented long_500k skips = the assigned 40."""
    from repro.configs import SHAPES, all_configs, applicable, cells
    cfgs = all_configs()
    live = cells(cfgs)
    assert len(cfgs) == 10 and len(SHAPES) == 4
    skips = [(a, s.name) for a in cfgs for s in SHAPES.values()
             if not applicable(cfgs[a], s)]
    assert len(live) + len(skips) == 40
    assert all(s == "long_500k" for _, s in skips)
    skipped_archs = {a for a, _ in skips}
    assert skipped_archs == {"grok-1-314b", "olmoe-1b-7b", "yi-34b",
                             "minitron-4b", "starcoder2-7b",
                             "llava-next-34b", "musicgen-large"}


def test_roofline_math():
    from repro.launch.roofline import analyze, ICI_BW
    rec = {"arch": "yi-34b", "shape": "train_4k", "mesh": "x",
           "devices": 256,
           "flops_per_device": 1e15, "bytes_per_device": 1e12,
           "collective_bytes_per_device": {"total": 1e11},
           "collective_bytes_per_device_trip_corrected": {"total": 2e11}}
    out = analyze(rec)
    assert out["terms"]["collective"] == pytest.approx(2e11 / ICI_BW)
    assert out["dominant"] in ("compute", "memory", "collective")
    assert out["model_flops"] > 0
