"""Staged fusion API: trace → plan → compile determinism, explain()
golden snapshot, jax.grad-vs-hand-gradient parity (the backward pass must
execute through *generated fused operators*), operand canonicalization,
context scoping, and layout threading.

Regenerate the explain() golden after an intentional plan change:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_staged_api.py
"""

import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FusionContext, FusionInputError, fused, fusion_mode,
                        ir, plan_cache_stats, current_context)

EXPLAIN_GOLDEN = Path(__file__).parent / "golden" / "explain_l2svm.json"

rng = np.random.default_rng(11)


def arr(*shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# --------------------------------------------------------------------------
# staging pipeline
# --------------------------------------------------------------------------

def _hinge_wrapper():
    return fused(lambda X, w, y: ir.relu(1.0 - y * (X @ w)))


def test_trace_plan_compile_stages():
    f = _hinge_wrapper()
    X, w, y = arr(60, 8), arr(8, 1), arr(60, 1)
    traced = f.trace(X, w, y)
    assert traced.in_names == ["X", "w", "y"]
    assert traced.in_meta["X"]["shape"] == (60, 8)
    planned = traced.plan(mode="gen")
    assert planned.cost > 0
    op = planned.compile()
    out = op(X, w, y)
    ref = jnp.maximum(1.0 - y * (X @ w), 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_trace_accepts_abstract_operands():
    f = _hinge_wrapper()
    traced = f.trace(jax.ShapeDtypeStruct((60, 8), jnp.float32),
                     jax.ShapeDtypeStruct((8, 1), jnp.float32),
                     jax.ShapeDtypeStruct((60, 1), jnp.float32))
    planned = traced.plan(mode="gen")
    assert planned.fused_signatures()


def test_plan_deterministic():
    f = _hinge_wrapper()
    spec = dict(X=np.zeros((60, 8), np.float32),
                w=np.zeros((8, 1), np.float32),
                y=np.zeros((60, 1), np.float32))
    reports = [f.trace(**spec).plan(mode="gen").explain() for _ in range(2)]
    assert reports[0] == reports[1]


def test_mode_and_context_equivalent():
    f = _hinge_wrapper()
    X, w, y = arr(40, 6), arr(6, 1), arr(40, 1)
    a = f.trace(X, w, y).plan(mode="fa")
    with FusionContext(mode="fa"):
        b = f.trace(X, w, y).plan()
    assert a.fused_signatures() == b.fused_signatures()
    assert a.cost == b.cost


def test_explain_golden_l2svm_hinge():
    """explain() for the l2svm hinge chain is pinned (costs rounded —
    the fields the staged API contracts to expose)."""
    from repro.algos import l2svm
    spec = dict(X=np.zeros((10_000, 100), np.float32),
                w=np.zeros((100, 1), np.float32),
                y=np.zeros((10_000, 1), np.float32))
    with fusion_mode("gen"):
        report = l2svm._hinge.trace(**spec).plan().explain()
    # float costs: round for a stable snapshot
    report["winner"]["cost"] = round(report["winner"]["cost"], 12)
    for c in report["candidates"]:
        c["cost"] = round(c["cost"], 12)
    if os.environ.get("REGEN_GOLDEN"):
        EXPLAIN_GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        EXPLAIN_GOLDEN.write_text(json.dumps(report, indent=1,
                                             sort_keys=True))
        pytest.skip(f"regenerated {EXPLAIN_GOLDEN}")
    assert EXPLAIN_GOLDEN.exists(), \
        "golden missing — run with REGEN_GOLDEN=1 to create it"
    expected = json.loads(EXPLAIN_GOLDEN.read_text())
    assert json.loads(json.dumps(report, sort_keys=True)) == expected


# --------------------------------------------------------------------------
# differentiable fused operators
# --------------------------------------------------------------------------

def test_grad_parity_l2svm():
    """jax.grad of the fused objective == the hand-derived fused gradient
    (−Xᵀ(out⊙y) + λw), to 1e-5; the backward pass runs through generated
    fused operators (plan-cache misses grow; explain shows fused bwd)."""
    from repro.algos import l2svm
    X, w = arr(300, 20), arr(20, 1)
    y = jnp.asarray(np.sign(rng.normal(size=(300, 1))), jnp.float32)
    lam = jnp.full((1, 1), 1e-3, jnp.float32)
    with fusion_mode("gen"):
        before = plan_cache_stats().total
        g = jax.grad(lambda w_: l2svm._objective_full(X, w_, y, lam)[0, 0])(w)
        after = plan_cache_stats().total
        out = l2svm._hinge(X, w, y)
        g_hand = l2svm._grad(X, out, y, w, lam)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_hand),
                               rtol=1e-5, atol=1e-5)
    assert after > before          # backward built generated operators


def test_grad_parity_mlogreg():
    """jax.grad of the fused NLL == the hand-derived Xᵀ(P−Y) to 1e-5."""
    from repro.algos import mlogreg
    m, n, k = 400, 12, 4
    X = arr(m, n)
    B = arr(n, k) * 0.1
    lab = rng.integers(0, k, size=m)
    Y = jnp.asarray(np.eye(k, dtype=np.float32)[lab])
    with fusion_mode("gen"):
        g = jax.grad(lambda B_: mlogreg._nll_obj(X, B_, Y)[0, 0])(B)
        P = mlogreg._probs(X, B)
        g_hand = mlogreg._grad(X, P, Y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_hand),
                               rtol=1e-5, atol=1e-5)


def test_backward_is_planned_fused():
    """The gradient DAG goes through explore → select: the backward plan
    itself selects fused operators, visible in explain()."""
    f = fused(lambda X, w, y: (ir.relu(1.0 - y * (X @ w)) ** 2).sum())
    planned = f.trace(arr(80, 8), arr(8, 1), arr(80, 1)).plan(mode="gen")
    report = planned.explain(include_backward=True)
    assert report["backward"]["operators"], "backward selected no fused ops"
    templates = {o["template"] for o in report["backward"]["operators"]}
    assert templates & {"CELL", "ROW", "MAGG", "MAGG(multi)"}


def test_value_and_grad_multi_output():
    f = fused(lambda X, Y: ((X * Y).sum(), (X ** 2).sum()))
    X, Y = arr(30, 10), arr(30, 10)
    with fusion_mode("gen"):
        g = jax.grad(lambda x: sum(jnp.sum(t) for t in f(x, Y)))(X)
    np.testing.assert_allclose(np.asarray(g), np.asarray(Y + 2.0 * X),
                               rtol=1e-5, atol=1e-5)


def test_grad_under_jit_and_scan_compatible():
    f = fused(lambda X, w: ((X @ w) ** 2).sum())
    X, w = arr(50, 5), arr(5, 1)

    @jax.jit
    def step(w_):
        return jax.grad(lambda v: f(X, v)[0, 0])(w_)

    g = step(w)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(2.0 * X.T @ (X @ w)),
                               rtol=2e-4, atol=2e-4)


def test_vmap_over_cellwise_fused_op():
    f = fused(lambda X, y: ir.relu(1.0 - y * X))
    Xb = arr(3, 20, 4)
    y = arr(20, 1)
    with fusion_mode("gen"):
        out = jax.vmap(lambda x: f(x, y))(Xb)
    ref = jnp.maximum(1.0 - y * Xb, 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_fused_rmsnorm_layer_parity():
    """models/layers.norm(fusion=) routes the rmsnorm Row chain through a
    staged fused operator — values and gradients must match the jnp path."""
    from repro.models import layers
    x = arr(2, 6, 16)
    s = arr(16) * 0.1
    a = layers.norm(x, s)
    b = layers.norm(x, s, fusion="gen")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
    ga = jax.grad(lambda x_: jnp.sum(layers.norm(x_, s)))(x)
    gb = jax.grad(lambda x_: jnp.sum(layers.norm(x_, s, fusion="gen")))(x)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=1e-4, atol=1e-5)


def test_grad_with_inf_masked_input():
    """Reduction cotangent broadcast must not turn ±inf forward cells into
    NaN gradients (-inf logit-mask pattern through an lse-style chain)."""
    f = fused(lambda X: X.sum())
    X = jnp.asarray([[1.0, -np.inf], [np.nan, 2.0]], jnp.float32)
    with fusion_mode("gen"):
        g = jax.grad(lambda x: f(x)[0, 0])(X)
    np.testing.assert_array_equal(np.asarray(g), np.ones((2, 2), np.float32))


def test_custom_params_replan():
    """A context with different CostParams must re-plan, not reuse the
    cached plan selected under the default cost model."""
    from repro.core import CostParams
    f = fused(lambda X, Y: (X * Y + 1.0).rowsums())
    X, Y = arr(32, 8), arr(32, 8)
    with fusion_mode("gen"):
        f(X, Y)
        n_default = len(f._staged)
    slow_reads = CostParams(read_bw=1e6)
    with fusion_mode("gen", params=slow_reads):
        out = f(X, Y)
    assert len(f._staged) == n_default + 1      # distinct signature
    ref = jnp.sum(X * Y + 1.0, axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


# --------------------------------------------------------------------------
# operand canonicalization (1-D / 0-D round trip) + typed errors
# --------------------------------------------------------------------------

def test_vector_and_scalar_operands_round_trip():
    f = fused(lambda X, v, c: ((X @ v) * c).rowsums())
    X = arr(12, 5)
    v1 = arr(5)                      # 1-D vector
    out = f(X, v1, 2.0)              # python scalar
    assert out.shape == (12,)        # column result squeezed back to 1-D
    ref = (X @ v1.reshape(5, 1)) * 2.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref).ravel(),
                               rtol=1e-5)
    # scalar-world full aggregate → 0-D
    g = fused(lambda x: (x ** 2).sum())
    s = g(arr(7))
    assert s.shape == ()
    # pure 2-D calls keep 2-D outputs
    out2d = f(X, v1.reshape(5, 1), jnp.full((1, 1), 2.0))
    assert out2d.shape == (12, 1)


def test_bad_rank_raises_typed_error():
    f = fused(lambda X: (X * 2.0).sum())
    with pytest.raises(FusionInputError, match="'X'"):
        f(jnp.zeros((2, 3, 4)))
    with pytest.raises(FusionInputError, match="'X'"):
        f(object())


# --------------------------------------------------------------------------
# contexts
# --------------------------------------------------------------------------

def test_context_scoping_immutable():
    base = current_context()
    ctx = FusionContext(mode="fnr", pallas="interpret")
    with ctx:
        assert current_context().mode == "fnr"
        with fusion_mode(mode="fa"):
            inner = current_context()
            assert inner.mode == "fa"
            assert inner.pallas == "interpret"   # derived, not reset
        assert current_context() is ctx
    assert current_context() is base or current_context().mode == base.mode
    assert ctx.with_(mode="gen").mode == "gen"
    assert ctx.mode == "fnr"                     # frozen


# --------------------------------------------------------------------------
# layout threading
# --------------------------------------------------------------------------

def _host_mesh():
    import jax as _jax
    dev = np.array(_jax.devices()).reshape(-1)
    return _jax.sharding.Mesh(dev, ("data",))


def test_layout_auto_threads_specs_and_executes():
    mesh = _host_mesh()
    f = fused(lambda X, w: (X @ w) * 2.0)
    n_rows = 16 * mesh.shape["data"]
    X, w = arr(n_rows, 8), arr(8, 1)
    planned = f.trace(X, w).plan(mode="gen", layout=mesh)
    report = planned.explain()
    assert report["layout"] is not None
    assert report["layout"]["mesh"] == dict(mesh.shape)
    assert set(report["layout"]["specs"]) >= {"X", "w", "__out0"}
    if mesh.shape["data"] > 1:                  # rows shard over data axis
        assert report["layout"]["specs"]["X"][0] is not None
    out = planned.compile()(X, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray((X @ w) * 2.0),
                               rtol=1e-5)


def test_layout_cost_abstract_mesh():
    """Distributed planning from a CPU container: an abstract LogicalMesh
    re-prices model-sharded side-input reads at ICI bandwidth, raising the
    plan's modeled cost — no devices required."""
    from repro.dist.planner import LogicalMesh
    f = fused(lambda X, W: (X @ W).rowsums())
    spec = dict(X=np.zeros((4096, 512), np.float32),
                W=np.zeros((512, 512), np.float32))
    local = f.trace(**spec).plan(mode="gen")
    dist = f.trace(**spec).plan(mode="gen",
                                layout=LogicalMesh({"data": 8, "model": 8}))
    assert dist.cost >= local.cost
    lay = dist.context.layout
    assert lay is not None
    assert tuple(lay.specs["X"])         # rows/cols actually sharded
    assert lay._shards_cols("W", (512, 512))
