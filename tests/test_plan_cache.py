"""Plan-cache hardening: thread-safety, LRU bounds, public stats, and the
positional re-binding path (a cached operator serving a structurally-equal
plan from a *different* graph with different node ids)."""

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import fused, fusion_mode, plan_cache_stats
from repro.core.codegen import PLAN_CACHE, PlanCache

rng = np.random.default_rng(3)


def arr(*shape, pos=False):
    a = rng.normal(size=shape).astype(np.float32)
    if pos:
        a = np.abs(a) + 0.5
    return jnp.asarray(a)


def test_positional_rebinding_across_equal_graphs():
    """Two separately-traced, structurally-equal graphs have different node
    ids; the second must *hit* the cache yet bind its own inputs in its own
    positions (codegen's positional re-binding).  The expression is
    order-sensitive (A/B − A), so a mis-bound operand changes the result."""
    PLAN_CACHE.clear()
    A, B = arr(24, 12), arr(24, 12, pos=True)
    f = fused(lambda A, B: (A / B - A).rowsums())
    g = fused(lambda P, Q: (P / Q - P).rowsums())   # fresh trace, new nids
    with fusion_mode("gen"):
        out_f = f(A, B)
        misses_after_f = plan_cache_stats().misses
        out_g = g(B, A)            # swapped operands: Q=A, P=B
    st = plan_cache_stats()
    assert st.misses == misses_after_f      # structural hit, no rebuild
    assert st.hits >= 1
    ref_f = jnp.sum(A / B - A, axis=1, keepdims=True)
    ref_g = jnp.sum(B / A - B, axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(ref_f),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(ref_g),
                               rtol=2e-4, atol=2e-4)


def test_lru_eviction_bound():
    cache = PlanCache(maxsize=4)
    from repro.core import ir
    from repro.core.select import plan as plan_graph
    for i in range(8):
        X = ir.matrix("X", (16 + i, 8))        # distinct shapes → new keys
        graph = ir.Graph.build([(X * 2.0).sum()])
        eplan = plan_graph(graph, "gen")
        for spec in eplan.fused_specs():
            cache.get_or_build(graph, spec)
    assert len(cache) <= 4
    assert cache.stats.evictions >= 4
    assert cache.stats.size <= 4


def test_get_or_build_thread_safe():
    cache = PlanCache(maxsize=64)
    from repro.core import ir
    from repro.core.select import plan as plan_graph
    graphs = []
    for i in range(8):
        X = ir.matrix("X", (32, 8 + i))
        graphs.append(ir.Graph.build([(X * 3.0 + 1.0).sum()]))
    plans = [plan_graph(g, "gen") for g in graphs]
    errors = []

    def worker():
        try:
            for g, p in zip(graphs, plans):
                for spec in p.fused_specs():
                    op, cp = cache.get_or_build(g, spec)
                    assert op.cplan.cache_key() == cp.cache_key()
        except Exception as e:        # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # 8 distinct operators built exactly once despite 8 racing threads
    assert cache.stats.misses == 8
    assert cache.stats.hits == 8 * 8 - 8


def test_plan_cache_stats_snapshot():
    PLAN_CACHE.clear()
    X = arr(10, 10)
    f = fused(lambda X: (X * X).sum())
    with fusion_mode("gen"):
        f(X)
    st = plan_cache_stats()
    assert st.misses >= 1 and st.size >= 1
    assert st.total == st.hits + st.misses
    # snapshot, not a live reference
    before = st.misses
    with fusion_mode("gen"):
        fused(lambda Y: (Y + 1.0).sum())(X)
    assert st.misses == before
    assert plan_cache_stats().misses >= before
