"""Plan-cache hardening: thread-safety, LRU bounds, public stats, and the
positional re-binding path (a cached operator serving a structurally-equal
plan from a *different* graph with different node ids).  The second half
covers the whole-plan cache lifecycle (bounded LRU, per-key stats that
survive eviction, build-once under concurrency) and hammers the full
staged pipeline — Traced.plan() / Planned.compile() / execution — from
many threads at once."""

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import (fused, fusion_mode, plan_cache_stats,
                        whole_plan_cache_stats)
from repro.core.codegen import (PLAN_CACHE, WHOLE_PLAN_CACHE, PlanCache,
                                WholePlanCache)

rng = np.random.default_rng(3)


def arr(*shape, pos=False):
    a = rng.normal(size=shape).astype(np.float32)
    if pos:
        a = np.abs(a) + 0.5
    return jnp.asarray(a)


def test_positional_rebinding_across_equal_graphs():
    """Two separately-traced, structurally-equal graphs have different node
    ids; the second must *hit* the cache yet bind its own inputs in its own
    positions (codegen's positional re-binding).  The expression is
    order-sensitive (A/B − A), so a mis-bound operand changes the result."""
    PLAN_CACHE.clear()
    A, B = arr(24, 12), arr(24, 12, pos=True)
    f = fused(lambda A, B: (A / B - A).rowsums())
    g = fused(lambda P, Q: (P / Q - P).rowsums())   # fresh trace, new nids
    with fusion_mode("gen"):
        out_f = f(A, B)
        misses_after_f = plan_cache_stats().misses
        out_g = g(B, A)            # swapped operands: Q=A, P=B
    st = plan_cache_stats()
    assert st.misses == misses_after_f      # structural hit, no rebuild
    assert st.hits >= 1
    ref_f = jnp.sum(A / B - A, axis=1, keepdims=True)
    ref_g = jnp.sum(B / A - B, axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(ref_f),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(ref_g),
                               rtol=2e-4, atol=2e-4)


def test_lru_eviction_bound():
    cache = PlanCache(maxsize=4)
    from repro.core import ir
    from repro.core.select import plan as plan_graph
    for i in range(8):
        X = ir.matrix("X", (16 + i, 8))        # distinct shapes → new keys
        graph = ir.Graph.build([(X * 2.0).sum()])
        eplan = plan_graph(graph, "gen")
        for spec in eplan.fused_specs():
            cache.get_or_build(graph, spec)
    assert len(cache) <= 4
    assert cache.stats.evictions >= 4
    assert cache.stats.size <= 4


def test_get_or_build_thread_safe():
    cache = PlanCache(maxsize=64)
    from repro.core import ir
    from repro.core.select import plan as plan_graph
    graphs = []
    for i in range(8):
        X = ir.matrix("X", (32, 8 + i))
        graphs.append(ir.Graph.build([(X * 3.0 + 1.0).sum()]))
    plans = [plan_graph(g, "gen") for g in graphs]
    errors = []

    def worker():
        try:
            for g, p in zip(graphs, plans):
                for spec in p.fused_specs():
                    op, cp = cache.get_or_build(g, spec)
                    assert op.cplan.cache_key() == cp.cache_key()
        except Exception as e:        # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # 8 distinct operators built exactly once despite 8 racing threads
    assert cache.stats.misses == 8
    assert cache.stats.hits == 8 * 8 - 8


def test_plan_cache_capacity_resize_and_eviction_stats():
    """The LRU bound is a public, adjustable stat: resize() evicts past
    the new bound immediately and the snapshot exposes it."""
    cache = PlanCache(maxsize=8)
    from repro.core import ir
    from repro.core.select import plan as plan_graph
    for i in range(6):
        X = ir.matrix("X", (8 + i, 4))
        g = ir.Graph.build([(X * 2.0).sum()])
        for spec in plan_graph(g, "gen").fused_specs():
            cache.get_or_build(g, spec)
    assert cache.stats.capacity == 8 and cache.stats.evictions == 0
    cache.resize(2)
    assert cache.stats.capacity == 2
    assert len(cache) <= 2
    assert cache.stats.evictions >= 4
    assert cache.stats.size == len(cache)


def test_whole_plan_cache_lru_and_key_stats_survive_eviction():
    """Bounded LRU over jitted whole-plan functions; the per-key
    hit/miss/eviction counters must outlive the evicted entries."""
    cache = WholePlanCache(maxsize=2)
    fns = {}
    for i in range(4):
        key = ("plan", i)
        fns[i] = cache.get_or_create(key, lambda i=i: (lambda: i))
    assert cache.stats.misses == 4
    assert cache.stats.size <= 2 and cache.stats.capacity == 2
    assert cache.stats.evictions == 2
    # evicted key: its stat record survives and charges the rebuild
    rebuilt = cache.get_or_create(("plan", 0), lambda: (lambda: "new"))
    assert rebuilt is not fns[0]
    recs = {r["key"]: r for r in cache.key_stats()}
    d0 = WholePlanCache.key_digest(("plan", 0))
    assert recs[d0]["misses"] == 2 and recs[d0]["evictions"] == 1
    # live key: hit returns the identical function object
    key3 = ("plan", 3)
    assert cache.get_or_create(key3, lambda: None) is fns[3]
    assert recs != {} and cache.stats.hits == 1
    cache.resize(1)
    assert cache.stats.capacity == 1 and cache.stats.size <= 1


def test_whole_plan_get_or_create_builds_once_under_race():
    """16 threads miss the same key simultaneously: exactly one builder
    runs; the rest wait on the in-flight event and share its result."""
    cache = WholePlanCache(maxsize=16)
    barrier = threading.Barrier(16)
    builds = []
    results = []

    def builder():
        builds.append(1)
        return lambda: "built"

    def worker():
        barrier.wait()
        results.append(cache.get_or_create(("hot", "key"), builder))

    threads = [threading.Thread(target=worker) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1
    assert len(set(map(id, results))) == 1      # one shared function
    assert cache.stats.misses == 1 and cache.stats.hits == 15


def test_staged_pipeline_thread_hammer_no_duplicate_compiles():
    """≥8 threads hammer the full staged pipeline — trace → plan →
    compile → execute — over identical AND distinct regions.  Each
    distinct plan structure must compile exactly once (whole-plan
    build-once), counters must stay consistent, and every thread's
    results must be bit-identical to a serial run."""
    makers = [
        lambda: fused(lambda X, w: ((X @ w) * 2.0).rowsums()),
        lambda: fused(lambda X, w: (X * X).sum() + (w * w).sum()),
        lambda: fused(lambda X, w: (X @ w).colsums()),
    ]
    X = arr(48, 12)
    w = arr(12, 1)

    def run_all():
        outs = []
        for make in makers:
            region = make()               # fresh trace, fresh node ids
            compiled = region.trace(X, w).plan(mode="gen").compile()
            outs.append(np.asarray(compiled(X, w)))
        return outs

    PLAN_CACHE.clear()
    WHOLE_PLAN_CACHE.clear()
    serial = run_all()
    serial_plan_misses = plan_cache_stats().misses
    serial_whole_misses = whole_plan_cache_stats().misses

    PLAN_CACHE.clear()
    WHOLE_PLAN_CACHE.clear()
    n_threads = 8
    barrier = threading.Barrier(n_threads)
    results = [None] * n_threads
    errors = []

    def worker(slot):
        try:
            barrier.wait()
            results[slot] = run_all()
        except Exception as e:            # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors

    # no duplicate compiles: the hammered run built exactly what the
    # serial run built, once per distinct structure, despite the race
    wst = whole_plan_cache_stats()
    assert wst.misses == serial_whole_misses == len(makers)
    assert plan_cache_stats().misses == serial_plan_misses
    assert wst.total == wst.hits + wst.misses
    assert wst.hits >= (n_threads - 1) * len(makers)

    # bit-identical results: same jitted fn, same inputs, same machine
    for outs in results:
        assert outs is not None
        for got, ref in zip(outs, serial):
            np.testing.assert_array_equal(got, ref)


def test_rewrite_variant_identity_in_cache_keys():
    """A rewritten region must never be served a staged function cached
    for the original DAG (or vice versa): the winning rule chain is part
    of the whole-plan key, and the per-operator layer keys the variant's
    own (structurally different) CPlans.  The fit-terms form rewrites to
    sum((X@B)⊙Y); with rewriting off the same trace plans the original
    two-operator DAG — same @fused source, different plans, both correct."""
    WHOLE_PLAN_CACHE.clear()
    from repro.core.codegen import staged_plan_key
    X, B, Y = arr(10_000, 100), arr(100, 5) * 0.1, arr(10_000, 5)
    f = fused(lambda X, B, Y: (B * (X.T @ Y)).sum())
    p_rw = f.trace(X, B, Y).plan(mode="gen")
    with fusion_mode("gen", rewrite=False):
        p_orig = f.trace(X, B, Y).plan(mode="gen")
    assert p_rw.eplan.rewrite != ()                 # the rewrite won
    assert p_orig.eplan.rewrite == ()
    k_rw = staged_plan_key(p_rw.eplan, pallas="never")
    k_orig = staged_plan_key(p_orig.eplan, pallas="never")
    assert k_rw != k_orig
    # both compile, populate distinct whole-plan entries, and agree
    out_rw = p_rw.compile(staged=True)(X, B, Y)
    out_orig = p_orig.compile(staged=True)(X, B, Y)
    assert whole_plan_cache_stats().misses >= 2     # no cross-serving
    np.testing.assert_allclose(np.asarray(out_rw), np.asarray(out_orig),
                               rtol=2e-4, atol=2e-4)


def test_plan_cache_stats_snapshot():
    PLAN_CACHE.clear()
    X = arr(10, 10)
    f = fused(lambda X: (X * X).sum())
    with fusion_mode("gen"):
        f(X)
    st = plan_cache_stats()
    assert st.misses >= 1 and st.size >= 1
    assert st.total == st.hits + st.misses
    # snapshot, not a live reference
    before = st.misses
    with fusion_mode("gen"):
        fused(lambda Y: (Y + 1.0).sum())(X)
    assert st.misses == before
    assert plan_cache_stats().misses >= before
