"""Public API-surface snapshot: ``repro.core.__all__`` is a contract.

A PR that adds, renames, or drops a public symbol must edit this list
consciously — silent drift fails here first.
"""

import repro.core as core

PINNED_ALL = [
    "Compiled",
    "CostParams",
    "Diagnostic",
    "Fused",
    "FusionContext",
    "FusionInputError",
    "FusionLayout",
    "NonDifferentiableError",
    "PlanInvariantError",
    "Planned",
    "TPU_V5E",
    "Traced",
    "VerificationError",
    "VerifyReport",
    "current_config",
    "current_context",
    "fuse_exprs",
    "fused",
    "fusion_mode",
    "ir",
    "plan",
    "plan_cache_stats",
    "verify_plan",
    "whole_plan_cache_stats",
]


def test_public_surface_pinned():
    assert sorted(core.__all__) == PINNED_ALL


def test_all_symbols_importable():
    for name in core.__all__:
        assert hasattr(core, name), name


def test_staged_types_are_the_call_sugar_types():
    """The @fused sugar routes through the same staged objects the explicit
    API returns — one pipeline, two spellings."""
    import numpy as np
    f = core.fused(lambda X: (X * 2.0).sum())
    traced = f.trace(np.zeros((4, 4), np.float32))
    planned = traced.plan(mode="gen")
    compiled = planned.compile()
    assert isinstance(traced, core.Traced)
    assert isinstance(planned, core.Planned)
    assert isinstance(compiled, core.Compiled)
