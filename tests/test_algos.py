"""Table-2 algorithm suite: convergence + cross-mode numeric equivalence
(Gen / Gen-FA / Base must match the hand-fused jnp reference)."""

import numpy as np
import pytest

from repro.algos import data, als_cg, autoencoder, glm, kmeans, l2svm, mlogreg

MODES = ("hand", "gen", "fa", "none")


def _run_all(run_fn, *args, **kw):
    out = {}
    for mode in MODES:
        res = run_fn(*args, mode=mode, **kw)
        out[mode] = np.asarray(res[-1])
    return out


def _check(out, rel=2e-2):
    h = out["hand"]
    assert h[-1] <= h[0] + 1e-6          # converges (non-increasing ends)
    for mode in MODES[1:]:
        g = out[mode]
        assert len(g) == len(h)
        np.testing.assert_allclose(g, h, rtol=rel, atol=1e-5)


@pytest.fixture(scope="module")
def cls_data():
    return data.classification(800, 24, k=4, seed=1)


def test_l2svm(cls_data):
    X, Y, ypm = cls_data
    _check(_run_all(l2svm.run, X, ypm, max_iter=8))


def test_mlogreg(cls_data):
    X, Y, ypm = cls_data
    _check(_run_all(mlogreg.run, X, Y, max_outer=4, max_inner=6))


def test_glm():
    Xr, yr = data.regression(600, 16, seed=2)
    _check(_run_all(glm.run, Xr, yr, max_outer=4, max_inner=6))


def test_kmeans():
    Xc, _ = data.clusters(600, 8, k=5, seed=3)
    C0 = Xc[:5]                       # bad init → visible progress
    out = _run_all(kmeans.run, Xc, C0, max_iter=6)
    _check(out)
    assert out["hand"][-1] < out["hand"][0] * 0.9   # real progress


def test_als_cg():
    Xr8 = data.ratings(512, 384, rank=6, bs=128, block_density=0.4, seed=4)
    out = _run_all(als_cg.run, Xr8, rank=6, max_iter=3, max_inner=3)
    _check(out, rel=5e-2)
    assert out["hand"][-1] < out["hand"][0] * 0.5


def test_autoencoder():
    Xim = data.images(512, 64, seed=5)
    _check(_run_all(autoencoder.run, Xim, h1=32, h2=2, batch=128, epochs=1))


def test_als_pallas_interpret():
    """The flagship sparse workload through the Pallas outer kernel."""
    Xr8 = data.ratings(384, 256, rank=4, bs=128, block_density=0.5, seed=6)
    _, _, l_gen = als_cg.run(Xr8, rank=4, max_iter=2, max_inner=2,
                             mode="gen")
    _, _, l_pl = als_cg.run(Xr8, rank=4, max_iter=2, max_inner=2,
                            mode="gen", pallas="interpret")
    np.testing.assert_allclose(l_pl, l_gen, rtol=1e-3)
