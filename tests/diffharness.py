"""Differential equivalence harness: plan-and-execute two HOP DAGs and
assert numerical parity, forward and grad.

The harness is the trust anchor of the rewrite pass (ISSUE 9 / SPORES):
an algebraic rule is only as good as the evidence that every variant it
produces computes the same function, so equivalence is checked by
*execution* — both DAGs go through the full staged pipeline
(trace-equivalent ``Traced`` → ``plan()`` → ``compile()`` → run) and
must agree to ``DEFAULT_TOL`` on forward outputs and on ``jax.grad``
w.r.t. any requested inputs (the grad path exercises planned-backward
over each DAG).  The same helpers also express the older
staged-vs-per-operator parity checks (``assert_staged_parity``), so
``test_whole_plan.py`` and ``test_rewrite.py`` share one oracle.

``random_case`` is the seeded random-DAG generator behind the
differential fuzzer: scalar-valued expressions composed from the
sub-patterns the rewrite rules target (sum-of-matmul-product, dead
transposes under aggregates, sums of sums, scalar-scaled aggregates)
plus generic element-wise chains, over dense or BCSR operands.  Purely
``np.random.default_rng(seed)``-driven — no hypothesis dependency, every
case reproducible from its seed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.core.api import Traced
from repro.core.context import current_context
from repro.kernels.blocksparse import BCSR

DEFAULT_TOL = 1e-5


def allclose(a, b, tol: float = DEFAULT_TOL, label: str = ""):
    """Tuple-normalizing allclose with rtol=atol=tol."""
    a = a if isinstance(a, tuple) else (a,)
    b = b if isinstance(b, tuple) else (b,)
    assert len(a) == len(b), f"{label}: arity {len(a)} != {len(b)}"
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=tol, atol=tol,
            err_msg=f"{label}[out {i}]")


def traced_from_graph(graph: ir.Graph, bindings: dict,
                      name: str = "diff") -> Traced:
    """Wrap a hand-built HOP DAG as a Traced, deriving operand metadata
    from the graph's input nodes and the concrete bindings' formats."""
    meta = {}
    for n in graph.inputs():
        v = bindings[n.name]
        meta[n.name] = {"shape": n.shape,
                        "format": "bcsr" if isinstance(v, BCSR)
                        else "dense",
                        "sparsity": n.sparsity}
    return Traced(name, graph, [n.name for n in graph.inputs()], meta)


def plan_and_execute(graph: ir.Graph, bindings: dict, *, grad_wrt=(),
                     mode: str = "gen", staged: bool = True,
                     pallas: str = "never", layout=None,
                     rewrite: bool = False):
    """Plan a DAG through the staged pipeline and execute it on
    ``bindings``; returns ``(outputs tuple, {name: grad})``.

    ``rewrite=False`` by default: the harness executes the DAG *as
    written* — when comparing a rewrite variant against its original,
    neither side may be silently re-rewritten by the sweep."""
    ctx = current_context().with_(mode=mode, staged=staged, pallas=pallas,
                                  rewrite=rewrite)
    if layout is not None:
        ctx = ctx.with_(layout=layout)
    compiled = traced_from_graph(graph, bindings).plan(context=ctx).compile()
    names = [n.name for n in graph.inputs()]
    outs = compiled(**{n: bindings[n] for n in names})
    outs = outs if isinstance(outs, tuple) else (outs,)
    grads = {}
    for gname in grad_wrt:
        def scalar(v, gname=gname):
            b = {n: bindings[n] for n in names}
            b[gname] = v
            o = compiled(**b)
            o = o if isinstance(o, tuple) else (o,)
            return sum(jnp.sum(x) for x in o)
        grads[gname] = jax.grad(scalar)(bindings[gname])
    return outs, grads


def assert_equivalent(ref_graph: ir.Graph, got_graph: ir.Graph,
                      bindings: dict, *, grad_wrt=(),
                      tol: float = DEFAULT_TOL, label: str = "",
                      **ctx_kw):
    """Plan-and-execute both DAGs on the same bindings and assert parity
    of every forward output and every requested gradient."""
    ref_o, ref_g = plan_and_execute(ref_graph, bindings,
                                    grad_wrt=grad_wrt, **ctx_kw)
    got_o, got_g = plan_and_execute(got_graph, bindings,
                                    grad_wrt=grad_wrt, **ctx_kw)
    allclose(got_o, ref_o, tol=tol, label=f"{label} fwd")
    for n in grad_wrt:
        allclose(got_g[n], ref_g[n], tol=tol, label=f"{label} grad[{n}]")


def assert_staged_parity(f, args, *, grad_index=None, mode: str = "gen",
                         layout=None, tol: float = DEFAULT_TOL):
    """Staged whole-plan execution vs the per-operator debug path must
    agree on one Planned — forward, and (``grad_index``) ``jax.grad``
    w.r.t. that positional operand of the scalar output.  Returns the
    Planned for further assertions."""
    planned = f.trace(*args).plan(mode=mode, layout=layout)
    s = planned.compile(staged=True)
    p = planned.compile(staged=False)
    allclose(p(*args), s(*args), tol=tol, label="staged-vs-per-op fwd")
    if grad_index is not None:
        def obj(op, v):
            a = list(args)
            a[grad_index] = v
            return op(*a)[0, 0]
        gs = jax.grad(lambda v: obj(s, v))(args[grad_index])
        gp = jax.grad(lambda v: obj(p, v))(args[grad_index])
        allclose(gp, gs, tol=tol, label="staged-vs-per-op grad")
    return planned


# --------------------------------------------------------------------------
# seeded random-DAG generation (the fuzzer's case source)
# --------------------------------------------------------------------------

#: dims are multiples of 16 so any operand can be handed to BCSR(bs=16)
_DIMS = (16, 32, 48)


class _CaseBuilder:
    """Accumulates fresh named inputs + their concrete values while the
    term builders below compose a random scalar expression."""

    def __init__(self, rng: np.random.Generator):
        self.rng = rng
        self.bindings: dict = {}
        self.exprs: dict = {}
        self._i = 0

    def new(self, m: int, n: int):
        name = f"X{self._i}"
        self._i += 1
        self.bindings[name] = jnp.asarray(
            self.rng.normal(size=(m, n)).astype(np.float32) * 0.3)
        e = ir.matrix(name, (m, n))
        self.exprs[name] = e
        return e

    def new_bcsr(self, m: int, n: int, density: float = 0.4):
        """A block-sparse input: block mask over 16×16 tiles, value bound
        as a real BCSR, planning-time sparsity hint on the IR matrix."""
        name = f"X{self._i}"
        self._i += 1
        mask = np.kron(self.rng.random((m // 16, n // 16)) < density,
                       np.ones((16, 16)))
        mask[:16, :16] = 1.0                     # never fully empty
        dense = (self.rng.normal(size=(m, n)) * mask * 0.3).astype(
            np.float32)
        self.bindings[name] = BCSR.from_dense(dense, bs=16)
        e = ir.matrix(name, (m, n), sparsity=float(mask.mean()))
        self.exprs[name] = e
        return e

    def scalar(self) -> float:
        return float(np.round(self.rng.uniform(0.5, 2.5), 3))

    def dims(self, k: int = 1):
        vals = self.rng.choice(len(_DIMS), size=k)
        got = tuple(_DIMS[int(v)] for v in vals)
        return got[0] if k == 1 else got


def _term_rotate(b: _CaseBuilder):
    """sum((A@B) ⊙ C) — the SPORES rotation target, random transposes."""
    m, k, n = b.dims(3)
    A = b.new(m, k) if b.rng.random() < 0.5 else b.new(k, m).T
    B = b.new(k, n) if b.rng.random() < 0.5 else b.new(n, k).T
    C = b.new(m, n)
    mm = A @ B
    return ((mm * C) if b.rng.random() < 0.5 else (C * mm)).sum()


def _term_mm(b: _CaseBuilder):
    """sum(A@B) — the sum-of-product factoring target."""
    m, k, n = b.dims(3)
    return (b.new(m, k) @ b.new(k, n)).sum()


def _term_tsum(b: _CaseBuilder):
    """sum(Aᵀ) (or sum_sq/min/max) — the transpose push-down target."""
    m, n = b.dims(2)
    A = b.new(m, n)
    agg = ("sum", "sum_sq", "min", "max")[int(b.rng.integers(4))]
    return A.T._agg(agg, "full")


def _term_addsplit(b: _CaseBuilder):
    """sum(A ± B) or sum(A ± s) — the sum-over-add target."""
    m, n = b.dims(2)
    A = b.new(m, n)
    other = b.new(m, n) if b.rng.random() < 0.6 else b.scalar()
    e = (A + other) if b.rng.random() < 0.5 else (A - other)
    return e.sum()


def _term_scalar(b: _CaseBuilder):
    """sum(A ⊙ s) / sum(A / s) — the scalar-hoist target."""
    m, n = b.dims(2)
    A = b.new(m, n)
    s = b.scalar()
    r = b.rng.random()
    return (A * s).sum() if r < 0.5 else (A / s).sum()


def _term_chain(b: _CaseBuilder):
    """Generic element-wise chain — mostly rule-inert, keeps the fuzzer
    honest about DAGs where no rewrite fires (or only part of the DAG
    rewrites)."""
    m, n = b.dims(2)
    A, B = b.new(m, n), b.new(m, n)
    return (ir.relu(A * B + b.scalar()) * A).sum()


_TERMS = (_term_rotate, _term_mm, _term_tsum, _term_addsplit,
          _term_scalar, _term_chain)


def random_case(seed: int, fmt: str = "dense"):
    """One seeded fuzzer case: ``(graph, bindings, grad_names)``.

    The expression is 1–3 scalar terms (each drawn from the rule-target
    patterns above) combined with +/− and an occasional scalar scale.
    ``fmt="bcsr"`` makes the case a single sum-of-matmul-product term
    whose left matmul operand is a real block-sparse BCSR (gradients are
    skipped for sparse cases — the sparse dispatch path is forward-only).
    """
    rng = np.random.default_rng(seed)
    b = _CaseBuilder(rng)
    if fmt == "bcsr":
        m, k, n = b.dims(3)
        A = b.new_bcsr(m, k)
        mm = A @ b.new(k, n)
        expr = ((mm * b.new(m, n)).sum() if rng.random() < 0.5
                else mm.sum())
        graph = ir.Graph.build([expr])
        return graph, b.bindings, []
    n_terms = int(rng.integers(1, 4))
    terms = []
    for _ in range(n_terms):
        t = _TERMS[int(rng.integers(len(_TERMS)))](b)
        if rng.random() < 0.3:
            t = t * b.scalar()
        terms.append(t)
    expr = terms[0]
    for t in terms[1:]:
        expr = (expr + t) if rng.random() < 0.7 else (expr - t)
    graph = ir.Graph.build([expr])
    dense_names = sorted(b.bindings)
    k = min(len(dense_names), 1 + int(rng.integers(2)))
    idx = rng.choice(len(dense_names), size=k, replace=False)
    grad_names = [dense_names[int(i)] for i in sorted(idx)]
    return graph, b.bindings, grad_names
