"""Docs stay truthful: the CI docs job's checks also run tier-1.

``tools/check_docs.py`` link-checks README.md + docs/ and executes the
README quickstart snippet verbatim — drift between the documented API
and the code fails here before it fails in CI.
"""

import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "architecture.md").exists()


def test_markdown_links_resolve():
    errors = _load_checker().check_links()
    assert not errors, "\n".join(errors)


def test_readme_quickstart_runs_verbatim():
    checker = _load_checker()
    snippet = checker.quickstart_snippet()
    assert "trace" in snippet and "plan" in snippet and "compile" in snippet
    res = checker.run_quickstart()
    assert res.returncode == 0, res.stdout + res.stderr


def test_readme_ci_snippets_discovered():
    names = _load_checker().snippet_names()
    assert "quickstart" in names
    assert "serving" in names


def test_readme_serving_snippet_runs_verbatim():
    checker = _load_checker()
    snippet = checker.ci_snippet("serving")
    assert "FusionServer" in snippet and "submit" in snippet
    res = checker.run_snippet("serving")
    assert res.returncode == 0, res.stdout + res.stderr
