"""Plan-verifier tests: clean plans verify clean, corrupted plans are
caught with the right diagnostic code, and the verifier is wired into the
stage boundaries.

Three layers:

* **property** — every plan the selector emits for random small DAGs (all
  four modes) passes strict verification; hypothesis-driven when
  available, with a seeded fallback sweep that always runs;
* **goldens** — every pinned algorithm region (the ``fusionlint``
  registry) verifies clean in strict mode;
* **corruption** — deliberately broken plans (freed-intermediate read,
  non-zero-preserving sparse-exploit driver, segment epilogue mismatch,
  drifted IR metadata) produce error-severity diagnostics with the
  documented codes, and the typed :class:`PlanInvariantError` raises
  replace the old silent fallbacks.
"""

import importlib.util
import random
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

from repro.core import (PlanInvariantError, VerificationError, fusion_mode,
                        ir, verify_plan)
from repro.core.cost import CostParams, DistParams, TPU_V5E
from repro.core.select import MODES, annotate_segments, plan as plan_graph
from repro.core.verify import verify_exec, verify_graph, verify_selection
from repro.dist import LogicalMesh

REPO = Path(__file__).resolve().parents[1]


def _arr(*shape):
    return np.zeros(shape, np.float32)


def _codes(diags):
    return {d.code for d in diags if d.severity == "error"}


# --------------------------------------------------------------------------
# property: selector output always verifies strict-clean
# --------------------------------------------------------------------------

def _random_graph(seed: int) -> ir.Graph:
    """A seeded random small HOP DAG over compatible shapes."""
    rng = random.Random(seed)
    m, k, n = rng.choice([(8, 4, 3), (12, 6, 2), (6, 3, 5)])
    X = ir.matrix("X", (m, k), sparsity=rng.choice([1.0, 1.0, 0.05]))
    W = ir.matrix("W", (k, n))
    y = ir.matrix("y", (m, 1))
    pool = [X, W, y, X @ W]
    for _ in range(rng.randint(2, 6)):
        a = rng.choice(pool)
        roll = rng.random()
        if roll < 0.3:
            e = rng.choice([ir.relu, ir.exp, ir.sigmoid])(a)
        elif roll < 0.55:
            b = rng.choice([p for p in pool if p.shape == a.shape])
            e = rng.choice([a + b, a * b, a - b])
        elif roll < 0.7:
            e = a * rng.choice([2.0, 0.5]) + 1.0
        elif roll < 0.85:
            mates = [p for p in pool if p.shape[0] == a.shape[1]]
            e = (a @ rng.choice(mates)) if mates else a.T
        else:
            e = rng.choice([a.sum(), a.rowsums()])
        pool.append(e)
    outs = [p for p in pool[3:] if rng.random() < 0.5] or [pool[-1]]
    return ir.Graph.build(outs)


def _assert_all_modes_verify(seed: int) -> None:
    graph = _random_graph(seed)
    for mode in MODES:
        eplan = plan_graph(graph, mode, TPU_V5E)
        report = verify_plan(eplan, level="strict")
        assert not report.errors, (
            f"seed {seed} mode {mode}:\n{report.pretty()}")


def test_random_plans_verify_strict_seeded():
    """Fallback sweep (no hypothesis needed): 12 seeded random DAGs ×
    all four selection modes all verify strict-clean."""
    for seed in range(12):
        _assert_all_modes_verify(seed)


def test_random_plans_verify_strict_hypothesis():
    pytest.importorskip("hypothesis", reason="property tests need "
                        "hypothesis (pip install repro[test])")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 100_000))
    def prop(seed):
        _assert_all_modes_verify(seed)

    prop()


# --------------------------------------------------------------------------
# goldens: every pinned algorithm region verifies clean (fusionlint)
# --------------------------------------------------------------------------

def _load_fusionlint():
    spec = importlib.util.spec_from_file_location(
        "fusionlint", REPO / "tools" / "fusionlint.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_golden_algo_plans_verify_strict():
    """The fusionlint registry regions (the plans the goldens pin) all
    verify strict-clean in gen mode, locally and under a mesh.  CI runs
    the full CLI over every mode; this keeps a fast in-suite gate."""
    fusionlint = _load_fusionlint()
    assert fusionlint.lint(["l2svm", "kmeans", "als_cg"], ["gen"],
                           "strict", verbose=False) == 0


def test_fusionlint_cli_smoke():
    fusionlint = _load_fusionlint()
    assert fusionlint.main(["--algo", "kmeans", "--mode", "gen",
                            "--strict"]) == 0


# --------------------------------------------------------------------------
# corruption: broken plans produce the documented diagnostics
# --------------------------------------------------------------------------

def _small_plan(mode="gen"):
    X = ir.matrix("X", (8, 4))
    w = ir.matrix("w", (4, 1))
    graph = ir.Graph.build([ir.relu(X @ w).sum()])
    return plan_graph(graph, mode, TPU_V5E)


def test_freed_intermediate_read_is_exe001():
    """Liveness corruption: freeing a value at its producer while a later
    operator still reads it must be flagged EXE001."""
    eplan = _small_plan(mode="none")       # every op basic: mm, relu, sum
    mm = next(n.nid for n in eplan.graph.nodes if n.op == "matmul")
    consumer = next(i for i, s in enumerate(eplan.specs)
                    if mm in s.inputs)
    producer = next(i for i, s in enumerate(eplan.specs) if s.root == mm)
    assert producer < consumer
    diags = verify_exec(eplan, last_uses={producer: [mm]})
    assert "EXE001" in _codes(diags)


def test_liveness_of_executed_plan_is_sound():
    """The map codegen actually executes never trips EXE001/EXE002."""
    eplan = _small_plan(mode="none")
    assert not _codes(verify_exec(eplan))


def test_unsafe_sparse_driver_is_sel004():
    """relu(1 − y⊙(Xw)) is NOT zero-preserving w.r.t. X (a zero row of X
    still yields relu(1) = 1), so exploiting X's sparsity would evaluate
    only the non-zeros and be numerically wrong."""
    from repro.algos import l2svm
    with fusion_mode("gen", verify="off"):
        eplan = l2svm._hinge.plan_for(X=_arr(10_000, 100),
                                      w=_arr(100, 1), y=_arr(10_000, 1))
    spec = eplan.fused_specs()[0]
    x_nid = next(n.nid for n in eplan.graph.nodes if n.name == "X")
    assert x_nid in spec.inputs
    spec.driver = x_nid                    # corrupt: unsafe exploitation
    diags = verify_selection(eplan)
    assert "SEL004" in _codes(diags)


def test_mismatched_segment_epilogue_is_sel011():
    """A distributed full-aggregate whose placement claims a "none"
    epilogue contradicts the template registry (full_agg completes with
    psum) — flagged SEL011."""
    from repro.algos import l2svm
    with fusion_mode("gen", layout=LogicalMesh({"data": 4}),
                     verify="off"):
        p = l2svm._objective.trace(out=_arr(10_000, 1),
                                   w=_arr(100, 1)).plan()
    eplan = p.eplan
    assert eplan.segments, "fixture drift: expected a plan segment"
    idx = eplan.segments[0].indices[0]
    pl = eplan.specs[idx].placement
    assert pl.epilogue == "psum"
    eplan.specs[idx].placement = replace(pl, epilogue="none")
    diags = verify_selection(eplan)
    assert "SEL011" in _codes(diags)


def test_corrupt_ir_shape_metadata_is_ir003():
    X = ir.matrix("X", (8, 4))
    w = ir.matrix("w", (4, 1))
    graph = ir.Graph.build([ir.relu(X @ w).sum()])
    mm = next(n for n in graph.nodes if n.op == "matmul")
    mm.shape = (999, 1)                    # drift stored metadata
    assert "IR003" in _codes(verify_graph(graph))


def test_error_report_raises_verification_error():
    eplan = _small_plan(mode="none")
    mm = next(n.nid for n in eplan.graph.nodes if n.op == "matmul")
    producer = next(i for i, s in enumerate(eplan.specs) if s.root == mm)
    report = verify_plan(eplan, level="cheap")
    report.diagnostics.extend(
        verify_exec(eplan, last_uses={producer: [mm]}))
    with pytest.raises(VerificationError) as exc:
        report.raise_if_errors()
    assert "EXE001" in str(exc.value)
    assert isinstance(exc.value, PlanInvariantError)


def test_annotate_segments_raises_on_drifted_placement():
    """Satellite: a placement whose sharded set names a value the spec
    does not bind is a typed PlanInvariantError, not a silent segment."""
    from repro.algos import l2svm
    with fusion_mode("gen", layout=LogicalMesh({"data": 4}),
                     verify="off"):
        p = l2svm._objective.trace(out=_arr(10_000, 1),
                                   w=_arr(100, 1)).plan()
    eplan = p.eplan
    idx = eplan.segments[0].indices[0]
    pl = eplan.specs[idx].placement
    eplan.specs[idx].placement = replace(
        pl, sharded=frozenset(pl.sharded | {99_999}))
    params = CostParams(dist=DistParams(axes=("data",), n=4))
    with pytest.raises(PlanInvariantError):
        annotate_segments(eplan.graph, eplan.specs, params)


def test_annotate_segments_raises_on_bad_epilogue_token():
    from repro.algos import l2svm
    with fusion_mode("gen", layout=LogicalMesh({"data": 4}),
                     verify="off"):
        p = l2svm._objective.trace(out=_arr(10_000, 1),
                                   w=_arr(100, 1)).plan()
    eplan = p.eplan
    idx = eplan.segments[0].indices[0]
    pl = eplan.specs[idx].placement
    eplan.specs[idx].placement = replace(pl, epilogue="allreduce")
    params = CostParams(dist=DistParams(axes=("data",), n=4))
    with pytest.raises(PlanInvariantError):
        annotate_segments(eplan.graph, eplan.specs, params)


# --------------------------------------------------------------------------
# stage-boundary wiring
# --------------------------------------------------------------------------

def test_strict_context_verifies_and_reports():
    from repro.algos import l2svm
    with fusion_mode("gen", verify="strict"):
        p = l2svm._hinge.trace(X=_arr(1_000, 20), w=_arr(20, 1),
                               y=_arr(1_000, 1)).plan()
    assert p._verify is not None and p._verify.level == "strict"
    section = p.explain()["verify"]
    assert section["level"] == "strict"
    assert section["errors"] == 0
    p.compile()                            # exec re-check passes too


def test_verify_off_skips_and_explain_reports_none():
    from repro.algos import l2svm
    with fusion_mode("gen", verify="off"):
        p = l2svm._hinge.trace(X=_arr(1_000, 20), w=_arr(20, 1),
                               y=_arr(1_000, 1)).plan()
    assert p._verify is None
    assert p.explain()["verify"] is None


def test_default_context_runs_cheap_verify():
    from repro.algos import l2svm
    with fusion_mode("gen"):
        p = l2svm._hinge.trace(X=_arr(1_000, 20), w=_arr(20, 1),
                               y=_arr(1_000, 1)).plan()
    assert p._verify is not None and p._verify.level == "cheap"
    assert p._verify.ok
