"""Candidate selection: partitions, interesting points, plan quality."""

from repro.core import ir
from repro.core.explore import explore
from repro.core.partitions import build_partitions
from repro.core.select import MultiAggSpec, plan
from repro.core.templates import TType


def test_partitions_independent():
    # two unconnected fusable chains → two partitions
    X = ir.matrix("X", (100, 100))
    Y = ir.matrix("Y", (100, 100))
    A = ir.matrix("A", (50, 50))
    g = ir.Graph.build([(X * Y).sum(), (A + 1.0).rowsums()])
    memo = explore(g)
    parts = build_partitions(g, memo)
    assert len(parts) == 2


def test_interesting_points_mat_consumers():
    X = ir.matrix("X", (100, 100))
    m = X * 2.0                       # multi-consumer intermediate
    g = ir.Graph.build([(m + 1.0).sum(), (m * m).sum()])
    memo = explore(g)
    parts = build_partitions(g, memo)
    assert len(parts) == 1
    p = parts[0]
    mul = next(n for n in g.nodes if n.op == "mul"
               and any(i.op == "lit" for i in n.inputs))
    assert mul.nid in p.mat_points
    consumers = {c for (c, t) in p.points if t == mul.nid}
    assert len(consumers) >= 2        # one boolean per consuming dependency


def test_template_switch_point():
    """Y + X ⊙ UVᵀ: the Cell consumer of the Outer chain is a switch."""
    X = ir.matrix("X", (1000, 1000), sparsity=0.05)
    U = ir.matrix("U", (1000, 16))
    V = ir.matrix("V", (1000, 16))
    Y = ir.matrix("Y", (1000, 1000))
    out = Y + X * (U @ V.T)
    g = ir.Graph.build([out.sum()])
    memo = explore(g)
    parts = build_partitions(g, memo)
    pts = [p for part in parts for p in part.points]
    assert pts, "expected at least one template-switch interesting point"


def test_gen_beats_heuristics_on_als():
    X = ir.matrix("X", (20000, 20000), sparsity=0.01)
    U = ir.matrix("U", (20000, 100))
    V = ir.matrix("V", (20000, 100))
    r = ir.matrix("r", (20000, 1))
    O = (ir.neq0(X) * (U @ V.T)) @ V + 1e-6 * U * r
    g = ir.Graph.build([O])
    costs = {m: plan(g, m).cost for m in ("gen", "fa", "fnr", "none")}
    assert costs["gen"] < costs["fa"] / 5
    assert costs["gen"] < costs["fnr"] / 5
    assert costs["fa"] <= costs["none"]
    p = plan(g, "gen")
    outers = [s for s in p.specs if getattr(s, "ttype", None) == TType.OUTER]
    assert outers and outers[0].driver is not None


def test_gen_never_worse_than_heuristics():
    X = ir.matrix("X", (100000, 10))
    w = ir.matrix("w", (10, 1))
    y = ir.matrix("y", (100000, 1))
    out = ir.relu(1.0 - y * (X @ w))
    g = ir.Graph.build([(out ** 2).sum(), out.rowsums()])
    c = {m: plan(g, m).cost for m in ("gen", "fa", "fnr", "none")}
    assert c["gen"] <= c["fa"] + 1e-12
    assert c["gen"] <= c["fnr"] + 1e-12
    assert c["gen"] <= c["none"] + 1e-12


def test_multiagg_combining_gen_only():
    X = ir.matrix("X", (1000, 1000))
    Y = ir.matrix("Y", (1000, 1000))
    Z = ir.matrix("Z", (1000, 1000))
    g = ir.Graph.build([(X * Y).sum(), (X * Z).sum(), (X ** 2).sum()])
    pg = plan(g, "gen")
    multi = [s for s in pg.specs if isinstance(s, MultiAggSpec)]
    assert len(multi) == 1 and len(multi[0].roots) == 3
    pf = plan(g, "fa")
    assert not [s for s in pf.specs if isinstance(s, MultiAggSpec)]


def test_fnr_materializes_multi_consumers():
    X = ir.matrix("X", (1000, 1000))
    m = X * 2.0
    g = ir.Graph.build([(m + 1.0).sum(), (m * 3.0).sum()])
    p = plan(g, "fnr")
    # the shared intermediate must be produced by its own operator
    mul = next(n for n in g.nodes if n.op == "mul"
               and any(i.op == "lit" for i in n.inputs))
    assert any(s.root == mul.nid for s in p.specs)
