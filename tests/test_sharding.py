"""Sharding rules: every parameter/cache spec must be valid (rank-matched,
divisibility-checked) for every architecture on both production mesh
shapes — checked abstractly (no device allocation, no compile)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.dist import sharding as sh
from repro.launch.serve import cache_specs_abstract
from repro.models import LM


class _FakeMesh:
    """Mesh stand-in: shape dict + axis names (rules only use these)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESHES = {
    "pod16x16": _FakeMesh({"data": 16, "model": 16}),
    "multipod2x16x16": _FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _axsize(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _check_tree(mesh, specs, abstract):
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    leaves_a = jax.tree_util.tree_leaves(abstract)
    assert len(leaves_s) == len(leaves_a)
    for spec, leaf in zip(leaves_s, leaves_a):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            n = _axsize(mesh, axes)
            assert dim % n == 0, (spec, leaf.shape, dim, n)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch, mesh_name):
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    model = LM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = sh.param_specs(mesh, cfg, params)
    _check_tree(mesh, specs, params)
    # serving layout too (no fsdp axes)
    _check_tree(mesh, sh.param_specs(mesh, cfg, params, serve=True), params)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    mesh = MESHES["pod16x16"]
    model = LM(cfg)
    for shape in SHAPES.values():
        if not shape.is_decode or not applicable(cfg, shape):
            continue
        cache = cache_specs_abstract(model, shape)
        specs = sh.cache_specs(mesh, cfg, shape, cache)
        _check_tree(mesh, specs, cache)


def test_tp_dims_actually_sharded():
    """The big TP dims must not silently fall back to replication."""
    cfg = get_config("yi-34b")
    mesh = MESHES["pod16x16"]
    model = LM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = sh.param_specs(mesh, cfg, params)
    w1 = specs["blocks"][0]["mlp"]["w1"]
    assert "model" in jax.tree_util.tree_leaves(
        [w1], is_leaf=lambda x: isinstance(x, P))[0]
    emb = specs["embed"]
    assert tuple(emb)[0] == "model"           # vocab TP


def test_moe_ep_vs_tp_choice():
    """olmoe (64e) shards experts over model (EP); grok (8e) falls back
    to ff-TP — the documented rule."""
    mesh = MESHES["pod16x16"]
    for arch, expect_ep in (("olmoe-1b-7b", True), ("grok-1-314b", False)):
        cfg = get_config(arch)
        model = LM(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        specs = sh.param_specs(mesh, cfg, params)
        w1 = tuple(specs["blocks"][0]["mlp"]["w1"])
        # leading (G,) stacked dim is None; expert dim is index 1
        assert (w1[1] == "model") == expect_ep, (arch, w1)
