"""Layout-planner regression harness: for every registry config on both
production meshes the searched layout must be (a) valid — every sharded
dim of every param/cache leaf divides its mesh-axis product, (b) no
worse than the PR-1 fixed rules under the same cost model, and
(c) deterministic across runs.  Plus hypothesis property tests over the
enumeration/costing primitives."""

import math

import pytest

from repro.configs import (ARCH_IDS, MESH_SHAPES, SHAPES, applicable,
                           get_config)
from repro.dist import planner

MESH_SIGS = {name: planner.signature_of(shape)
             for name, shape in MESH_SHAPES.items()}

#: the cells the acceptance criteria name: every live config × shape ×
#: production mesh
CELLS = [(arch, shape_name, mesh_name)
         for arch in ARCH_IDS
         for shape_name, shape in SHAPES.items()
         if applicable(get_config(arch), shape)
         for mesh_name in MESH_SHAPES]


@pytest.mark.parametrize("arch,shape_name,mesh_name", CELLS)
def test_searched_layout_valid_and_beats_fixed(arch, shape_name, mesh_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    res = planner.search(cfg, shape, MESH_SIGS[mesh_name])

    # the winner's spec trees are rank-matched and divisibility-clean
    assert planner.validate_layout(cfg, shape, res.winner.layout)

    # auto beats or ties fixed on modeled step time (∞ ties allowed for
    # cells that fit no layout, e.g. grok training on one pod)
    assert res.winner.step_time <= res.fixed.step_time
    if math.isfinite(res.fixed.step_time):
        assert res.speedup >= 1.0

    # the fixed-rule layout is always in the candidate set
    assert any(c.layout == res.fixed.layout for c in res.candidates)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_search_deterministic(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    first = {m: planner.search(cfg, shape, sig)
             for m, sig in MESH_SIGS.items()}
    planner.clear_memo()
    for m, sig in MESH_SIGS.items():
        again = planner.search(cfg, shape, sig)
        assert again.winner.layout == first[m].winner.layout
        assert again.winner.step_time == first[m].winner.step_time
        assert [c.layout for c in again.candidates] == \
            [c.layout for c in first[m].candidates]


def test_every_candidate_is_valid():
    """Not just the winner: every enumerated candidate maps to clean
    spec trees (spot-checked on the families with awkward dims)."""
    for arch in ("grok-1-314b", "jamba-v0.1-52b", "musicgen-large"):
        cfg = get_config(arch)
        shape = SHAPES["decode_32k"]
        for lay in planner.enumerate_layouts(cfg, shape,
                                             MESH_SIGS["pod16x16"]):
            assert planner.validate_layout(cfg, shape, lay), (arch, lay)


def test_serve_replication_beats_fsdp_gather_at_decode():
    """The planner derives PR-1's documented serving rule from cost:
    for a dense model's decode cell the winner replicates params rather
    than all-gathering them every token."""
    cfg = get_config("xlstm-1.3b")
    res = planner.search(cfg, SHAPES["decode_32k"], MESH_SIGS["pod16x16"])
    assert res.winner.layout.serve_params


def test_plan_layout_is_realizable():
    """The consumer entry point only applies candidates the physical
    mesh and runtime MoE dispatch can realize: TP = the mesh's model
    axis, expert role = the EP predicate's choice.  Re-slicing
    recommendations stay in the search report."""
    mesh = planner.LogicalMesh(dict(MESH_SHAPES["pod16x16"]))
    for arch in ("olmoe-1b-7b", "grok-1-314b", "yi-34b", "xlstm-1.3b"):
        cfg = get_config(arch)
        for shape_name in ("train_4k", "decode_32k"):
            shape = SHAPES[shape_name]
            fixed = planner.fixed_layout(cfg, shape,
                                         planner.signature_of(mesh))
            lay = planner.plan_layout(mesh, cfg, shape)
            assert lay.tp == fixed.tp, (arch, shape_name)
            assert lay.moe == fixed.moe, (arch, shape_name)


def test_infeasible_cells_are_reported_not_hidden():
    """grok training does not fit one pod under any enumerated layout —
    the planner must say so (∞/∞ tie), not invent a winner."""
    cfg = get_config("grok-1-314b")
    res = planner.search(cfg, SHAPES["train_4k"], MESH_SIGS["pod16x16"])
    assert not res.winner.feasible
    assert res.speedup == 1.0
    d = res.to_dict()
    assert d["winner"]["step_time"] is None     # strict-JSON artifacts


def test_report_roundtrip(tmp_path):
    import json
    cfg = get_config("yi-34b")
    res = planner.search(cfg, SHAPES["decode_32k"], MESH_SIGS["pod16x16"])
    p = planner.write_report(res, name="yi-34b", mesh_name="pod16x16",
                             out_dir=tmp_path)
    rec = json.loads(p.read_text())
    assert rec["arch"] == "yi-34b"
    assert rec["n_candidates"] == len(rec["candidates"])
    # None = fixed rules fit no HBM at all (auto-only cell)
    assert rec["speedup"] is None or rec["speedup"] >= 1.0
    winner_steps = [c["step_time"] for c in rec["candidates"]
                    if c["feasible"]]
    assert rec["winner"]["step_time"] == min(winner_steps)


# ---------------------------------------------------------------------------
# hypothesis property tests over the primitives (gated — the grid tests
# above must run even without hypothesis installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env-dependent
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @given(dim=st.integers(1, 1 << 20), n=st.integers(1, 512))
    def test_eff_divides(dim, n):
        e = planner._eff(dim, n)
        assert dim % e == 0
        assert e in (1, n)

    @given(dim=st.integers(1, 1 << 16),
           sizes=st.lists(st.integers(1, 16), max_size=4))
    def test_group_eff_divides(dim, sizes):
        g = planner._group_eff(dim, sizes)
        assert dim % g == 0
        total = 1
        for s in sizes:
            total *= s
        assert g <= total

    @settings(max_examples=20, deadline=None)
    @given(arch=st.sampled_from(ARCH_IDS),
           shape_name=st.sampled_from(list(SHAPES)),
           mesh_name=st.sampled_from(list(MESH_SHAPES)))
    def test_enumeration_properties(arch, shape_name, mesh_name):
        """Candidate space: deterministic order, device-count
        preserving, fixed layout reachable, EP only when allowed."""
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        if not applicable(cfg, shape):
            return
        sig = MESH_SIGS[mesh_name]
        devices = 1
        for _, n in sig:
            devices *= n
        lays = planner.enumerate_layouts(cfg, shape, sig)
        assert lays == planner.enumerate_layouts(cfg, shape, sig)
        assert len(set(lays)) == len(lays)
        for lay in lays:
            assert lay.devices == devices
            if lay.moe == "ep":
                assert cfg.n_experts % lay.tp == 0
            if cfg.n_experts == 0:
                assert lay.moe == "dense"
            if shape.kind == "train":
                assert not lay.serve_params
        fixed = planner.fixed_layout(cfg, shape, sig)
        assert fixed in lays

    @settings(max_examples=15, deadline=None)
    @given(arch=st.sampled_from(ARCH_IDS),
           mesh_name=st.sampled_from(list(MESH_SHAPES)),
           shape_name=st.sampled_from(list(SHAPES)))
    def test_costs_are_positive_and_monotone_in_terms(arch, mesh_name,
                                                      shape_name):
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        if not applicable(cfg, shape):
            return
        sig = MESH_SIGS[mesh_name]
        for lc in planner.search(cfg, shape, sig).candidates:
            assert all(v >= 0 for v in lc.terms.values())
            if lc.feasible:
                assert lc.step_time >= max(lc.terms["compute"],
                                           lc.terms["memory"])
                assert math.isfinite(lc.step_time)
            else:
                assert lc.step_time == float("inf")
            assert lc.mem_bytes["total"] >= lc.mem_bytes["params"] >= 0
