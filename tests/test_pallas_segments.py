"""Generated Pallas kernels inside staged and distributed plans.

The silent-fallback fix (shard-local BlockSpecs): fused bodies run as
``pallas_call`` *inside* ``shard_map`` segments instead of silently
downgrading to XLA or per-operator dispatch.  Three layers of proof:

* an in-process parity sweep — dense × BCSR operands across the
  Cell/Row/Outer/MultiAgg templates, ``pallas="interpret"`` vs
  ``pallas="never"`` on the same staged plan, 1e-5;
* jaxpr witnesses — the staged whole-plan trace contains ``pallas_call``,
  and on a real 8-device mesh (subprocess, forced host devices) it sits
  *inside* the ``shard_map`` region;
* the distributed BCSR-main path — an Outer-template plan whose sparse
  main block-row-partitions across 8 shards compiles staged with zero
  recorded fallbacks, and when the operand *cannot* partition the
  downgrade carries a reason (and raises under ``verify="strict"``).
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Fused, fused, ir
from repro.kernels.blocksparse import BCSR

rng = np.random.default_rng(9)


def arr(*shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _bcsr(m, n, bs, density):
    mask = rng.random((m // bs, n // bs)) < density
    mask.flat[0] = True
    dense = (rng.normal(size=(m, n))
             * np.kron(mask, np.ones((bs, bs)))).astype(np.float32)
    return BCSR.from_dense(dense, bs=bs), jnp.asarray(dense)


# --------------------------------------------------------------------------
# interpret-vs-never parity sweep: dense × BCSR × every template
# --------------------------------------------------------------------------

def _cases():
    X, Y = arr(64, 48), arr(64, 48)
    v = arr(48, 3)
    Xs, _ = _bcsr(64, 48, 16, 0.3)
    Xo, _ = _bcsr(1024, 512, 128, 0.05)
    U, V = arr(1024, 8), arr(512, 8)
    return {
        "cell_noagg_dense":
            ("CELL", fused(lambda X, Y: ir.abs_(X) * Y + 2.0),
             dict(X=X, Y=Y)),
        "row_dense":
            ("ROW", fused(lambda X, v: ((X @ v) * 2.0).rowsums()),
             dict(X=X, v=v)),
        "magg_single_dense":
            ("MAGG", fused(lambda X, Y: (X * Y + 1.0).sum()),
             dict(X=X, Y=Y)),
        "magg_multi_dense":
            ("MAGG(multi)",
             fused(lambda X, Y: ((X * Y).sum(), (X ** 2).sum(),
                                 ir.abs_(Y).max_())),
             dict(X=X, Y=Y)),
        "magg_bcsr":
            ("MAGG", fused(lambda X, Y: (X * Y).sum()), dict(X=Xs, Y=Y)),
        "outer_bcsr_right_mm":
            ("OUTER",
             Fused(lambda X, U, V: (ir.neq0(X) * (U @ V.T)) @ V,
                   sparsity={"X": 0.05}),
             dict(X=Xo, U=U, V=V)),
        "outer_bcsr_full_agg":
            ("OUTER",
             Fused(lambda X, U, V: (ir.neq0(X) * (U @ V.T)).sum(),
                   sparsity={"X": 0.05}),
             dict(X=Xo, U=U, V=V)),
    }


@pytest.mark.parametrize("name", sorted(_cases()))
def test_interpret_parity_by_template(name):
    """Same staged plan, ``pallas="interpret"`` vs ``pallas="never"``:
    the generated kernel and the XLA lowering agree to 1e-5, and the
    plan picks the intended template."""
    template, f, args = _cases()[name]
    planned = f.trace(**args).plan(mode="gen")
    ops = planned.explain()["winner"]["operators"]
    assert [o["template"] for o in ops] == [template], ops
    got = planned.compile(pallas="interpret")(**args)
    ref = planned.compile(pallas="never")(**args)
    got = got if isinstance(got, tuple) else (got,)
    ref = ref if isinstance(ref, tuple) else (ref,)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_staged_jaxpr_contains_pallas_call():
    """``pallas="interpret"`` staged plans actually trace the generated
    kernel — the whole-plan jaxpr contains a ``pallas_call``."""
    X, v = arr(64, 48), arr(48, 3)
    f = fused(lambda X, v: ((X @ v) * 2.0).rowsums())
    compiled = f.trace(X, v).plan(mode="gen").compile(pallas="interpret")
    compiled(X, v)
    _fn, raw = compiled._cplan.staged_callable()
    assert "pallas_call" in str(jax.make_jaxpr(raw)(X, v))
    assert compiled._cplan.fallbacks == []


# --------------------------------------------------------------------------
# real-mesh subprocess harness (8 forced host devices)
# --------------------------------------------------------------------------

def _run_forced_devices(prog: str) -> None:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


# jaxpr helpers shared by the subprocess programs: find a pallas_call
# nested anywhere inside a shard_map equation's body
_JAXPR_HELPERS = """
def _subjaxprs(jx):
    for eqn in jx.eqns:
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                yield v.jaxpr
            elif hasattr(v, "eqns"):
                yield v

def _count(jx, name):
    c = sum(1 for eqn in jx.eqns if name in eqn.primitive.name)
    for sub in _subjaxprs(jx):
        c += _count(sub, name)
    return c

def _pallas_inside_shard_map(jx):
    for eqn in jx.eqns:
        inner = [v.jaxpr if hasattr(v, "jaxpr") else v
                 for v in eqn.params.values()
                 if hasattr(v, "jaxpr") or hasattr(v, "eqns")]
        if "shard_map" in eqn.primitive.name:
            if any(_count(sub, "pallas_call") > 0 for sub in inner):
                return True
        if any(_pallas_inside_shard_map(sub) for sub in inner):
            return True
    return False
"""


_SEGMENT_PALLAS_PROG = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import fused, ir

assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
""" + _JAXPR_HELPERS + """
def expr(X1, X2, X3, X4, X5, X6, w):
    A = ir.sigmoid(X1 + X2 + X3 + X4 + X5 + X6)
    return ((A * X1 + X2).sum(), (A - X3).rowsums(),
            (A * A + X4).sum(), (w ** 2).sum())

f = fused(expr)
m, n = 4096, 64
rng = np.random.default_rng(11)
Xs = [jnp.asarray(rng.normal(size=(m, n)), jnp.float32) for _ in range(6)]
w = jnp.asarray(rng.normal(size=(10, 1)), jnp.float32)
tr = f.trace(*Xs, w)
planned = tr.plan(mode="gen", layout=mesh)
segs = planned.explain()["distributed"]["segments"]
assert len(segs) == 1 and segs[0]["n_operators"] >= 2, segs

compiled = planned.compile(pallas="interpret")
outs = compiled(*Xs, w)
assert compiled._cplan.fallbacks == [], compiled._cplan.fallbacks

_fn, raw = compiled._cplan.staged_callable()
jaxpr = jax.make_jaxpr(raw)(*Xs, w)
assert _pallas_inside_shard_map(jaxpr.jaxpr), \\
    "no pallas_call inside a shard_map region"

local = tr.plan(mode="gen").compile(pallas="never")(*Xs, w)
for a, b in zip(outs, local):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
print("OK")
"""


def test_segment_runs_pallas_inside_shard_map():
    """On a real 8-device mesh a multi-operator distributed segment
    executes its generated kernels as ``pallas_call`` *inside* the
    ``shard_map`` region (jaxpr inspection), with 1e-5 parity against
    the local ``pallas="never"`` plan and zero recorded fallbacks."""
    _run_forced_devices(_SEGMENT_PALLAS_PROG)


_DIST_BCSR_PROG = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import Fused, ir
from repro.kernels.blocksparse import BCSR

assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
""" + _JAXPR_HELPERS + """
rng = np.random.default_rng(13)
m, n, bs = 2048, 512, 128                  # mb=16: 2 block rows per shard
mask = rng.random((m // bs, n // bs)) < 0.05
mask.flat[0] = True
Xd = (rng.normal(size=(m, n))
      * np.kron(mask, np.ones((bs, bs)))).astype(np.float32)
X = BCSR.from_dense(Xd, bs=bs)
U = jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)
V = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)

f = Fused(lambda X, U, V: (ir.neq0(X) * (U @ V.T)) @ V,
          sparsity={"X": 0.05})
planned = f.trace(X=X, U=U, V=V).plan(mode="gen", layout=mesh)
ops = planned.explain()["winner"]["operators"]
assert [(o["template"], o.get("placement")) for o in ops] \\
    == [("OUTER", "distributed")], ops

compiled = planned.compile(pallas="interpret")
out = compiled(X=X, U=U, V=V)
assert compiled._cplan._staged_fn is not None          # staged, not per-op
assert compiled._cplan.fallbacks == [], compiled._cplan.fallbacks
assert compiled.explain()["execution"]["fallbacks"] == []

ref = (np.where(Xd != 0, 1.0, 0.0).astype(np.float32)
       * (np.asarray(U) @ np.asarray(V).T)) @ np.asarray(V)
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)
print("OK")
"""


def test_distributed_bcsr_main_compiles_staged():
    """A distributed Outer-template plan with a BCSR main partitions the
    sparse operand block-row-wise across the 8 shards, compiles staged,
    records zero fallbacks, and matches the dense reference to 1e-5."""
    _run_forced_devices(_DIST_BCSR_PROG)


_STRICT_PROG = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import Fused, FusionContext, ir
from repro.core.partitions import PlanInvariantError
from repro.kernels.blocksparse import BCSR

assert len(jax.devices()) == 8
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(17)
m, n, bs = 1536, 512, 128       # mb=12: rows divide 8, block rows do not
mask = rng.random((m // bs, n // bs)) < 0.05
mask.flat[0] = True
Xd = (rng.normal(size=(m, n))
      * np.kron(mask, np.ones((bs, bs)))).astype(np.float32)
X = BCSR.from_dense(Xd, bs=bs)
U = jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)
V = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)

f = Fused(lambda X, U, V: (ir.neq0(X) * (U @ V.T)) @ V,
          sparsity={"X": 0.05})
planned = f.trace(X=X, U=U, V=V).plan(mode="gen", layout=mesh)
assert [o.get("placement") for o in
        planned.explain()["winner"]["operators"]] == ["distributed"]

# default: runs correctly, the downgrade is recorded WITH a reason
compiled = planned.compile(pallas="interpret")
out = compiled(X=X, U=U, V=V)
fbs = compiled.explain()["execution"]["fallbacks"]
assert fbs and all(str(fb.get("reason", "")).strip() for fb in fbs), fbs
assert any("not partitionable" in fb["reason"] for fb in fbs), fbs
ref = (np.where(Xd != 0, 1.0, 0.0).astype(np.float32)
       * (np.asarray(U) @ np.asarray(V).T)) @ np.asarray(V)
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)

# strict: abandoning the costed distributed placement raises
try:
    with FusionContext(mode="gen", layout=mesh, verify="strict",
                       pallas="interpret"):
        f.trace(X=X, U=U, V=V).plan().compile()(X=X, U=U, V=V)
except PlanInvariantError as e:
    assert "abandoned at execution time" in str(e), e
else:
    raise SystemExit("strict did not raise on the abandoned placement")
print("OK")
"""


def test_strict_raises_on_abandoned_distributed_placement():
    """When a costed distributed placement cannot execute (sparse main
    whose block rows don't divide across the shards), the default mode
    records an explained downgrade and still computes the right answer;
    ``verify="strict"`` raises ``PlanInvariantError`` instead."""
    _run_forced_devices(_STRICT_PROG)
