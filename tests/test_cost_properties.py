"""Property tests for the analytical cost model (paper Eq. 4)."""

import math

import pytest

from repro.core import ir
from repro.core.cost import (CostParams, TPU_V5E, mp_cost, node_bytes,
                             node_flops, partition_cost, spec_cost,
                             static_lower_bound)
from repro.core.explore import explore
from repro.core.partitions import build_partitions
from repro.core.select import plan

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402


def test_node_flops_matmul_and_cell():
    X = ir.matrix("X", (100, 50))
    Y = ir.matrix("Y", (50, 20))
    mm = (X @ Y).node
    assert node_flops(mm) == 2 * 100 * 50 * 20
    assert node_flops((X * 2.0).node) == 100 * 50
    assert node_flops(ir.exp(X).node) == 100 * 50 * 16   # transcendental


def test_node_bytes_sparse_vs_dense():
    d = ir.matrix("D", (1000, 1000)).node
    s = ir.matrix("S", (1000, 1000), sparsity=0.01).node
    assert node_bytes(s, TPU_V5E) < node_bytes(d, TPU_V5E)
    assert node_bytes(s, TPU_V5E) == pytest.approx(1e6 * 0.01 * 8)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.001, 0.2))
def test_outer_cost_monotone_in_sparsity(sp):
    """Sparsity-exploiting plans get monotonically cheaper with sparsity
    (within the sparse-format regime — near-dense, sparse storage
    legitimately costs more than dense, as in SystemML's format switch)."""
    def cost_at(s):
        X = ir.matrix("X", (10000, 10000), sparsity=s)
        U = ir.matrix("U", (10000, 32))
        V = ir.matrix("V", (10000, 32))
        g = ir.Graph.build([(ir.neq0(X) * (U @ V.T)) @ V])
        return plan(g, "gen").cost
    assert cost_at(sp) <= cost_at(min(0.4, sp * 2)) + 1e-12


def test_lower_bound_below_all_assignments():
    """C̲ must lower-bound every assignment's true cost (the soundness
    condition for cost-based pruning)."""
    import itertools
    X = ir.matrix("X", (5000, 200))
    m = ir.exp(X)
    g = ir.Graph.build([(m * 2.0).sum(), (m + 1.0).rowsums(), m])
    memo = explore(g)
    for part in build_partitions(g, memo):
        lb = static_lower_bound(g, memo, part, TPU_V5E)
        written = frozenset(set(part.roots) | part.exits)
        for bits in itertools.product([False, True],
                                      repeat=len(part.points)):
            banned = {p for p, b in zip(part.points, bits) if b}
            c = partition_cost(g, memo, part, banned, TPU_V5E)
            assert lb + mp_cost(g, banned, TPU_V5E, written) <= c + 1e-15


def test_distributed_reads_cost_more():
    """Side inputs priced at ICI must raise plan costs (never lower)."""
    X = ir.matrix("X", (1_000_000, 100))
    w = ir.matrix("w", (100, 1))
    y = ir.matrix("y", (1_000_000, 1))
    g = ir.Graph.build([(ir.relu(1.0 - y * (X @ w)) ** 2).sum()])
    local = plan(g, "gen").cost
    slow = CostParams(input_read_bw={y.node.nid: 50e9, w.node.nid: 50e9})
    dist = plan(g, "gen", slow).cost
    assert dist >= local


def test_constraint_violation_infinite():
    from repro.core.cost import FusedOpSpec
    from repro.core.templates import TType
    X = ir.matrix("X", (10, 10))
    g = ir.Graph.build([(X * 2.0).sum()])
    agg = g.outputs[0]
    mul = agg.inputs[0]
    spec = FusedOpSpec(agg.nid, TType.CELL,
                       {agg.nid: None, mul.nid: None},   # fused (2 ops)
                       inputs=list(range(100)))          # too many inputs
    params = CostParams(max_fused_inputs=12)
    assert spec_cost(g, spec, params) == math.inf
