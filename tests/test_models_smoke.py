"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness; plus prefill→decode
consistency against the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM, lm_loss


def _tokens(cfg, B, S, key):
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    return jax.random.randint(key, shape, 0, cfg.vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = _tokens(cfg, B, S + 1, jax.random.PRNGKey(1))
    inp, tgt = toks[:, :S], toks[:, 1:]
    prefix = None
    if cfg.frontend == "vision":
        prefix = jnp.ones((B, 4, cfg.d_model), jnp.float32)

    def loss_fn(p):
        logits, _, aux = m.apply(p, inp, prefix_emb=prefix)
        logits = logits[:, -S:]          # drop prefix positions
        if cfg.n_codebooks > 1:
            l = jnp.mean(jnp.stack([
                lm_loss(logits[..., c, :], tgt[..., c])
                for c in range(cfg.n_codebooks)]))
        else:
            l = lm_loss(logits, tgt)
        return l + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    # one SGD step reduces nothing catastrophic (finite update)
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g,
                                        params, grads)
    loss2 = loss_fn(new_params)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = _tokens(cfg, B, S, jax.random.PRNGKey(1))
    full_logits, _, _ = m.apply(params, toks)
    P = S - 3
    cache = m.init_cache(B, S)
    _, cache, _ = m.apply(params, toks[:, :P], caches=cache)
    for t in range(P, S):
        logits, cache = m.decode_step(params, cache, toks[:, t:t + 1], t)
        err = float(jnp.max(jnp.abs(logits - full_logits[:, t:t + 1])))
        assert err < 2e-2, (arch, t, err)


def test_sliding_window_masks_differently():
    cfg = get_config("gemma3-27b").reduced()
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = _tokens(cfg, 1, 64, jax.random.PRNGKey(2))
    l1, _, _ = m.apply(params, toks)
    # distant past must influence global layers only — changing token 0
    # must still change the last logits (global layer exists in pattern)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    l2, _, _ = m.apply(params, toks2)
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 0
