"""Hybrid local/distributed fused-operator execution.

Planning under a mesh layout must (a) select genuinely *hybrid* plans —
row-parallel operators distributed, small-operand partitions local — with
per-operator placement and collective volume reported by ``explain()``,
(b) execute to the same numbers as the all-local plan (the collective
epilogues are exact), and (c) really run the generated body under
``shard_map`` on a multi-device mesh (subprocess with forced host
devices).

The mlogreg hybrid explain() report is golden-pinned; regenerate after an
intentional cost-model/placement change:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_dist_exec.py
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FusionContext, fused, ir
from repro.core.templates import TType, dist_epilogue
from repro.dist.planner import LogicalMesh

DIST_GOLDEN = Path(__file__).parent / "golden" / "explain_mlogreg_dist.json"

rng = np.random.default_rng(7)


def arr(*shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _mlogreg_spec(m=10_000, n=100, k=5):
    return dict(X=np.zeros((m, n), np.float32),
                B=np.zeros((n, k), np.float32),
                Y=np.zeros((m, k), np.float32),
                lam=np.zeros((1, 1), np.float32))


def _placements(planned):
    return [(o["template"], o.get("placement"))
            for o in planned.explain()["winner"]["operators"]]


# --------------------------------------------------------------------------
# the distributed-variant registry
# --------------------------------------------------------------------------

def test_dist_variant_registry():
    """Row-partitioned variants need no collective; reduction-over-rows
    variants all-reduce with the op's collective; mean and unknown
    variants stay local."""
    assert dist_epilogue(TType.CELL, "no_agg", "") == "none"
    assert dist_epilogue(TType.ROW, "row_agg", "sum") == "none"
    assert dist_epilogue(TType.MAGG, "full_agg", "sum") == "psum"
    assert dist_epilogue(TType.ROW, "col_t_agg", "sum") == "psum"
    assert dist_epilogue(TType.OUTER, "left_mm", "sum") == "psum"
    assert dist_epilogue(TType.CELL, "full_agg", "min") == "pmin"
    assert dist_epilogue(TType.CELL, "full_agg", "max") == "pmax"
    assert dist_epilogue(TType.CELL, "full_agg", "mean") is None
    assert dist_epilogue(TType.MAGG, "no_agg", "sum") is None


# --------------------------------------------------------------------------
# hybrid plan selection (abstract ≥8-device mesh, no devices required)
# --------------------------------------------------------------------------

def test_mlogreg_selects_hybrid_plan():
    """On a 1×8 abstract mesh the regularized-NLL objective splits: the
    X-row-parallel softmax/NLL chain distributes (psum epilogue, nonzero
    collective volume), the B-space regularizer multi-aggregate stays
    local (100 rows don't divide 8 shards)."""
    from repro.algos import mlogreg
    planned = mlogreg._nll_obj_reg.trace(**_mlogreg_spec()).plan(
        mode="gen", layout=LogicalMesh({"data": 8}))
    report = planned.explain()
    ops = report["winner"]["operators"]
    arms = {o["placement"] for o in ops}
    assert arms == {"local", "distributed"}, ops
    dist_ops = [o for o in ops if o["placement"] == "distributed"]
    assert all(o["epilogue"] in ("none", "psum", "pmin", "pmax")
               for o in dist_ops)
    assert any(o["collective_bytes"] > 0 for o in dist_ops)
    assert report["distributed"]["devices"] == 8
    assert report["distributed"]["n_fused_distributed"] >= 1
    assert report["distributed"]["n_fused_local"] >= 1


def test_l2svm_objective_selects_hybrid_plan():
    from repro.algos import l2svm
    spec = dict(X=np.zeros((10_000, 100), np.float32),
                w=np.zeros((100, 1), np.float32),
                y=np.zeros((10_000, 1), np.float32),
                lam=np.zeros((1, 1), np.float32))
    planned = l2svm._objective_full.trace(**spec).plan(
        mode="gen", layout=LogicalMesh({"data": 8}))
    arms = {pl for _, pl in _placements(planned)}
    assert arms == {"local", "distributed"}


def test_square_main_keeps_matmul_operand_replicated():
    """Row-alignment is template-semantic, not shape-coincidental: with a
    square X (m == n), w in (X @ w).sum() has w.shape[0] == rows yet is
    the matmul's *right* operand (its rows are the contraction dim), so
    it must not be marked row-sharded — regression for the shard_map
    slice crash this coincidence caused on real meshes."""
    f = fused(lambda X, w: (X @ w).sum())
    spec = dict(X=np.zeros((64, 64), np.float32),
                w=np.zeros((64, 1), np.float32))
    planned = f.trace(**spec).plan(mode="gen",
                                   layout=LogicalMesh({"data": 8}))
    g = planned.eplan.graph
    w_nid = next(n.nid for n in g.inputs() if n.name == "w")
    for s in planned.eplan.fused_specs():
        pl = s.placement
        if pl is not None and pl.arm == "distributed":
            assert w_nid not in pl.sharded
    # and the compiled plan executes (locally here; the real-mesh
    # subprocess test covers shard_map)
    out = planned.compile()(jnp.ones((64, 64)), jnp.ones((64, 1)))
    np.testing.assert_allclose(float(out[0, 0]), 64.0 * 64.0)


def test_indivisible_rows_stay_local():
    """Rows that don't divide the shard group have no distributed
    variant — the whole plan is local and costs match the no-layout arm
    structure."""
    f = fused(lambda X, y: ir.relu(1.0 - y * X).sum())
    spec = dict(X=np.zeros((1000, 10), np.float32),   # 1000 % 16 != 0
                y=np.zeros((1000, 1), np.float32))
    planned = f.trace(**spec).plan(mode="gen",
                                   layout=LogicalMesh({"data": 16}))
    assert all(pl == "local" for _, pl in _placements(planned))


def test_placement_changes_with_mesh_width():
    """The placement decision is cost-based, not a flag: the same trace
    plans all-local on a 1-device mesh and hybrid on an 8-device mesh."""
    from repro.algos import mlogreg
    one = mlogreg._nll_obj_reg.trace(**_mlogreg_spec()).plan(
        mode="gen", layout=LogicalMesh({"data": 1}))
    eight = mlogreg._nll_obj_reg.trace(**_mlogreg_spec()).plan(
        mode="gen", layout=LogicalMesh({"data": 8}))
    assert all(pl is None or pl == "local" for _, pl in _placements(one))
    assert any(pl == "distributed" for _, pl in _placements(eight))
    assert eight.cost < one.cost          # modeled mesh-wide speedup


# --------------------------------------------------------------------------
# numeric parity: hybrid plan == all-local plan (1e-5)
# --------------------------------------------------------------------------

def test_hybrid_parity_l2svm():
    X = arr(512, 20)
    y = jnp.asarray(np.sign(rng.normal(size=(512, 1))), jnp.float32)
    from repro.algos import l2svm
    w_local, obj_local = l2svm.run(X, y, max_iter=4)
    w_dist, obj_dist = l2svm.run(X, y, max_iter=4,
                                 layout=LogicalMesh({"data": 8}))
    np.testing.assert_allclose(np.asarray(w_dist), np.asarray(w_local),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(obj_dist, obj_local, rtol=1e-5)


def test_hybrid_parity_mlogreg():
    m, n, k = 400, 12, 4
    X = arr(m, n)
    lab = rng.integers(0, k, size=m)
    Y = jnp.asarray(np.eye(k, dtype=np.float32)[lab])
    from repro.algos import mlogreg
    B_local, nll_local = mlogreg.run(X, Y, max_outer=3, max_inner=5)
    B_dist, nll_dist = mlogreg.run(X, Y, max_outer=3, max_inner=5,
                                   layout=LogicalMesh({"data": 8}))
    np.testing.assert_allclose(np.asarray(B_dist), np.asarray(B_local),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(nll_dist, nll_local, rtol=1e-5)


def test_hybrid_grad_parity():
    """jax.grad through a hybrid plan (planned backward runs under the
    same layout) matches the local gradient."""
    from repro.algos import mlogreg
    m, n, k = 400, 12, 4
    X, B = arr(m, n), arr(n, k) * 0.1
    lab = rng.integers(0, k, size=m)
    Y = jnp.asarray(np.eye(k, dtype=np.float32)[lab])
    lam = jnp.full((1, 1), 1e-3, jnp.float32)

    def obj(B_):
        return mlogreg._nll_obj_reg(X, B_, Y, lam)[0, 0]

    g_local = jax.grad(obj)(B)
    with FusionContext(mode="gen", layout=LogicalMesh({"data": 8})):
        g_dist = jax.grad(obj)(B)
    np.testing.assert_allclose(np.asarray(g_dist), np.asarray(g_local),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# golden pin: the hybrid mlogreg explain() report
# --------------------------------------------------------------------------

def test_explain_golden_mlogreg_dist():
    from repro.algos import mlogreg
    report = mlogreg._nll_obj_reg.trace(**_mlogreg_spec()).plan(
        mode="gen", layout=LogicalMesh({"data": 8})).explain()
    report["winner"]["cost"] = round(report["winner"]["cost"], 12)
    for c in report["candidates"]:
        c["cost"] = round(c["cost"], 12)
    if os.environ.get("REGEN_GOLDEN"):
        DIST_GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        DIST_GOLDEN.write_text(json.dumps(report, indent=1, sort_keys=True))
        pytest.skip(f"regenerated {DIST_GOLDEN}")
    assert DIST_GOLDEN.exists(), \
        "golden missing — run with REGEN_GOLDEN=1 to create it"
    expected = json.loads(DIST_GOLDEN.read_text())
    assert json.loads(json.dumps(report, sort_keys=True)) == expected
    # the pin itself must witness a hybrid plan
    arms = [o["placement"] for o in expected["winner"]["operators"]]
    assert "distributed" in arms and "local" in arms


# --------------------------------------------------------------------------
# real-mesh execution: shard_map over forced host devices
# --------------------------------------------------------------------------

_REAL_MESH_PROG = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import fused, ir

assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(3)
f = fused(lambda X, w, y, lam: (0.5 * (ir.relu(1.0 - y * (X @ w)) ** 2).sum()
                                + 0.5 * lam * (w ** 2).sum()))
X = jnp.asarray(rng.normal(size=(1024, 36)), jnp.float32)
w = jnp.asarray(rng.normal(size=(36, 1)), jnp.float32)
y = jnp.asarray(np.sign(rng.normal(size=(1024, 1))), jnp.float32)
lam = jnp.full((1, 1), 1e-3, jnp.float32)
tr = f.trace(X, w, y, lam)
local = tr.plan(mode="gen").compile()(X, w, y, lam)
planned = tr.plan(mode="gen", layout=mesh)
arms = [o["placement"] for o in planned.explain()["winner"]["operators"]]
assert "distributed" in arms, arms
dist = planned.compile()(X, w, y, lam)
np.testing.assert_allclose(np.asarray(local), np.asarray(dist), rtol=1e-5)
print("OK")
"""


def test_real_mesh_shard_map_parity():
    """End to end on a *real* 8-device mesh (forced host platform
    devices, fresh process): the plan selects a distributed operator and
    the shard_map execution with its psum epilogue matches the local
    result."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    res = subprocess.run([sys.executable, "-c", _REAL_MESH_PROG],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


# --------------------------------------------------------------------------
# shard-spanning segments: adjacent distributed operators fuse into ONE
# shard_map region (whole-plan staged execution)
# --------------------------------------------------------------------------

_SEGMENT_PROG = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import fused, ir

assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))

# A wide shared cell chain: materializing A (6 reads -> 1 write) beats
# recomputing it inside all three consumers, so selection materializes A
# as a distributed row-partitioned operator and the consumers chain off
# it — a 3-operator distributed run plus a local w-space aggregate.
def expr(X1, X2, X3, X4, X5, X6, w):
    A = ir.sigmoid(X1 + X2 + X3 + X4 + X5 + X6)
    return ((A * X1 + X2).sum(), (A - X3).rowsums(),
            (A * A + X4).sum(), (w ** 2).sum())

f = fused(expr)
m, n = 4096, 64
rng = np.random.default_rng(11)
Xs = [jnp.asarray(rng.normal(size=(m, n)), jnp.float32) for _ in range(6)]
w = jnp.asarray(rng.normal(size=(10, 1)), jnp.float32)

tr = f.trace(*Xs, w)
planned = tr.plan(mode="gen", layout=mesh)
rep = planned.explain()

# hybrid: >= 2 adjacent distributed operators + a local one
arms = [o["placement"] for o in rep["winner"]["operators"]]
assert "local" in arms, arms
segs = rep["distributed"]["segments"]
assert len(segs) == 1, segs
seg = segs[0]
assert seg["n_operators"] >= 2, seg
assert seg["n_sharded_edges"] >= 1, seg
assert seg["removed_collective_bytes"] > 0, seg
assert rep["distributed"]["removed_collective_bytes"] \
    == seg["removed_collective_bytes"]

# the segment executes inside a SINGLE shard_map region: inspect the
# staged whole-plan jaxpr
compiled = planned.compile()
outs = compiled(*Xs, w)
_fn, raw = compiled._cplan.staged_callable()
jaxpr = str(jax.make_jaxpr(raw)(*Xs, w))
n_regions = jaxpr.count("shard_map")
assert n_regions == 1, f"expected one shard_map region, found {n_regions}"

# numeric parity with the all-local plan
local = tr.plan(mode="gen").compile()(*Xs, w)
for a, b in zip(outs, local):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
print("OK", seg["n_operators"], seg["removed_collective_bytes"])
"""


def test_segment_single_shard_map_region():
    """A hybrid plan with ≥2 adjacent distributed operators executes them
    inside one ``shard_map`` region (jaxpr inspection), with ``explain()``
    reporting the segment and the removed intra-segment collective
    bytes — and the same numbers as the all-local plan."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    res = subprocess.run([sys.executable, "-c", _SEGMENT_PROG],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


def test_segment_annotation_abstract_mesh():
    """Segment annotation is a plan property, not a runtime one: the same
    expression planned on an abstract 1×8 mesh reports the segment (and
    its removed boundary volume) from a CPU container with no devices."""
    def expr(X1, X2, X3, X4, X5, X6, w):
        A = ir.sigmoid(X1 + X2 + X3 + X4 + X5 + X6)
        return ((A * X1 + X2).sum(), (A - X3).rowsums(),
                (A * A + X4).sum(), (w ** 2).sum())

    f = fused(expr)
    shapes = [np.zeros((4096, 64), np.float32) for _ in range(6)]
    w = np.zeros((10, 1), np.float32)
    planned = f.trace(*shapes, w).plan(mode="gen",
                                       layout=LogicalMesh({"data": 8}))
    segs = planned.eplan.segments
    assert len(segs) == 1
    assert len(segs[0].indices) >= 2
    assert segs[0].removed_gather_bytes > 0
    assert segs[0].sharded_edges      # a materialized A flows shard-to-shard
    # indices are adjacent spec positions
    ix = segs[0].indices
    assert list(ix) == list(range(ix[0], ix[-1] + 1))
