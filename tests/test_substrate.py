"""Substrate tests: data determinism, checkpoint atomicity + elastic
restore, fault-tolerant loop behavior, gradient compression, optimizer."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, ShardedLoader, TokenSource
from repro.optim import adamw
from repro.optim.compression import (CompressionConfig,
                                     compress_decompress)
from repro.train import LoopConfig, run_loop


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_restart_exact():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=7)
    a = ShardedLoader(cfg, 0, 1)
    b1, b2 = next(a), next(a)
    a.close()
    # restarting at step 1 reproduces batch 2 exactly
    c = ShardedLoader(cfg, 0, 1, start_step=1)
    c2 = next(c)
    c.close()
    np.testing.assert_array_equal(b2["tokens"], c2["tokens"])


def test_data_host_sharding_partitions_batch():
    cfg = DataConfig(seq_len=8, global_batch=8, vocab=50, seed=3)
    full = ShardedLoader(cfg, 0, 1)
    fb = next(full)
    full.close()
    parts = []
    for h in range(4):
        l = ShardedLoader(cfg, h, 4)
        parts.append(next(l)["tokens"])
        l.close()
    np.testing.assert_array_equal(np.concatenate(parts), fb["tokens"])


def test_targets_shifted():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50)
    src = TokenSource(cfg)
    l = ShardedLoader(cfg, 0, 1)
    b = next(l)
    l.close()
    ex = src.example(0, 0)
    np.testing.assert_array_equal(b["tokens"][0], ex[:-1])
    np.testing.assert_array_equal(b["targets"][0], ex[1:])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "b": {"x": jnp.arange(5.0)}}


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    t = _tree()
    store.save(10, t, extra={"step": 10}, blocking=True)
    like = jax.tree_util.tree_map(jnp.zeros_like, t)
    restored, extra = store.restore(like)
    assert extra["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(s))
    store.wait()
    assert store.steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(5, _tree(), blocking=True)
    # a stale temp dir from a "crashed" save must not be visible
    (tmp_path / ".tmp_step_6").mkdir()
    assert store.latest_step() == 5


def test_elastic_restore_new_sharding(tmp_path):
    """Restore re-shards onto a different mesh (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    store = CheckpointStore(tmp_path)
    t = {"w": jnp.arange(16.0).reshape(16, 1)}
    store.save(1, t, blocking=True)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    shd = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = store.restore(jax.tree_util.tree_map(jnp.zeros_like, t),
                                shardings=shd)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == shd["w"]


# ---------------------------------------------------------------------------
# training loop: restart + straggler + nan-skip
# ---------------------------------------------------------------------------

def _toy_step():
    def train_step(params, opt_state, batch):
        x = batch["tokens"].astype(jnp.float32)
        grad = jnp.mean(x) * jnp.ones_like(params["w"])
        params = {"w": params["w"] - 0.1 * grad}
        loss = jnp.mean((params["w"]) ** 2)
        return params, opt_state, {"loss": loss}
    return train_step


def test_loop_checkpoint_restart(tmp_path):
    cfg = DataConfig(seq_len=4, global_batch=2, vocab=10, seed=1)
    store = CheckpointStore(tmp_path)
    params = {"w": jnp.ones((3,))}
    loader = ShardedLoader(cfg, 0, 1)
    p1, _, st = run_loop(_toy_step(), params, {}, loader,
                         LoopConfig(total_steps=6, checkpoint_every=3),
                         store=store)
    loader.close()
    assert store.latest_step() == 6
    # resume from step 3 and retrain 3 steps deterministically
    p_like = jax.tree_util.tree_map(jnp.zeros_like, params)
    tree, extra = store.restore({"params": p_like, "opt": {}}, step=3)
    loader2 = ShardedLoader(cfg, 0, 1, start_step=extra["step"])
    p2, _, _ = run_loop(_toy_step(), tree["params"], {}, loader2,
                        LoopConfig(total_steps=6, checkpoint_every=100),
                        start_step=extra["step"])
    loader2.close()
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-6)


def test_loop_straggler_detection():
    cfg = DataConfig(seq_len=4, global_batch=2, vocab=10)
    loader = ShardedLoader(cfg, 0, 1)
    calls = {"n": 0}

    def slow_step(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 5:
            time.sleep(0.25)            # injected straggler
        else:
            time.sleep(0.01)
        return params, opt_state, {"loss": jnp.asarray(0.0)}

    _, _, st = run_loop(slow_step, {}, {}, loader,
                        LoopConfig(total_steps=8, checkpoint_every=100,
                                   straggler_factor=3.0))
    loader.close()
    assert any(step == 4 for step, _, _ in st.straggler_events)


def test_loop_skips_nonfinite():
    cfg = DataConfig(seq_len=4, global_batch=2, vocab=10)
    loader = ShardedLoader(cfg, 0, 1)
    calls = {"n": 0}

    def nan_step(params, opt_state, batch):
        calls["n"] += 1
        loss = jnp.asarray(np.nan if calls["n"] == 2 else 1.0)
        return {"w": params["w"] + 1}, opt_state, {"loss": loss}

    params = {"w": jnp.zeros(())}
    p, _, st = run_loop(nan_step, params, {}, loader,
                        LoopConfig(total_steps=4, checkpoint_every=100))
    loader.close()
    assert st.skipped_steps == [1]
    assert float(p["w"]) == 3.0          # one update dropped


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.ones((4,)) * 5.0}
    cfg = adamw.OptConfig(lr=0.5, warmup_steps=0, decay_steps=100,
                          weight_decay=0.0)
    state = adamw.init(params, cfg)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    cfg = CompressionConfig(kind="int8")
    res = jnp.zeros_like(g_true)
    acc_sent = jnp.zeros_like(g_true)
    for _ in range(50):
        dec, res = compress_decompress(g_true, res, cfg)
        acc_sent = acc_sent + dec
    # error feedback: long-run average of transmitted ≈ true gradient
    np.testing.assert_allclose(np.asarray(acc_sent / 50),
                               np.asarray(g_true), atol=0.02)


def test_topk_compression_sparsity():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(100,)),
                    jnp.float32)
    cfg = CompressionConfig(kind="topk", topk_frac=0.1)
    dec, res = compress_decompress(g, jnp.zeros_like(g), cfg)
    assert int(jnp.sum(dec != 0)) <= 12
    np.testing.assert_allclose(np.asarray(dec + res), np.asarray(g),
                               rtol=1e-6)
