"""OFMC candidate exploration (Algorithm 1) invariants on paper examples."""

from repro.core import ir
from repro.core.explore import ExploreStats, explore
from repro.core.templates import Status, TType


def _mlogreg_graph():
    X = ir.matrix("X", (10000, 100))
    v = ir.matrix("v", (100, 4))
    P = ir.matrix("P", (10000, 5))
    Pk = P.cols(0, 4)
    Q = Pk * (X @ v)
    H = X.T @ (Q - Pk * Q.rowsums())
    return ir.Graph.build([H])


def _als_graph(sp=0.01):
    X = ir.matrix("X", (20000, 20000), sparsity=sp)
    U = ir.matrix("U", (20000, 100))
    V = ir.matrix("V", (20000, 100))
    r = ir.matrix("r", (20000, 1))
    O = (ir.neq0(X) * (U @ V.T)) @ V + 1e-6 * U * r
    return ir.Graph.build([O])


def test_every_operator_visited_once():
    g = _mlogreg_graph()
    st = ExploreStats()
    explore(g, stats=st)
    n_ops = sum(1 for n in g.nodes if not n.is_input)
    assert st.operators == n_ops


def test_entry_bound_linear():
    """Paper: ≤ 32n entries (2^3 inputs × 4 templates)."""
    g = _als_graph()
    st = ExploreStats()
    memo = explore(g, stats=st)
    assert memo.n_entries() <= 32 * len(g)


def test_mlogreg_memo_structure():
    """Figure 5: the final ba(+*) carries open Row plans; rowSums has Row
    entries and no single-op closed Cell entry."""
    g = _mlogreg_graph()
    memo = explore(g)
    rowsums = next(n for n in g.nodes if n.is_agg and n.agg_axis == "row")
    types = memo.distinct_types(rowsums.nid)
    assert TType.ROW in types
    for e in memo.entries(rowsums.nid):
        assert not (e.status == Status.CLOSED_VALID and e.n_refs == 0)
    final = g.outputs[0]
    entries = memo.entries(final.nid)
    assert entries and all(e.ttype == TType.ROW for e in entries)
    assert any(e.status == Status.CLOSED_VALID for e in entries)


def test_als_outer_entries():
    """The sparsity-exploiting Outer plan must exist and close valid at the
    right_mm; the outer matmul itself is an invalid entry point."""
    g = _als_graph()
    memo = explore(g)
    mm_outer = next(n for n in g.nodes if n.is_matmul and n.tb)
    assert all(e.status == Status.OPEN_INVALID
               for e in memo.entries(mm_outer.nid))
    rmm = next(n for n in g.nodes
               if n.is_matmul and not n.tb and not n.ta)
    outer = [e for e in memo.entries(rmm.nid) if e.ttype == TType.OUTER]
    assert outer and outer[0].status == Status.CLOSED_VALID


def test_outer_requires_sparse_driver():
    """sum(U@V.T) has no sparse-safe driver → no valid Outer plan."""
    U = ir.matrix("U", (2000, 10))
    V = ir.matrix("V", (2000, 10))
    g = ir.Graph.build([(U @ V.T).sum()])
    memo = explore(g)
    agg = g.outputs[0]
    assert all(e.ttype != TType.OUTER for e in memo.entries(agg.nid))


def test_multi_agg_entries():
    X = ir.matrix("X", (500, 500))
    Y = ir.matrix("Y", (500, 500))
    g = ir.Graph.build([(X * Y).sum(), (X ** 2).sum()])
    memo = explore(g)
    for out in g.outputs:
        assert TType.MAGG in memo.distinct_types(out.nid)


def test_dominance_pruning_only_for_heuristics():
    g = _mlogreg_graph()
    base = explore(g, prune_dominated=False).n_entries()
    pruned = explore(g, prune_dominated=True).n_entries()
    assert pruned <= base
