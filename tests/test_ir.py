"""IR construction: shapes, broadcasting, CSE, sparsity propagation."""

import pytest

from repro.core import ir


def test_shapes_and_broadcast():
    X = ir.matrix("X", (100, 10))
    v = ir.matrix("v", (100, 1))
    r = ir.matrix("r", (1, 10))
    assert (X * v).shape == (100, 10)
    assert (X + r).shape == (100, 10)
    assert (X * 2.0).shape == (100, 10)
    assert X.rowsums().shape == (100, 1)
    assert X.colsums().shape == (1, 10)
    assert X.sum().shape == (1, 1)
    assert (X.T).shape == (10, 100)
    with pytest.raises(ValueError):
        _ = X + ir.matrix("Y", (50, 10))


def test_matmul_transpose_folding():
    X = ir.matrix("X", (100, 10))
    y = ir.matrix("y", (100, 1))
    n = (X.T @ y).node
    assert n.op == "matmul" and n.ta and not n.tb
    assert n.shape == (10, 1)
    U = ir.matrix("U", (50, 8))
    V = ir.matrix("V", (60, 8))
    o = (U @ V.T).node
    assert o.tb and o.shape == (50, 60)
    assert o.mm_dims() == (50, 8, 60)


def test_double_transpose_cancels():
    X = ir.matrix("X", (3, 4))
    assert X.T.T.node is X.node


def test_cse_dedup():
    X = ir.matrix("X", (10, 10))
    Y = ir.matrix("Y", (10, 10))
    a = (X * Y).sum()
    b = (X * Y).sum()
    g = ir.Graph.build([a, b])
    muls = [n for n in g.nodes if n.op == "mul"]
    sums = [n for n in g.nodes if n.op == "sum"]
    assert len(muls) == 1 and len(sums) == 1
    assert len(g.outputs) == 2 and g.outputs[0] is g.outputs[1]


def test_sparsity_propagation():
    X = ir.matrix("X", (100, 100), sparsity=0.1)
    Y = ir.matrix("Y", (100, 100), sparsity=0.2)
    assert (X * Y).node.sparsity == pytest.approx(0.1)
    assert (X + Y).node.sparsity == pytest.approx(0.3)
    assert ir.exp(X).node.sparsity == 1.0       # exp(0) != 0
    assert ir.abs_(X).node.sparsity == pytest.approx(0.1)
    assert (X ** 2).node.op == "pow2"


def test_sparse_safety():
    X = ir.matrix("X", (200, 200), sparsity=0.05)
    U = ir.matrix("U", (200, 8))
    V = ir.matrix("V", (200, 8))
    chain = ir.neq0(X) * (U @ V.T)
    assert ir.sparse_safe_wrt(chain.node, X.node)
    assert not ir.sparse_safe_wrt(chain.node, U.node)
    plus = chain + 1.0
    assert not ir.sparse_safe_wrt(plus.node, X.node)
    # div by side is safe for the numerator's driver
    d = chain / ir.exp(U @ V.T)
    assert ir.sparse_safe_wrt(d.node, X.node)


def test_consumer_counts():
    X = ir.matrix("X", (10, 10))
    m = X * 2.0
    a, b = m.rowsums(), m.colsums()
    g = ir.Graph.build([a, b])
    mul = next(n for n in g.nodes if n.op == "mul")
    assert g.n_consumers(mul.nid) == 2
    assert mul.nid in g.multi_consumer_ids()
