"""MPSkipEnum (Algorithm 2): optimality vs brute force + pruning stats.

Property-based: random DAGs with shared intermediates; the pruned, cut-set-
decomposed enumeration must return exactly the brute-force optimal cost.
"""

import itertools
import math

import pytest

from repro.core import ir
from repro.core.cost import TPU_V5E, partition_cost
from repro.core.enumerate import EnumStats, find_cut_sets, mp_skip_enum
from repro.core.explore import explore
from repro.core.partitions import build_partitions

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402


def brute_force(graph, memo, part, params=TPU_V5E):
    n = len(part.points)
    best = math.inf
    for bits in itertools.product([False, True], repeat=n):
        banned = {p for p, b in zip(part.points, bits) if b}
        best = min(best, partition_cost(graph, memo, part, banned, params))
    return best


def _check_graph(g):
    memo = explore(g)
    for part in build_partitions(g, memo):
        if len(part.points) > 10:
            continue
        st_ = EnumStats()
        q, c = mp_skip_enum(g, memo, part, TPU_V5E, stats=st_)
        bf = brute_force(g, memo, part)
        assert c == pytest.approx(bf, rel=1e-9), (c, bf, part.points)
        # sanity: pruning + cut-set recursion stays near the full space
        assert st_.plans_costed <= 2 * 2 ** len(part.points)


def test_mlogreg_optimal():
    X = ir.matrix("X", (10000, 100))
    v = ir.matrix("v", (100, 4))
    P = ir.matrix("P", (10000, 5))
    Pk = P.cols(0, 4)
    Q = Pk * (X @ v)
    H = X.T @ (Q - Pk * Q.rowsums())
    _check_graph(ir.Graph.build([H]))


def test_als_optimal():
    X = ir.matrix("X", (20000, 20000), sparsity=0.01)
    U = ir.matrix("U", (20000, 100))
    V = ir.matrix("V", (20000, 100))
    r = ir.matrix("r", (20000, 1))
    O = (ir.neq0(X) * (U @ V.T)) @ V + 1e-6 * U * r
    _check_graph(ir.Graph.build([O]))


# ---------------------------------------------------------------------------
# hypothesis: random DAGs
# ---------------------------------------------------------------------------

_UNARIES = ["exp", "abs", "relu", "pow2", "sqrt"]
_BINS = ["add", "mul", "sub", "max"]


@st.composite
def random_graph(draw):
    m = draw(st.sampled_from([500, 2000, 10000]))
    n = draw(st.sampled_from([10, 100, 1000]))
    sp = draw(st.sampled_from([1.0, 1.0, 0.1, 0.01]))
    inputs = [ir.matrix(f"I{i}", (m, n), sparsity=sp if i == 0 else 1.0)
              for i in range(draw(st.integers(2, 3)))]
    pool = list(inputs)
    for _ in range(draw(st.integers(2, 7))):
        k = draw(st.integers(0, 1))
        if k == 0:
            a = draw(st.sampled_from(pool))
            pool.append(a.unary(draw(st.sampled_from(_UNARIES))))
        else:
            a, b = draw(st.sampled_from(pool)), draw(st.sampled_from(pool))
            pool.append(a._bin(b, draw(st.sampled_from(_BINS))))
    outs = []
    n_out = draw(st.integers(1, 3))
    for _ in range(n_out):
        x = draw(st.sampled_from(pool[-4:]))
        agg = draw(st.sampled_from(["sum", "rowsums", "colsums", "none"]))
        outs.append({"sum": x.sum(), "rowsums": x.rowsums(),
                     "colsums": x.colsums(), "none": x}[agg])
    return ir.Graph.build(outs)


@settings(max_examples=25, deadline=None)
@given(random_graph())
def test_random_dags_optimal(g):
    _check_graph(g)


def test_cut_sets_valid():
    # diamond with a clear cut: m consumed by two chains that re-join
    X = ir.matrix("X", (5000, 100))
    m = ir.exp(X)
    a = (m * 2.0 + 1.0)
    b = (m - 3.0)
    out = (a * b).sum()
    g = ir.Graph.build([out])
    memo = explore(g)
    (part,) = build_partitions(g, memo)
    cuts = find_cut_sets(g, part, part.points)
    for c in cuts:
        assert not (set(c.s1_ix) & set(c.s2_ix))
        assert set(c.points_ix + c.s1_ix + c.s2_ix) == set(
            range(len(part.points)))


def test_pruning_reduces_costed_plans():
    """Fig. 12: cost-based pruning cuts evaluated plans by large factors."""
    X = ir.matrix("X", (100000, 100))
    m = ir.exp(X)
    outs = []
    cur = m
    for i in range(5):
        cur = cur * float(i + 2)
        outs.append(cur.sum())
    g = ir.Graph.build(outs)
    memo = explore(g)
    parts = build_partitions(g, memo)
    st_p = EnumStats()
    for part in parts:
        mp_skip_enum(g, memo, part, TPU_V5E, stats=st_p)
    st_np = EnumStats()
    for part in parts:
        mp_skip_enum(g, memo, part, TPU_V5E, use_cost_pruning=False,
                     use_structural=False, stats=st_np)
    assert st_p.plans_costed <= st_np.plans_costed
