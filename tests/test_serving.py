"""FusionServer: async continuous batching over compiled fused plans.

Covers the serving request path (submit → Future → host-NumPy result
parity with direct region calls), shape-bucketed batching with row
padding, the pad-safety analysis (both as a unit and end-to-end via the
exact-shape fallback), warming + the fusionlint hook, typed admission
errors, and the metrics snapshot/report surface.

Batched execution runs jit(vmap(plan_fn)) while the direct call runs
jit(plan_fn): float32 reduction order may differ, so parity checks use
rtol=1e-5 *and* atol=1e-5 (never pure atol).  Servers are always closed
in ``finally`` — daemon workers executing XLA during interpreter
shutdown can crash the process.
"""

import numpy as np
import pytest

from repro.core import fused, ir
from repro.serve import (FusionServer, FusionServeError, PadReport,
                         ServerClosedError, pad_safety)

rng = np.random.default_rng(11)


def _hinge():
    return fused(lambda X, w, y: ir.relu(1.0 - y * (X @ w)))


def _hinge_args(m, k=16):
    X = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, 1)).astype(np.float32)
    y = np.sign(rng.normal(size=(m, 1))).astype(np.float32)
    return X, w, y


def _probs():
    def probs(X, W):
        E = ir.exp(X @ W)
        return E / E.rowsums()
    return fused(probs)


def _close(server):
    server.close()


def _parity(got, ref):
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# request path: submit → Future → parity with direct execution
# --------------------------------------------------------------------------

def test_submit_matches_direct_call():
    region = _hinge()
    X, w, y = _hinge_args(50)
    server = FusionServer(workers=1, max_batch=4, pad_to=32)
    try:
        got = server.submit(region, X, w, y).result(timeout=300)
        ref = region(X, w, y)
        assert isinstance(got, np.ndarray)      # host result, documented
        assert got.shape == (50, 1)             # all-2-D call stays 2-D
        _parity(got, ref)
    finally:
        _close(server)


def test_vector_world_round_trip():
    """1-D operands put the call in vector world: the served result must
    round-trip (n, 1) → (n,) exactly like a direct region call."""
    region = _hinge()
    X, w, y = _hinge_args(40)
    y1 = y.reshape(-1)
    server = FusionServer(workers=1, max_batch=4, pad_to=32)
    try:
        got = server.submit(region, X, w, y1).result(timeout=300)
        ref = region(X, w, y1)
        assert got.shape == (40,) == ref.shape
        _parity(got, ref)
    finally:
        _close(server)


def test_batching_and_padding_mixed_sizes():
    """Requests with different row counts inside one padded shape class
    execute as ONE batched dispatch, each sliced back to its true rows.
    Enqueue before starting the worker so the batch is deterministic."""
    region = _hinge()
    ms = (20, 25, 31, 32)               # all land in the 32-row class
    cases = [(_hinge_args(m)) for m in ms]
    server = FusionServer(workers=1, max_batch=8, pad_to=32,
                          autostart=False)
    server._started = True              # admit without draining
    try:
        futs = [server.submit(region, *args) for args in cases]
        server._started = False
        server.start()                  # drain: one bucket, one batch
        results = [f.result(timeout=300) for f in futs]
        for m, args, got in zip(ms, cases, results):
            assert got.shape == (m, 1)
            _parity(got, region(*args))
        snap = server.metrics.snapshot()
        assert snap["batches"]["count"] == 1
        assert snap["batches"]["occupancy_max"] == 4
        assert snap["batches"]["batched_requests"] == 4
        assert snap["batches"]["padded_requests"] == 3   # 32 was exact
        assert snap["requests"]["completed"] == 4
        assert snap["compiles"]["count"] == 1            # one shared entry
    finally:
        _close(server)


def test_three_buckets_interleaved():
    """Two regions at mixed sizes → ≥3 distinct batch buckets served
    concurrently, every result exact against direct execution."""
    hinge, probs = _hinge(), _probs()
    W = rng.normal(size=(16, 5)).astype(np.float32)
    cases = []
    for m in (20, 40, 20, 33, 40, 21):
        cases.append((hinge, _hinge_args(m)))
        Xp = rng.normal(size=(m, 16)).astype(np.float32)
        cases.append((probs, (Xp, W)))
    server = FusionServer(workers=2, max_batch=4, pad_to=32)
    try:
        futs = [server.submit(r, *args) for r, args in cases]
        for (r, args), f in zip(cases, futs):
            _parity(f.result(timeout=300), r(*args))
        snap = server.metrics.snapshot()
        assert len(snap["buckets"]) >= 3
        assert snap["requests"]["completed"] == len(cases)
        assert snap["requests"]["failed"] == 0
    finally:
        _close(server)


# --------------------------------------------------------------------------
# pad safety
# --------------------------------------------------------------------------

def _graph_of(region, *shaped):
    import jax
    import jax.numpy as jnp
    return region.trace(*[jax.ShapeDtypeStruct(s, jnp.float32)
                          for s in shaped]).graph


def test_pad_safety_analysis_unit():
    # hinge: padded rows are garbage but confined → safe, sliced on axis 0
    g = _graph_of(_hinge(), (64, 16), (16, 1), (64, 1))
    rep = pad_safety(g, frozenset({"X", "y"}))
    assert rep.safe and rep.out_axes == (0,)

    # sum of squares: padded rows stay exactly zero → the full reduction
    # is exact, and the scalar output never sees the padded dim
    g = _graph_of(fused(lambda X: (X * X).sum()), (64, 8))
    rep = pad_safety(g, frozenset({"X"}))
    assert rep.safe and rep.out_axes == (None,)

    # +1 turns padded zeros into finite garbage; summing it is wrong
    g = _graph_of(fused(lambda X: (X + 1.0).sum()), (64, 8))
    rep = pad_safety(g, frozenset({"X"}))
    assert not rep.safe and "sum" in rep.reason

    # exp(0) = 1: same story through a unary
    g = _graph_of(fused(lambda X: ir.exp(X).colsums()), (64, 8))
    assert not pad_safety(g, frozenset({"X"})).safe

    # mean over the padded dimension is never exact (divides by the
    # padded count) even though the padded rows are zero
    g = _graph_of(fused(lambda X: X.mean()), (64, 8))
    assert not pad_safety(g, frozenset({"X"})).safe

    # row-local aggregate: reduction is over the *un*padded axis → safe
    g = _graph_of(fused(lambda X: ir.relu(X).rowsums()), (64, 8))
    rep = pad_safety(g, frozenset({"X"}))
    assert rep.safe and rep.out_axes == (0,)

    assert isinstance(rep, PadReport)


def test_pad_unsafe_region_falls_back_to_exact_buckets():
    """A full reduction of non-zero-preserving data must NOT be padded;
    the server degrades the class to exact-shape bucketing (identical
    shapes still batch) and counts the fallback."""
    region = fused(lambda X: (X + 1.0).sum())
    X1 = rng.normal(size=(20, 8)).astype(np.float32)
    X2 = rng.normal(size=(20, 8)).astype(np.float32)   # exact twin
    X3 = rng.normal(size=(24, 8)).astype(np.float32)   # separate entry
    server = FusionServer(workers=1, max_batch=4, pad_to=32,
                          autostart=False)
    server._started = True
    try:
        futs = [server.submit(region, X) for X in (X1, X2, X3)]
        server._started = False
        server.start()
        for X, f in zip((X1, X2, X3), futs):
            got = f.result(timeout=300)
            assert got.shape == (1, 1)
            _parity(got, (X.astype(np.float64) + 1.0).sum())
        snap = server.metrics.snapshot()
        assert snap["batches"]["pad_fallbacks"] == 2    # one per entry
        assert snap["batches"]["padded_requests"] == 0
        assert snap["batches"]["occupancy_max"] == 2    # the exact twins
        assert snap["compiles"]["count"] == 2           # 20-row + 24-row
    finally:
        _close(server)


# --------------------------------------------------------------------------
# warming, lifecycle, admission errors
# --------------------------------------------------------------------------

def test_warm_and_warmed_plans():
    """A warm-only server (workers=0) compiles entries ahead of traffic
    and exposes their Planned stages for fusionlint --serving."""
    region = _hinge()
    server = FusionServer(workers=0, max_batch=4, pad_to=32,
                          autostart=False)
    X, w, y = _hinge_args(30)
    report = server.warm([(region, {"X": X, "w": w, "y": y})],
                         execute=True, batch_sizes=(1, 4))
    assert len(report["entries"]) == 1
    ent = report["entries"][0]
    assert ent["batchable"] and ent["pad_safe"] and ent["digest"]
    assert report["whole_plan_cache"]["capacity"] > 0
    plans = server.warmed_plans()
    assert len(plans) == 1
    label, planned = plans[0]
    assert "[" in label and "x" in label    # "<fn>[RxC/...]" shape label
    assert planned.eplan is not None        # verifiable by fusionlint
    # workers=0: admission is rejected with the typed closed error
    with pytest.raises(ServerClosedError):
        server.submit(region, X, w, y)


def test_submit_typed_errors():
    region = _hinge()
    X, w, y = _hinge_args(20)
    server = FusionServer(workers=1, max_batch=2, pad_to=32)
    try:
        with pytest.raises(FusionServeError):
            server.submit(object(), X)             # not a fused region
        with pytest.raises(FusionServeError) as ei:
            server.submit(region, X, w)            # missing operand
        assert "missing" in str(ei.value)
        with pytest.raises(FusionServeError):
            server.submit(region, X=X, w=w, z=y)   # unknown name
        with pytest.raises(FusionServeError):
            server.submit(region, X, w, "nope")    # not an array
        with pytest.raises(FusionServeError):
            server.submit(region, X[None], w, y)   # 3-D operand
        assert server.metrics.snapshot()["requests"]["rejected"] == 5
        assert server.metrics.snapshot()["requests"]["submitted"] == 0
    finally:
        _close(server)
    with pytest.raises(ServerClosedError):
        server.submit(region, X, w, y)             # closed server


def test_metrics_snapshot_and_report_shape():
    region = _hinge()
    server = FusionServer(workers=1, max_batch=4, pad_to=32)
    try:
        args = _hinge_args(25)
        server.submit(region, *args).result(timeout=300)
        snap = server.metrics.snapshot()
        for key in ("requests", "latency_us", "batches", "queue",
                    "compiles", "buckets", "cache"):
            assert key in snap, key
        assert snap["latency_us"]["count"] == 1
        assert snap["latency_us"]["p99"] >= snap["latency_us"]["p50"] > 0
        for cache in ("plan", "whole_plan"):
            st = snap["cache"][cache]
            for field in ("hits", "misses", "evictions", "capacity"):
                assert field in st, (cache, field)
        doc = server.metrics.report(server)
        assert doc["server"]["max_batch"] == 4
        assert doc["server"]["entries"] == 1
        assert isinstance(doc["serving"]["cache"]["whole_plan_keys"], list)
    finally:
        _close(server)


def test_context_manager_closes():
    region = _hinge()
    args = _hinge_args(20)
    with FusionServer(workers=1, max_batch=2, pad_to=32) as server:
        _parity(server.submit(region, *args).result(timeout=300),
                region(*args))
    assert server._closed and not server._threads
