#!/usr/bin/env python
"""Docs checks run by the CI docs job (and tier-1 via tests/test_docs.py).

Two checks over ``README.md`` and ``docs/*.md``:

1. **Link check** — every relative markdown link target must exist on
   disk (external http(s)/mailto links are skipped to keep the job
   hermetic; pure #anchors are skipped).
2. **Snippet drift** — every code block between
   ``<!-- ci:NAME:start -->`` and ``<!-- ci:NAME:end -->`` markers
   (``quickstart``, ``serving``, ``faults``, ...) in README.md *or*
   any ``docs/*.md`` file is extracted verbatim and executed with
   ``PYTHONPATH=src``; any API drift that breaks a documented snippet
   fails here.

Usage: ``python tools/check_docs.py`` (from the repo root; exits
nonzero on failure).
"""

from __future__ import annotations

import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def doc_files() -> list[Path]:
    docs = [REPO / "README.md"]
    docs.extend(sorted((REPO / "docs").glob("*.md")))
    return [d for d in docs if d.exists()]


def check_links() -> list[str]:
    """Return a list of broken-link descriptions (empty = pass)."""
    errors = []
    for doc in doc_files():
        text = doc.read_text()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{doc.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def snippet_names() -> list[str]:
    """Every ``ci:NAME`` snippet marker across README.md + docs/*.md."""
    names: list[str] = []
    for doc in doc_files():
        names.extend(re.findall(r"<!-- ci:(\w+):start -->",
                                doc.read_text()))
    return list(dict.fromkeys(names))


def ci_snippet(name: str) -> str:
    """The verbatim ``ci:name`` code block (README.md or docs/*.md)."""
    for doc in doc_files():
        m = re.search(rf"<!-- ci:{name}:start -->\s*```python\n(.*?)```"
                      rf"\s*<!-- ci:{name}:end -->", doc.read_text(),
                      re.DOTALL)
        if m is not None:
            return m.group(1)
    raise AssertionError(
        f"ci:{name} markers (or the ```python block between them) not "
        "found in README.md or docs/*.md")


def run_snippet(name: str) -> subprocess.CompletedProcess:
    """Execute one documented ci-snippet in a fresh interpreter."""
    import os
    snippet = ci_snippet(name)
    with tempfile.NamedTemporaryFile("w", suffix=f"_docs_{name}.py",
                                     delete=False) as f:
        f.write(snippet)
        path = f.name
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run([sys.executable, path], capture_output=True,
                          text=True, timeout=600, env=env, cwd=str(REPO))


def quickstart_snippet() -> str:
    """The verbatim quickstart code block from README.md."""
    return ci_snippet("quickstart")


def run_quickstart() -> subprocess.CompletedProcess:
    """Execute the README quickstart snippet in a fresh interpreter."""
    return run_snippet("quickstart")


def main() -> int:
    failures = 0
    errors = check_links()
    for e in errors:
        print(f"LINK FAIL: {e}")
    if errors:
        failures += 1
    print(f"link check: {len(doc_files())} files, "
          f"{'FAIL' if errors else 'ok'}")

    names = snippet_names()
    if "quickstart" not in names:
        print("SNIPPET FAIL: README.md has no ci:quickstart block")
        failures += 1
    for name in names:
        res = run_snippet(name)
        if res.returncode != 0:
            print(f"SNIPPET FAIL ci:{name} (docs drifted from the "
                  "code):")
            print(res.stdout)
            print(res.stderr)
            failures += 1
        else:
            print(f"snippet ci:{name}: ok")
            if res.stdout.strip():
                print(res.stdout)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
