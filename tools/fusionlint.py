#!/usr/bin/env python
"""fusionlint — static plan verification over the paper algorithms.

Plans every registered fused region of the requested algorithms under
every requested fusion mode (and, where the shapes allow, under an
abstract 4-way row-sharded mesh so distributed placements and segments
are exercised too), runs the plan verifier (:mod:`repro.core.verify`)
over each resulting ExecPlan, and pretty-prints the diagnostics.  Exits
nonzero iff any error-severity diagnostic is found — the CI gate that
every selectable plan in the repo satisfies the invariant catalog.

Usage (from the repo root):

    PYTHONPATH=src python tools/fusionlint.py \\
        --algo l2svm,mlogreg,kmeans,glm,autoencoder,als_cg \\
        --mode all --strict

``--strict`` runs the full pass (CPlan construction, placement/segment
replay, whole-plan-key completeness) instead of the default O(plan)
cheap mode, and additionally enforces **no-silent-fallback**: every
execution-time downgrade the compiled plan would take (distributed
segment running locally, sparse operand refusing to shard, per-operator
debug dispatch) must carry a nonempty recorded reason — a fallback
entry without one is an error.  Strict mode also sweeps every region's
**rewrite variants** (:mod:`repro.core.rewrite`): each algebraic
variant the bounded rule set generates must pass the rewrite verifier
(RW001–RW004 + the IR checks) strict-clean — a rule producing an
invalid variant on the repo's own regions is an error even though
``Traced.plan()`` would have quietly rejected it.  ``--verbose`` prints
every clean plan and every explained fallback, not just a summary.

Planning runs with rewriting enabled (the context default), so the
verified ExecPlans are exactly the ones the sweep selects — including
regions where a rewritten variant wins.

``--serving`` additionally warms a :class:`repro.serve.FusionServer`
with the load harness's cases (``benchmarks.serving.harness_regions``)
and verifies every plan the warmed cache holds — the serving path
compiles plans at *padded shape classes*, so EXE005/no-silent-fallback
run over exactly the ExecPlans concurrent traffic executes, not just
the paper-shape ones.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import Fused, fusion_mode  # noqa: E402
from repro.core.codegen import plan_fallbacks  # noqa: E402
from repro.core.select import MODES  # noqa: E402
from repro.core.verify import verify_plan  # noqa: E402


def _arr(*shape):
    return np.zeros(shape, np.float32)


def _cases(algo: str) -> list[tuple[str, object, dict]]:
    """(region name, Fused wrapper, shaped args) for one algorithm —
    paper-scale (m >> n) shapes, rows divisible by the probe mesh."""
    if algo == "l2svm":
        from repro.algos import l2svm
        X, w = _arr(10_000, 100), _arr(100, 1)
        y, out, lam = _arr(10_000, 1), _arr(10_000, 1), _arr(1, 1)
        return [
            ("hinge", l2svm._hinge, dict(X=X, w=w, y=y)),
            ("objective_full", l2svm._objective_full,
             dict(X=X, w=w, y=y, lam=lam)),
            ("grad", l2svm._grad, dict(X=X, out=out, y=y, w=w, lam=lam)),
            ("search_terms", l2svm._search_terms,
             dict(out=out, yXs=_arr(10_000, 1))),
            ("objective", l2svm._objective, dict(out=out, w=w)),
        ]
    if algo == "mlogreg":
        from repro.algos import mlogreg
        X, B = _arr(10_000, 100), _arr(100, 5)
        P, Y, v, lam = _arr(10_000, 5), _arr(10_000, 5), _arr(100, 5), \
            _arr(1, 1)
        return [
            ("probs", mlogreg._probs, dict(X=X, B=B)),
            ("nll_obj", mlogreg._nll_obj, dict(X=X, B=B, Y=Y)),
            ("nll_obj_reg", mlogreg._nll_obj_reg,
             dict(X=X, B=B, Y=Y, lam=lam)),
            ("hvp", mlogreg._hvp, dict(X=X, v=v, P=P)),
            ("grad", mlogreg._grad, dict(X=X, P=P, Y=Y)),
            ("nll_terms", mlogreg._nll_terms, dict(P=P, Y=Y)),
            ("fit_terms", mlogreg._fit_terms, dict(X=X, B=B, Y=Y)),
        ]
    if algo == "kmeans":
        from repro.algos import kmeans
        return [
            ("sq_rowsums", kmeans._sq_rowsums, dict(X=_arr(10_000, 50))),
            ("min_dist", kmeans._min_dist,
             dict(XC=_arr(10_000, 5), xsq=_arr(10_000, 1),
                  csq=_arr(1, 5))),
        ]
    if algo == "glm":
        from repro.algos import glm
        X = _arr(10_000, 100)
        col = _arr(10_000, 1)
        return [
            ("link_chain", glm._link_chain, dict(eta=col, y=col)),
            ("wxv", glm._wxv, dict(X=X, w=col, v=_arr(100, 1))),
            ("wz", glm._wz, dict(X=X, w=col, r=col)),
            ("deviance", glm._deviance, dict(y=col, eta=col)),
        ]
    if algo == "autoencoder":
        from repro.algos import autoencoder
        return [
            ("recon_loss", autoencoder._recon_loss,
             dict(Xb=_arr(256, 100),
                  W1=_arr(100, 64), b1=_arr(1, 64),
                  W2=_arr(64, 2), b2=_arr(1, 2),
                  W3=_arr(2, 64), b3=_arr(1, 64),
                  W4=_arr(64, 100), b4=_arr(1, 100))),
        ]
    if algo == "als_cg":
        from repro.algos import als_cg
        # re-wrap with a planning-time sparsity hint for the ratings
        # matrix so the sparsity-exploiting Outer template qualifies
        # (the algo passes a real BCSR whose density the trace reads)
        wsq = Fused(als_cg._wsq_mm.fn, sparsity={"X": 0.05})
        loss = Fused(als_cg._loss_terms.fn, sparsity={"X": 0.05})
        X, U, V = _arr(2_000, 500), _arr(2_000, 20), _arr(500, 20)
        return [
            ("wsq_mm", wsq, dict(X=X, U=U, V=V)),
            ("loss_terms", loss, dict(X=X, U=U, V=V)),
        ]
    raise SystemExit(f"fusionlint: unknown algo '{algo}'")


def _mesh():
    from repro.dist import LogicalMesh
    return LogicalMesh({"data": 4})


def _check_fallbacks(eplan, layout, label: str,
                     verbose: bool) -> tuple[int, int]:
    """no-silent-fallback: every downgrade the compiled plan would take
    must carry a nonempty recorded reason.  Returns (total, silent)."""
    entries = plan_fallbacks(eplan, layout=layout)
    silent = 0
    for fb in entries:
        site = fb.get("site", "?")
        reason = str(fb.get("reason", "") or "").strip()
        if not reason:
            silent += 1
            print(f"{label}: SILENT fallback at site={site!r} — "
                  "no reason recorded")
        elif verbose:
            print(f"{label}: fallback[{site}] {reason}")
    return len(entries), silent


def _check_rewrites(graph, label: str, verbose: bool) -> tuple[int, int]:
    """all-variants-verify-clean: every algebraic variant the rewrite
    rule set generates for this region must pass the rewrite verifier
    strict-clean.  Returns (variants, failing variants)."""
    from repro.core.rewrite import rewrite_variants
    from repro.core.verify import verify_variant

    bad = 0
    variants = rewrite_variants(graph)
    for v in variants:
        report = verify_variant(graph, v.graph, level="strict")
        if not report.ok:
            bad += 1
            print(f"{label}: rewrite variant {'+'.join(v.rules)} "
                  f"failed verification: {report.pretty()}")
        elif verbose:
            print(f"{label}: rewrite {'+'.join(v.rules)} clean")
    return len(variants), bad


def lint_serving(level: str, verbose: bool) -> tuple[int, list[str]]:
    """Verify the plans the serving harness compiles, reusing the warmed
    entry cache (``workers=0`` server: warm() plans and compiles without
    executing anything).  Returns (plans verified, failing labels)."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.serving import MAX_BATCH, PAD_TO, harness_regions
    from repro.serve import FusionServer

    server = FusionServer(workers=0, max_batch=MAX_BATCH, pad_to=PAD_TO,
                          autostart=False)
    cases = harness_regions()
    server.warm([(region, ops) for _l, region, ops in cases],
                execute=False)
    failed: list[str] = []
    n = 0
    for label, planned in server.warmed_plans():
        full = f"serving/{label}"
        report = verify_plan(planned.eplan, level=level, layout=None)
        n += 1
        if report.errors:
            failed.append(full)
        if report.diagnostics or verbose:
            print(f"{full}: {report.pretty()}")
        if level == "strict":
            total, silent = _check_fallbacks(planned.eplan, None, full,
                                             verbose)
            if silent:
                failed.append(f"{full} [no-silent-fallback]")
    return n, failed


def lint_faults(verbose: bool) -> int:
    """``--faults``: audit the fault-injection site registry.  Imports
    every module that declares a site (``faults.ensure_registered``) and
    fails if no sites exist or any site lacks a documented handler —
    the fault-tolerance analogue of no-silent-fallback: a site you can
    inject at but nothing recovers from is a latent outage."""
    from repro import faults

    sites = faults.ensure_registered()
    bad = 0
    for site in sorted(sites, key=lambda s: s.name):
        ok = bool(site.handler.strip())
        if not ok:
            bad += 1
        if verbose or not ok:
            status = "OK" if ok else "MISSING HANDLER"
            print(f"{site.name}: kinds={','.join(site.kinds)} [{status}]")
            if ok:
                print(f"  handler: {site.handler}")
    print(f"fusionlint: {len(sites)} fault site(s) registered, "
          f"{bad} without a handler")
    if not sites:
        print("fusionlint: no fault sites registered — the injection "
              "harness is disconnected from the stack")
        return 1
    return 1 if bad else 0


def lint(algos: list[str], modes: list[str], level: str,
         verbose: bool, serving: bool = False) -> int:
    n_plans = n_errors = n_warnings = n_fallbacks = n_silent = 0
    n_rewrites = n_rewrite_bad = 0
    failed: list[str] = []
    layouts = [("local", None), ("mesh[data=4]", _mesh())]
    for algo in algos:
        for region, wrapper, args in _cases(algo):
            if level == "strict":
                # once per region: every rewrite variant verify-clean
                rlabel = f"{algo}/{region} [rewrite]"
                total, bad = _check_rewrites(wrapper.trace(**args).graph,
                                             rlabel, verbose)
                n_rewrites += total
                n_rewrite_bad += bad
                if bad:
                    n_errors += bad
                    failed.append(rlabel)
            for mode in modes:
                for lname, layout in layouts:
                    label = f"{algo}/{region} mode={mode} {lname}"
                    with fusion_mode(mode, layout=layout, verify="off"):
                        eplan = wrapper.plan_for(**args)
                    report = verify_plan(eplan, level=level, layout=layout)
                    n_plans += 1
                    n_errors += len(report.errors)
                    n_warnings += len(report.warnings)
                    if report.errors:
                        failed.append(label)
                    if report.diagnostics or verbose:
                        print(f"{label}: {report.pretty()}")
                    if level == "strict":
                        total, silent = _check_fallbacks(
                            eplan, layout, label, verbose)
                        n_fallbacks += total
                        n_silent += silent
                        if silent:
                            n_errors += silent
                            failed.append(f"{label} [no-silent-fallback]")
    if serving:
        n, sfailed = lint_serving(level, verbose)
        n_plans += n
        n_errors += len(sfailed)
        failed.extend(sfailed)
    print(f"fusionlint: {n_plans} plans verified [{level}] — "
          f"{n_errors} error(s), {n_warnings} warning(s)"
          + (f", {n_fallbacks} fallback(s) ({n_silent} silent), "
             f"{n_rewrites} rewrite variant(s) ({n_rewrite_bad} unclean)"
             if level == "strict" else ""))
    if failed:
        print("failing plans:")
        for label in failed:
            print(f"  {label}")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="fusionlint",
        description="statically verify every selectable fusion plan "
                    "of the paper algorithms")
    ap.add_argument("--algo", default="l2svm,mlogreg,kmeans,glm,"
                    "autoencoder,als_cg",
                    help="comma-separated algorithm list (default: all)")
    ap.add_argument("--mode", default="all",
                    help="fusion mode(s), comma-separated or 'all' "
                         f"(choices: {', '.join(MODES)})")
    ap.add_argument("--strict", action="store_true",
                    help="full pass: build CPlans, replay placements/"
                         "segments, check the whole-plan key")
    ap.add_argument("--serving", action="store_true",
                    help="also verify the plans the serving harness "
                         "compiles (warmed FusionServer cache)")
    ap.add_argument("--faults", action="store_true",
                    help="audit the fault-injection site registry: list "
                         "every site and fail on any without a "
                         "documented handler")
    ap.add_argument("--verbose", action="store_true",
                    help="print every verified plan, including clean "
                         "ones")
    args = ap.parse_args(argv)

    if args.faults:
        return lint_faults(args.verbose)
    algos = [a.strip() for a in args.algo.split(",") if a.strip()]
    modes = list(MODES) if args.mode == "all" else \
        [m.strip() for m in args.mode.split(",") if m.strip()]
    for m in modes:
        if m not in MODES:
            ap.error(f"unknown mode '{m}' (choices: {', '.join(MODES)})")
    return lint(algos, modes, "strict" if args.strict else "cheap",
                args.verbose, serving=args.serving)


if __name__ == "__main__":
    raise SystemExit(main())
