"""Quickstart: the staged fusion API on the ALS expression (Expression 1).

The optimizer pipeline is three explicit, inspectable stages —

    fused(fn).trace(*operands)   -> Traced    (HOP DAG, static shapes)
    Traced.plan(mode=, layout=)  -> Planned   (explore -> select; explain())
    Planned.compile(pallas=)     -> Compiled  (generated fused operators)

— with ``@fused`` call syntax as sugar over the same path, and
``jax.grad`` working through compiled operators (the backward pass is
planned too).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FusionContext, fused, ir, plan_cache_stats
from repro.kernels.blocksparse import BCSR


def main():
    # -- 1. declare the expression over typed operands ------------------------
    @fused(sparsity={"X": 0.1})
    def als_update(X, U, V, r):
        return (ir.neq0(X) * (U @ V.T)) @ V + 1e-6 * U * r

    rng = np.random.default_rng(0)
    mask = np.kron(rng.random((16, 16)) < 0.1, np.ones((128, 128)))
    Xd = (rng.normal(size=(2048, 2048)) * mask).astype(np.float32)
    binds = dict(
        X=BCSR.from_dense(Xd, bs=128),
        U=jnp.asarray(rng.normal(size=(2048, 32)), jnp.float32),
        V=jnp.asarray(rng.normal(size=(2048, 32)), jnp.float32),
        r=jnp.asarray(rng.normal(size=(2048, 1)), jnp.float32),
    )

    # -- 2. stage: trace once, inspect every candidate arm's cost -------------
    traced = als_update.trace(**binds)
    planned = traced.plan(mode="gen")
    report = planned.explain()
    for cand in report["candidates"]:
        mark = " <- selected" if cand["selected"] else ""
        print(f"{cand['mode']:5s} cost={cand['cost']:.6f}s "
              f"fused_ops={cand['n_fused']}{mark}")
    print("winner operators:",
          json.dumps(report["winner"]["operators"], indent=1))

    # -- 3. compile + execute the generated fused operators -------------------
    op = planned.compile()
    out = op(**binds)
    ref = ((Xd != 0) * (binds["U"] @ binds["V"].T)) @ binds["V"] \
        + 1e-6 * binds["U"] * binds["r"]
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"fused output {out.shape}, max err vs dense reference: {err:.2e}")

    # -- 4. sugar: the same operator through @fused call syntax ---------------
    with FusionContext(mode="gen"):
        out2 = als_update(**binds)
    print("call-sugar max diff:", float(jnp.max(jnp.abs(out2 - out))))

    # -- 5. differentiate a fused region: the backward pass is planned too ----
    sq_loss = fused(lambda U, V: ((U @ V.T) ** 2).sum())
    gU = jax.grad(lambda u: sq_loss(u, binds["V"])[0, 0])(binds["U"])
    gref = 2.0 * (binds["U"] @ binds["V"].T) @ binds["V"]
    print("jax.grad through fused op, max err:",
          float(jnp.max(jnp.abs(gU - gref))))
    st = plan_cache_stats()
    print(f"plan cache: {st.hits} hits / {st.misses} misses "
          f"({st.size} operators)")


if __name__ == "__main__":
    main()
