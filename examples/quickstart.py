"""Quickstart: the paper's optimizer on the ALS expression (Expression 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import ir, fused, fusion_mode
from repro.core.select import plan
from repro.kernels.blocksparse import BCSR


def main():
    # -- 1. declare the expression over typed matrices ----------------------
    X = ir.matrix("X", (2048, 2048), sparsity=0.05)
    U = ir.matrix("U", (2048, 32))
    V = ir.matrix("V", (2048, 32))
    r = ir.matrix("r", (2048, 1))
    O = (ir.neq0(X) * (U @ V.T)) @ V + 1e-6 * U * r
    graph = ir.Graph.build([O])

    # -- 2. inspect the optimized fusion plan --------------------------------
    for mode in ("gen", "fa", "fnr", "none"):
        p = plan(graph, mode)
        ops = [f"{s.ttype.letter if getattr(s, 'ttype', None) else 'basic'}"
               f"@{s.root}" for s in p.specs]
        print(f"{mode:5s} cost={p.cost:.6f}s plan: {' | '.join(ops)}")

    # -- 3. execute through the fusion API ------------------------------------
    rng = np.random.default_rng(0)
    mask = np.kron(rng.random((16, 16)) < 0.1, np.ones((128, 128)))
    Xd = (rng.normal(size=(2048, 2048)) * mask).astype(np.float32)
    binds = dict(
        X=BCSR.from_dense(Xd, bs=128),
        U=jnp.asarray(rng.normal(size=(2048, 32)), jnp.float32),
        V=jnp.asarray(rng.normal(size=(2048, 32)), jnp.float32),
        r=jnp.asarray(rng.normal(size=(2048, 1)), jnp.float32),
    )

    @fused(sparsity={"X": 0.1})
    def als_update(X, U, V, r):
        return (ir.neq0(X) * (U @ V.T)) @ V + 1e-6 * U * r

    with fusion_mode("gen"):
        out = als_update(**binds)
    ref = ((Xd != 0) * (binds["U"] @ binds["V"].T)) @ binds["V"] \
        + 1e-6 * binds["U"] * binds["r"]
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"fused output {out.shape}, max err vs dense reference: {err:.2e}")


if __name__ == "__main__":
    main()
