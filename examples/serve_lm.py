"""Serve a small model with batched requests through the continuous-
batching engine (prefill + decode with shared KV cache slots).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import LM
from repro.serve import Engine, Request


def main():
    cfg = get_config("starcoder2-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, batch_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=p).astype(np.int32),
                    max_new=12) for p in (9, 17, 5, 24, 13, 7)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_done()
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.out}")
    assert all(r.done and len(r.out) == 12 for r in reqs)
    print("all requests served")


if __name__ == "__main__":
    main()
