"""The paper's flagship workload end-to-end: ALS-CG matrix factorization
over block-sparse ratings, with the Gen-optimized sparsity-exploiting
Outer-template operators.

Run:  PYTHONPATH=src python examples/als_recommender.py
"""

import time

import numpy as np

from repro.algos import als_cg, data
from repro.configs.als_paper import CONFIG


def main():
    X = data.ratings(2048, 1536, rank=CONFIG.rank, bs=CONFIG.block_size,
                     block_density=0.15, seed=0)
    print(f"ratings: {X.shape}, {X.nblocks} non-zero blocks "
          f"(block density {X.block_sparsity:.2f})")
    for mode in ("gen", "hand"):
        t0 = time.perf_counter()
        U, V, losses = als_cg.run(X, rank=CONFIG.rank, lam=CONFIG.lam,
                                  max_iter=4, max_inner=CONFIG.max_inner,
                                  mode=mode)
        dt = time.perf_counter() - t0
        print(f"{mode:5s}: loss {losses[0]:.1f} -> {losses[-1]:.1f} "
              f"in {dt:.1f}s")


if __name__ == "__main__":
    main()
