"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with fused loss, checkpointing, and restart.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
(CPU demo: use --steps 30 --preset tiny)
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "minitron-4b", "--preset", "100m",
                     "--steps", "300", "--batch", "8", "--seq", "512",
                     "--fusion", "gen"]
    main()
