"""Fig. 8(h): Outer template micro — sum(X ⊙ log(UVᵀ + eps)) over a
block-sparsity sweep.  Gen over BCSR does work ∝ non-zero blocks; Base
materializes the dense m×n product (the paper's orders-of-magnitude gap)."""

import jax.numpy as jnp
import numpy as np

from repro.core import FusionContext, fused, ir
from repro.kernels.blocksparse import BCSR
from .common import emit, timeit

BS = 128
GRID = (16, 16)          # 2048 × 2048 cells
RANK = 32


def main() -> None:
    rng = np.random.default_rng(0)
    m, n = GRID[0] * BS, GRID[1] * BS
    U = jnp.asarray(rng.normal(size=(m, RANK)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(n, RANK)), jnp.float32)

    @fused
    def outer(X, U, V):
        return (ir.abs_(X) * ir.log((U @ V.T) ** 2 + 1e-15)).sum()

    for density in (1.0, 0.25, 0.05):
        mask = rng.random(GRID) < density
        mask.flat[0] = True
        dense = rng.normal(size=(m, n)).astype(np.float32) \
            * np.kron(mask, np.ones((BS, BS), np.float32))
        Xs = BCSR.from_dense(dense, bs=BS)
        Xd = jnp.asarray(dense)

        hand = timeit(
            lambda: jnp.sum(jnp.abs(Xd) * jnp.log((U @ V.T) ** 2 + 1e-15)))
        with FusionContext(mode="gen"):
            gen = timeit(lambda: outer(Xs, U, V))
        emit(f"outer_sum_d{density}_dense", hand, "")
        emit(f"outer_sum_d{density}_gen_bcsr", gen,
             f"speedup={hand / gen:.2f},nblocks={Xs.nblocks}")
