"""Fig. 10 (adapted): operator-chain length vs materialization cost.

SystemML's experiment probed JIT/i-cache limits of inlined generated code;
the TPU analogue is intermediate materialization: one fused operator for an
n-op cell chain vs n materialized basic operators."""

import jax.numpy as jnp
import numpy as np

from repro.core import FusionContext, fused, ir
from .common import emit, timeit


def chain_fn(n_ops: int):
    @fused
    def f(X, r):
        c = X / r
        for i in range(n_ops):
            c = c * float(i + 1)
        return c.sum()
    return f


def main() -> None:
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(4000, 250)), jnp.float32)
    r = jnp.asarray(np.abs(rng.normal(size=(4000, 1))) + 1.0, jnp.float32)
    for n_ops in (4, 16, 64):
        f = chain_fn(n_ops)
        times = {}
        for mode in ("none", "gen"):
            with FusionContext(mode=mode):
                times[mode] = timeit(lambda: f(X, r))
        emit(f"footprint_chain{n_ops}_base", times["none"], "")
        emit(f"footprint_chain{n_ops}_gen", times["gen"],
             f"speedup_vs_base={times['none'] / times['gen']:.2f}")
