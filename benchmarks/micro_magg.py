"""Fig. 8(c,d): Multi-aggregate micro — sum(X⊙Y), sum(X⊙Z), sum(X²) share
one scan of X when Gen compiles a multi-aggregate."""

import jax.numpy as jnp
import numpy as np

from repro.core import FusionContext, fused
from .common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)
    m, n = 2000, 1000
    X, Y, Z = (jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
               for _ in range(3))

    @fused
    def magg(X, Y, Z):
        return (X * Y).sum(), (X * Z).sum(), (X ** 2).sum()

    hand = timeit(lambda: (jnp.sum(X * Y), jnp.sum(X * Z), jnp.sum(X * X)))
    times = {}
    for mode in ("none", "fa", "gen"):
        with FusionContext(mode=mode):
            times[mode] = timeit(lambda: magg(X, Y, Z))
    emit(f"magg3_{m}x{n}_base", times["none"], "")
    emit(f"magg3_{m}x{n}_hand", hand, "individual_aggs")
    emit(f"magg3_{m}x{n}_fa", times["fa"], "no_multiagg_sharing")
    emit(f"magg3_{m}x{n}_gen", times["gen"],
         f"speedup_vs_base={times['none'] / times['gen']:.2f}")
