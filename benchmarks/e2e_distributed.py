"""Table 6 analog: distributed fusion plans.

The paper's distributed finding: fuse-all eagerly pulls driver-local
vector operations into distributed operators over large inputs, paying
broadcast overhead — Gen avoids it by reasoning about template switches
and broadcast costs.  Here the same mechanism appears on the mesh: side
inputs of a fused operator that cross shards are priced at ICI all-gather
bandwidth instead of HBM.  We cost the same DAGs with local vs
distributed read bandwidths and report the plan changes, plus a real
shard_map execution of the fused L2SVM step over host devices.
"""

import numpy as np

from repro.core import ir
from repro.core.cost import CostParams
from repro.core.select import plan
from .common import emit

HBM = 819e9
ICI = 50e9


def _l2svm_graph():
    X = ir.matrix("X", (2_000_000, 100))
    w = ir.matrix("w", (100, 1))
    y = ir.matrix("y", (2_000_000, 1))
    out = ir.relu(1.0 - y * (X @ w))
    g = -1.0 * (X.T @ (out * y)) + 1e-3 * w
    return ir.Graph.build([(out ** 2).sum(), g]), ("w", "y")


def _mlogreg_graph():
    X = ir.matrix("X", (2_000_000, 100))
    v = ir.matrix("v", (100, 4))
    P = ir.matrix("P", (2_000_000, 5))
    Pk = P.cols(0, 4)
    Q = Pk * (X @ v)
    return ir.Graph.build([X.T @ (Q - Pk * Q.rowsums())]), ("v",)


def main() -> None:
    for name, (graph, bc_names) in {
            "l2svm": _l2svm_graph(), "mlogreg": _mlogreg_graph()}.items():
        # local: everything at HBM speed
        local = plan(graph, "gen")
        # distributed: broadcast-able small inputs cross shards at ICI bw
        bc_ids = {n.nid for n in graph.inputs() if n.name in bc_names}
        params = CostParams(input_read_bw={i: ICI for i in bc_ids})
        dist_gen = plan(graph, "gen", params)
        dist_fa = plan(graph, "fa", params)
        emit(f"dist_{name}_gen_local", local.cost * 1e6, "")
        emit(f"dist_{name}_gen", dist_gen.cost * 1e6,
             f"vs_fa={dist_fa.cost / dist_gen.cost:.2f}x")
        emit(f"dist_{name}_fa", dist_fa.cost * 1e6,
             "eager fusion pays broadcast reads")

    _shardmap_execution()


def _shardmap_execution() -> None:
    """Execute the fused hinge+gradient step SPMD over all host devices."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import fused

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    m = 1024 * n_dev
    X = jnp.asarray(rng.normal(size=(m, 32)), jnp.float32)
    y = jnp.asarray(np.sign(rng.normal(size=(m, 1))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)
    X = jax.device_put(X, NamedSharding(mesh, P("data", None)))
    y = jax.device_put(y, NamedSharding(mesh, P("data", None)))
    w = jax.device_put(w, NamedSharding(mesh, P(None, None)))

    @fused
    def step(X, w, y):
        out = ir.relu(1.0 - y * (X @ w))
        return (out ** 2).sum(), -1.0 * (X.T @ (out * y)) + 1e-3 * w

    # staged path with the mesh threaded onto fused-operator I/O: the
    # layout prices distributed side-input reads during selection and
    # sharding-constrains the operands at execution.
    op = step.trace(X, w, y).plan(mode="gen", layout=mesh).compile()
    jstep = jax.jit(lambda X, w, y: op(X, w, y))
    loss, grad = jstep(X, w, y)
    ref_out = jnp.maximum(1.0 - y * (X @ w), 0.0)
    ref = (jnp.sum(ref_out ** 2),
           -(X.T @ (ref_out * y)) + 1e-3 * w)
    err = max(float(jnp.max(jnp.abs(loss - ref[0]))),
              float(jnp.max(jnp.abs(grad - ref[1]))))
    emit("dist_shardmap_l2svm_step", 0.0,
         f"devices={n_dev},max_err={err:.1e}")
    assert err < 2e-2


if __name__ == "__main__":
    main()
