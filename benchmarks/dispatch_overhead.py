"""Whole-plan vs per-operator dispatch — the staged-execution microbench.

A many-operator chain is executed two ways from the same ExecPlan:

* ``staged=True`` — the whole plan is one jitted computation: a single
  dispatch per call, every operator boundary an XLA value;
* ``staged=False`` — the per-operator interpreter: one jitted dispatch
  per fused operator plus eager basic ops and Python between them (the
  pre-staging runtime, kept as the debug path).

Each stage is ``sigmoid(cᵀ ⊙ a + b)``: the transpose is never covered by
a template (a basic operator), so the plan genuinely materializes one
fused Cell operator plus one basic operator per stage — ``n_operators``
grows with the chain instead of the whole chain collapsing into a single
Row operator.  On 96×96 operands the computation is microseconds while
each dispatch costs tens of microseconds, so the gap is pure plan-level
overhead — the quantity the whole-plan backend removes.  Expected:
staged ≥ 2x faster per call on the ≥ 8-operator chain (CPU,
``pallas="never"``).
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import fused, ir
from .common import _block, emit

M = 64


def chain_fn(n_stages: int):
    @fused
    def f(X, a, b):
        c = X
        for _ in range(n_stages):
            c = ir.sigmoid(c.T * a + b)    # t: basic op between fused ops
        return c.sum()
    return f


def _paired(fn_a, fn_b, warmup: int = 3, reps: int = 9):
    """Interleaved min-of-reps timing (us) for two callables: alternating
    the arms cancels machine-load drift that would bias whichever arm
    runs first, and the min is the standard estimator for pure-overhead
    microbenches (noise is strictly additive)."""
    for _ in range(warmup):
        _block(fn_a())
        _block(fn_b())
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        _block(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def main() -> None:
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(M, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(M, M)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(M, M)) * 0.1, jnp.float32)
    for n_stages in (8, 16, 32):
        f = chain_fn(n_stages)
        planned = f.trace(X, a, b).plan(mode="gen")
        n_ops = len(planned.eplan.specs)
        whole = planned.compile(staged=True)
        per_op = planned.compile(staged=False)
        t_whole, t_per_op = _paired(lambda: whole(X, a, b),
                                    lambda: per_op(X, a, b))
        emit(f"dispatch_chain{n_stages}_per_op", t_per_op,
             f"n_operators={n_ops}")
        emit(f"dispatch_chain{n_stages}_whole_plan", t_whole,
             f"n_operators={n_ops},"
             f"speedup_vs_per_op={t_per_op / t_whole:.2f}")


if __name__ == "__main__":
    main()
