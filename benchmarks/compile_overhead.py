"""Table 3 / Fig. 11: compilation overhead — planning time, generated
operators, and plan-cache effectiveness per algorithm."""

import time

import numpy as np

from repro.algos import data, als_cg, autoencoder, glm, kmeans, l2svm, mlogreg
from repro.core import plan_cache_stats
from repro.core.codegen import PLAN_CACHE
from .common import _block, emit


def main() -> None:
    X, Y, ypm = data.classification(600, 24, k=4, seed=1)
    Xr, yr = data.regression(400, 16, seed=2)
    Xc, C0 = data.clusters(400, 8, k=5, seed=3)
    Xr8 = data.ratings(384, 256, rank=4, bs=128, block_density=0.4, seed=4)
    Xim = data.images(256, 64, seed=5)

    runs = [
        ("l2svm", lambda: l2svm.run(X, ypm, max_iter=5, mode="gen")),
        ("mlogreg", lambda: mlogreg.run(X, Y, max_outer=3, max_inner=4,
                                        mode="gen")),
        ("glm", lambda: glm.run(Xr, yr, max_outer=3, max_inner=4,
                                mode="gen")),
        ("kmeans", lambda: kmeans.run(Xc, C0, max_iter=5, mode="gen")),
        ("als_cg", lambda: als_cg.run(Xr8, rank=4, max_iter=2, max_inner=3,
                                      mode="gen")),
        ("autoencoder", lambda: autoencoder.run(Xim, h1=32, h2=2, batch=128,
                                                epochs=1, mode="gen")),
    ]
    for name, fn in runs:
        PLAN_CACHE.clear()
        t0 = time.perf_counter()
        # async dispatch: block on the returned arrays, or the stop clock
        # reads queue time, not run time
        _block(fn())
        total_s = time.perf_counter() - t0
        st = plan_cache_stats()
        emit(f"compile_{name}", total_s * 1e6,
             f"ops_compiled={st.misses},cache_hits={st.hits},"
             f"codegen_ms={st.codegen_time_s * 1e3:.1f}")
