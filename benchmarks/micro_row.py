"""Fig. 8(e,g): Row template micro — Xᵀ(Xv) and Xᵀ(XV)."""

import jax.numpy as jnp
import numpy as np

from repro.core import FusionContext, fused
from .common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)
    m, n = 20000, 256
    X = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)

    @fused
    def mmchain(X, v):
        return X.T @ (X @ v)

    for k, tag in ((1, "mv"), (2, "mm")):
        v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        hand = timeit(lambda: X.T @ (X @ v))
        times = {}
        for mode in ("none", "gen"):
            with FusionContext(mode=mode):
                times[mode] = timeit(lambda: mmchain(X, v))
        emit(f"row_mmchain_{tag}_{m}x{n}_base", times["none"], "")
        emit(f"row_mmchain_{tag}_{m}x{n}_hand", hand, "")
        emit(f"row_mmchain_{tag}_{m}x{n}_gen", times["gen"],
             f"speedup_vs_base={times['none'] / times['gen']:.2f}")
