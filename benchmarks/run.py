"""Benchmark driver — one module per paper table/figure.

Usage: ``PYTHONPATH=src python -m benchmarks.run [module ...]``
Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys
import time

from .common import header

MODULES = [
    "micro_cell",        # Fig. 8(a,b)
    "micro_magg",        # Fig. 8(c,d)
    "micro_row",         # Fig. 8(e,g)
    "micro_outer",       # Fig. 8(h)
    "micro_compressed",  # Fig. 9
    "footprint",         # Fig. 10 (adapted)
    "compile_overhead",  # Table 3 / Fig. 11
    "plan_enum",         # Fig. 12
    "e2e_algos",         # Tables 4/5
    "e2e_distributed",   # Table 6 (shard_map over host devices)
]


def main() -> None:
    import importlib
    want = sys.argv[1:] or MODULES
    header()
    for name in want:
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except ImportError as e:
            print(f"# skip {name}: {e}", flush=True)
            continue
        print(f"# --- {name} ---", flush=True)
        mod.main()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
