"""Benchmark driver — one module per paper table/figure.

Usage::

    PYTHONPATH=src python -m benchmarks.run [--json PATH] [module ...]

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
writes every collected row as a machine-readable artifact (the CI uploads
``BENCH_fusion.json`` from the full job so the perf trajectory is
diffable across commits).
"""

from __future__ import annotations

import sys
import time

from .common import header, write_json

MODULES = [
    "micro_cell",        # Fig. 8(a,b)
    "micro_magg",        # Fig. 8(c,d)
    "micro_row",         # Fig. 8(e,g)
    "micro_outer",       # Fig. 8(h)
    "micro_compressed",  # Fig. 9
    "footprint",         # Fig. 10 (adapted)
    "dispatch_overhead",  # whole-plan vs per-operator dispatch
    "serving",           # FusionServer load test (throughput + tails)
    "compile_overhead",  # Table 3 / Fig. 11
    "plan_enum",         # Fig. 12
    "e2e_algos",         # Tables 4/5
    "e2e_distributed",   # Table 6 (shard_map over host devices)
]


def main() -> None:
    import importlib
    argv = sys.argv[1:]
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("usage: python -m benchmarks.run [--json PATH] "
                     "[module ...]")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    want = argv or MODULES
    header()
    for name in want:
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except ImportError as e:
            print(f"# skip {name}: {e}", flush=True)
            continue
        print(f"# --- {name} ---", flush=True)
        mod.main()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)
    if json_path is not None:
        write_json(json_path, modules=want)


if __name__ == "__main__":
    main()
