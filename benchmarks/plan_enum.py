"""Fig. 12: plan enumeration and pruning effectiveness — evaluated plans
with (a) no partitioning (full 2^|M'| space), (b) partitioning, and
(c) partitioning + cost-based + structural pruning."""

import numpy as np

from repro.core import ir
from repro.core.cost import TPU_V5E
from repro.core.enumerate import EnumStats, mp_skip_enum
from repro.core.explore import explore
from repro.core.partitions import build_partitions
from .common import emit, timeit


def _algo_graphs():
    gs = {}
    X = ir.matrix("X", (100000, 100))
    w = ir.matrix("w", (100, 1))
    y = ir.matrix("y", (100000, 1))
    out = ir.relu(1.0 - y * (X @ w))
    gs["l2svm"] = ir.Graph.build([
        (out ** 2).sum(), (-1.0 * (X.T @ (out * y)) + 1e-3 * w)])
    v = ir.matrix("v", (100, 4))
    P = ir.matrix("P", (100000, 5))
    Pk = P.cols(0, 4)
    Q = Pk * (X @ v)
    gs["mlogreg"] = ir.Graph.build([X.T @ (Q - Pk * Q.rowsums())])
    Xs = ir.matrix("Xs", (20000, 20000), sparsity=0.01)
    U = ir.matrix("U", (20000, 20))
    V = ir.matrix("V", (20000, 20))
    gs["als"] = ir.Graph.build([
        (ir.neq0(Xs) * (U @ V.T)) @ V + 1e-6 * U,
        ((ir.neq0(Xs) * (U @ V.T) - Xs) ** 2).sum()])
    # wide shared-CSE DAG (AutoEncoder-like worst case for enumeration)
    A = ir.matrix("A", (10000, 256))
    h = ir.sigmoid(A * 0.5)
    outs = []
    for i in range(6):
        outs.append((h * float(i + 1) + 1.0).sum())
    gs["wide_cse"] = ir.Graph.build(outs)
    return gs


def main() -> None:
    for name, g in _algo_graphs().items():
        memo = explore(g)
        parts = build_partitions(g, memo)
        n_points = sum(len(p.points) for p in parts)
        space_all = 2 ** n_points
        space_part = sum(2 ** len(p.points) for p in parts)
        st = EnumStats()
        for p in parts:
            mp_skip_enum(g, memo, p, TPU_V5E, stats=st)
        emit(f"planenum_{name}_all", 0.0, f"plans={space_all}")
        emit(f"planenum_{name}_partition", 0.0, f"plans={space_part}")
        emit(f"planenum_{name}_partition_prune", 0.0,
             f"plans={st.plans_costed},skipped_cost="
             f"{int(st.plans_skipped_cost)},skipped_struct="
             f"{int(st.plans_skipped_struct)}")
