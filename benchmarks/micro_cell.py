"""Fig. 8(a,b): Cell template micro-benchmark — sum(X ⊙ Y ⊙ Z)."""

import jax.numpy as jnp
import numpy as np

from repro.core import FusionContext, fused
from .common import emit, timeit

SIZES = [(1000, 1000), (4000, 1000)]


def main() -> None:
    rng = np.random.default_rng(0)

    @fused
    def cell(X, Y, Z):
        return (X * Y * Z).sum()

    for (m, n) in SIZES:
        X, Y, Z = (jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
                   for _ in range(3))
        hand = timeit(lambda: jnp.sum(X * Y * Z))
        base_t = gen_t = None
        for mode in ("none", "gen"):
            with FusionContext(mode=mode):
                t = timeit(lambda: cell(X, Y, Z))
            if mode == "none":
                base_t = t
            else:
                gen_t = t
        emit(f"cell_sum_mul3_{m}x{n}_base", base_t, "")
        emit(f"cell_sum_mul3_{m}x{n}_hand", hand, "")
        emit(f"cell_sum_mul3_{m}x{n}_gen", gen_t,
             f"speedup_vs_base={base_t / gen_t:.2f}")
