"""Shared benchmark harness: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = a
benchmark-specific figure of merit, e.g. speedup over Base).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def _block(x):
    return jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a, x)


def timeit(fn: Callable, *, warmup: int = 1, reps: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        _block(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
