"""Shared benchmark harness: timing + CSV/JSON emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = a
benchmark-specific figure of merit, e.g. speedup over Base).  The driver
(``benchmarks.run --json``) can additionally dump all collected rows as a
machine-readable JSON artifact (``BENCH_fusion.json``) so the perf
trajectory is diffable across commits.

Timing rule: :func:`timeit` blocks on *every* value the timed callable
returns (``jax.block_until_ready`` over the pytree).  JAX dispatch is
asynchronous — without the block, a "per-call" number for a small
operator measures Python dispatch only, not the computation.  Any timing
loop added outside :func:`timeit` must block the same way.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Optional

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def _block(x):
    return jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a, x)


def timeit(fn: Callable, *, warmup: int = 1, reps: int = 3) -> float:
    """Median wall time per call in microseconds (output-blocked)."""
    for _ in range(warmup):
        _block(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def write_json(path: str, modules: Optional[list[str]] = None) -> None:
    """Dump every row emitted so far as a JSON artifact:
    ``{"rows": [{"name", "us_per_call", "derived"}, ...], ...}``."""
    doc = {
        "schema": "repro-bench-v1",
        "modules": list(modules or []),
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "rows": [{"name": n, "us_per_call": round(us, 3), "derived": d}
                 for (n, us, d) in ROWS],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(ROWS)} rows to {path}", flush=True)
