"""Tables 4/5: end-to-end algorithm runtime across experimental arms
(Base / hand-Fused / Gen / Gen-FA / Gen-FNR)."""

import numpy as np

from repro.algos import data, als_cg, autoencoder, glm, kmeans, l2svm, mlogreg
from .common import emit, timeit

ARMS = ("none", "hand", "fnr", "fa", "gen")


def main() -> None:
    X, Y, ypm = data.classification(4000, 64, k=4, seed=1)
    Xr, yr = data.regression(4000, 32, seed=2)
    Xc, C0 = data.clusters(4000, 16, k=5, seed=3)
    Xr8 = data.ratings(1024, 768, rank=8, bs=128, block_density=0.25, seed=4)
    Xim = data.images(1024, 128, seed=5)

    suites = [
        ("l2svm", lambda m: l2svm.run(X, ypm, max_iter=5, mode=m)),
        ("mlogreg", lambda m: mlogreg.run(X, Y, max_outer=2, max_inner=4,
                                          mode=m)),
        ("glm", lambda m: glm.run(Xr, yr, max_outer=2, max_inner=4, mode=m)),
        ("kmeans", lambda m: kmeans.run(Xc, C0, max_iter=5, mode=m)),
        ("als_cg", lambda m: als_cg.run(Xr8, rank=8, max_iter=2,
                                        max_inner=2, mode=m)),
        ("autoencoder", lambda m: autoencoder.run(Xim, h1=64, h2=2,
                                                  batch=256, epochs=1,
                                                  mode=m)),
    ]
    for name, fn in suites:
        times = {}
        for arm in ARMS:
            times[arm] = timeit(lambda: fn(arm), warmup=1, reps=2)
        base = times["none"]
        for arm in ARMS:
            emit(f"e2e_{name}_{arm}", times[arm],
                 f"speedup_vs_base={base / times[arm]:.2f}")
