"""Fig. 9: compressed linear algebra — sum(X²) over DictCompressed vs
uncompressed (the generated operator runs over distinct dictionary values
only and aggregates via counts)."""

import jax.numpy as jnp
import numpy as np

from repro.core import FusionContext, fused
from repro.kernels.blocksparse import DictCompressed
from .common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)
    m, n = 200_000, 16
    # few distinct values per column (CLA's sweet spot)
    dense = rng.integers(0, 30, size=(m, n)).astype(np.float32) / 7.0
    Xc = DictCompressed.from_dense(dense)
    Xd = jnp.asarray(dense)

    @fused
    def sumsq(X):
        return (X ** 2).sum()

    hand = timeit(lambda: jnp.sum(Xd * Xd))
    with FusionContext(mode="gen"):
        ula = timeit(lambda: sumsq(Xd))
        cla = timeit(lambda: sumsq(Xc))
    emit("cla_sumsq_ula_hand", hand, "")
    emit("cla_sumsq_ula_gen", ula, "")
    emit("cla_sumsq_cla_gen", cla,
         f"speedup_vs_ula={ula / cla:.2f},ratio={Xc.compression_ratio:.2f}")
