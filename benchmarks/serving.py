"""Serving load test: N simulated clients against the fused-plan server.

The paper's economics (negligible optimization/codegen overhead because
plans amortize across invocations, §6.4/Fig. 11) only materialize if a
*serving* layer actually reuses compiled plans under concurrent traffic.
This harness measures that: ``N_CLIENTS`` threads fire l2svm/mlogreg
scoring requests with jittered row counts across ≥3 shape buckets at a
:class:`repro.serve.FusionServer`, once with continuous batching
(requests sharing a structural plan + shape class execute as one vmapped
whole-plan call) and once with per-request dispatch (``max_batch=1`` —
same compiled plans, no batching).  Emitted rows:

``serving_batched`` / ``serving_unbatched``
    Wall microseconds per request over the whole load run (completed
    requests / elapsed — i.e. 1e6/throughput).  ``serving_batched`` is
    the gated headline number; its ``derived`` column records the
    speedup over per-request dispatch and the mean batch occupancy.
``serving_batched_p50`` / ``_p95`` / ``_p99``
    Submit-to-result latency percentiles (µs) under the batched run.
``serving_hardened``
    The batched workload with the fault-tolerance machinery engaged
    (deadlines, retry budgets, bounded queue, circuit breaker, finite
    checks — see docs/robustness.md) and zero faults injected: the
    fault-free overhead of being prepared, gated like
    ``serving_batched``.

Both arms are warmed first (plan compile + every power-of-two batch
class) so the run measures serving, not XLA builds.  Before timing, the
harness asserts the batched/padded path is 1e-5-equal to direct region
execution for every bucket.

``--smoke`` runs a seconds-scale version (8 clients, 2 buckets) and
asserts nonzero throughput and zero failed/rejected requests — the CI
fast job's serving smoke.
"""

from __future__ import annotations

import sys
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.algos.l2svm import _hinge
from repro.algos.mlogreg import _probs
from repro.serve import FusionServer, percentiles

from .common import emit

#: full-load configuration (≥32 clients over ≥3 shape buckets).  Row
#: counts are scoring-batch sized: per-request payloads of tens of KB,
#: where per-call dispatch overhead (what batching amortizes) dominates
#: the extra stacking copy the batched path pays.
N_CLIENTS = 32
REQS_PER_CLIENT = 8
BUCKET_ROWS = (115, 240, 490)        # pad_to=128 → classes 128/256/512
N_FEATURES = 64
N_CLASSES = 5
PAD_TO = 128
MAX_BATCH = 8
WORKERS = 2


def harness_regions(rows=BUCKET_ROWS, n_features=N_FEATURES,
                    n_classes=N_CLASSES, seed=0):
    """``(label, region, operands)`` cases: the l2svm hinge and mlogreg
    softmax scoring regions at every row bucket.  Row counts sit off the
    pad boundary so the padded path is actually exercised.  Shared by
    the load run, the CI smoke, and ``tools/fusionlint.py --serving``
    (which strict-verifies exactly these plans)."""
    rng = np.random.default_rng(seed)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    cases = []
    for m in rows:
        X = f32(rng.standard_normal((m, n_features)))
        w = f32(rng.standard_normal((n_features, 1)))
        y = f32(rng.choice([-1.0, 1.0], (m, 1)))
        cases.append((f"l2svm_hinge_m{m}", _hinge,
                      {"X": X, "w": w, "y": y}))
        B = f32(rng.standard_normal((n_features, n_classes)))
        cases.append((f"mlogreg_probs_m{m}", _probs, {"X": X, "B": B}))
    return cases


def check_parity(server: FusionServer, cases, rtol=1e-5, atol=1e-5):
    """Batched/padded serving must be numerically equal (1e-5) to direct
    region execution for every case."""
    futs = [(label, server.submit(region, **ops), region(**ops))
            for label, region, ops in cases]
    for label, fut, ref in futs:
        got = np.asarray(fut.result(timeout=120))
        ref = np.asarray(ref)
        assert got.shape == ref.shape, \
            f"{label}: served shape {got.shape} != direct {ref.shape}"
        assert np.allclose(got, ref, rtol=rtol, atol=atol), \
            f"{label}: served result diverges from direct execution " \
            f"(max |Δ| = {np.abs(got - ref).max():.2e})"


def run_load(server: FusionServer, cases, n_clients: int,
             reqs_per_client: int) -> dict:
    """Drive ``n_clients`` threads × ``reqs_per_client`` requests (each
    picks a random case) and return throughput + latency summary."""
    errors: list[Exception] = []
    lock = threading.Lock()

    def client(k: int) -> None:
        rng = np.random.default_rng(10_000 + k)
        futs = []
        for _ in range(reqs_per_client):
            _label, region, ops = cases[int(rng.integers(len(cases)))]
            futs.append(server.submit(region, **ops))
        for f in futs:
            try:
                f.result(timeout=300)    # results are host arrays already
            except Exception as e:   # noqa: BLE001 - collected, asserted on
                with lock:
                    errors.append(e)

    threads = [threading.Thread(target=client, args=(k,), daemon=True)
               for k in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0

    total = n_clients * reqs_per_client
    snap = server.metrics.snapshot()
    lat = percentiles(server.metrics.latency_us.values())
    return {
        "requests": total,
        "elapsed_s": elapsed,
        "throughput_rps": total / elapsed,
        "us_per_req": elapsed / total * 1e6,
        "latency_us": lat,
        "occupancy_mean": snap["batches"]["occupancy_mean"],
        "failed": snap["requests"]["failed"] + len(errors),
        "rejected": snap["requests"]["rejected"],
        "errors": errors,
    }


#: the fault-tolerance machinery engaged for the ``serving_hardened``
#: arm: deadlines stamped per request, retry budgets, a bounded queue,
#: the circuit breaker, and per-request finite-checking — everything
#: docs/robustness.md describes, measured with zero faults injected so
#: the row is the pure overhead of being prepared.
HARDENED = dict(default_deadline_s=120.0, retry_budget=4,
                max_queue=4096, check_finite=True,
                breaker_threshold=3, breaker_cooldown_s=30.0)


def _serve_arm(cases, *, max_batch: int, pad_to: int, n_clients: int,
               reqs_per_client: int, parity: bool = False,
               **server_kwargs) -> dict:
    regions = [(region, ops) for _l, region, ops in cases]
    sizes = [b for b in (1, 2, 4, 8, 16, 32) if b <= max_batch]
    with FusionServer(workers=WORKERS, max_batch=max_batch,
                      pad_to=pad_to, **server_kwargs) as server:
        server.warm(regions, batch_sizes=tuple(sizes))
        if parity:
            check_parity(server, cases)
        return run_load(server, cases, n_clients, reqs_per_client)


def main(smoke: bool = False) -> None:
    if smoke:
        cases = harness_regions(rows=(60, 140), n_features=32, n_classes=3)
        batched = _serve_arm(cases, max_batch=4, pad_to=64, n_clients=8,
                             reqs_per_client=4, parity=True)
        assert batched["failed"] == 0, \
            f"serving smoke: {batched['failed']} failed requests " \
            f"({batched['errors'][:3]})"
        assert batched["rejected"] == 0
        assert batched["throughput_rps"] > 0
        print(f"serving smoke OK: {batched['requests']} requests, "
              f"{batched['throughput_rps']:.0f} req/s, p95 "
              f"{batched['latency_us']['p95']:.0f} us, occupancy "
              f"{batched['occupancy_mean']:.2f}", flush=True)
        return

    cases = harness_regions()
    batched = _serve_arm(cases, max_batch=MAX_BATCH, pad_to=PAD_TO,
                         n_clients=N_CLIENTS,
                         reqs_per_client=REQS_PER_CLIENT, parity=True)
    unbatched = _serve_arm(cases, max_batch=1, pad_to=0,
                           n_clients=N_CLIENTS,
                           reqs_per_client=REQS_PER_CLIENT)
    hardened = _serve_arm(cases, max_batch=MAX_BATCH, pad_to=PAD_TO,
                          n_clients=N_CLIENTS,
                          reqs_per_client=REQS_PER_CLIENT, parity=True,
                          **HARDENED)
    for arm in (batched, unbatched, hardened):
        assert arm["failed"] == 0, f"load run failed: {arm['errors'][:3]}"

    speedup = unbatched["us_per_req"] / batched["us_per_req"]
    overhead = hardened["us_per_req"] / batched["us_per_req"]
    emit("serving_batched", batched["us_per_req"],
         f"x{speedup:.2f}_vs_unbatched_occ{batched['occupancy_mean']:.1f}")
    emit("serving_unbatched", unbatched["us_per_req"],
         f"{unbatched['throughput_rps']:.0f}rps")
    # fault-free overhead of the self-healing configuration (deadlines,
    # retry budgets, bounded queue, breaker, finite checks) — gated by
    # compare.py so hardening cannot silently get expensive
    emit("serving_hardened", hardened["us_per_req"],
         f"x{overhead:.2f}_vs_batched")
    for q in ("p50", "p95", "p99"):
        emit(f"serving_batched_{q}", batched["latency_us"][q], "latency")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
