#!/usr/bin/env python
"""Compare two BENCH_fusion.json artifacts and gate on regressions.

Usage::

    python benchmarks/compare.py BASELINE CURRENT \\
        [--threshold 1.3] [--gate 'dispatch_chain*_whole_plan,serving_batched,serving_hardened']

Both files are ``repro-bench-v1`` artifacts (``benchmarks.run --json``).
Every row shared by both files is printed with its current/baseline
ratio; rows whose name matches any of the comma-separated ``--gate``
globs (default: the dispatch-overhead whole-plan medians plus the
serving-throughput median plus the hardened-serving overhead row —
the staged backend's headline numbers) additionally *gate* the run: any gated row slower than ``threshold ×``
its baseline, or missing from the current artifact, exits nonzero.
Each glob must also match at least one baseline row, so a renamed
benchmark cannot silently un-gate itself.  CI runs this against the
committed seed so a PR cannot regress whole-plan dispatch overhead or
serving throughput.

Absolute microbench timings move with the host, so the default gate is
deliberately loose (1.3×) and only guards order-of-magnitude claims —
the per-commit artifact diff, not this gate, is the fine-grained record.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "repro-bench-v1":
        sys.exit(f"compare: {path} is not a repro-bench-v1 artifact")
    return {r["name"]: r for r in doc["rows"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bench-compare")
    ap.add_argument("baseline", help="committed seed artifact")
    ap.add_argument("current", help="freshly measured artifact")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="fail when a gated row's us_per_call exceeds "
                         "threshold x baseline (default: 1.3)")
    ap.add_argument("--gate",
                    default="dispatch_chain*_whole_plan,serving_batched,"
                            "serving_hardened",
                    help="comma-separated globs of row names that gate "
                         "the run (default: dispatch-overhead whole-plan "
                         "rows + the serving-throughput median)")
    args = ap.parse_args(argv)

    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    globs = [g.strip() for g in args.gate.split(",") if g.strip()]

    def gated(name: str) -> bool:
        return any(fnmatch.fnmatch(name, g) for g in globs)

    failures: list[str] = []
    shared = sorted(set(base) & set(cur))
    print(f"{'name':42s} {'base us':>10s} {'cur us':>10s} {'ratio':>7s}")
    for name in shared:
        b, c = base[name]["us_per_call"], cur[name]["us_per_call"]
        ratio = c / b if b > 0 else float("inf")
        flag = ""
        if gated(name) and ratio > args.threshold:
            flag = f"  REGRESSION (> {args.threshold}x)"
            failures.append(f"{name}: {b:.1f} -> {c:.1f} us "
                            f"({ratio:.2f}x)")
        elif gated(name):
            flag = "  [gate]"
        print(f"{name:42s} {b:10.1f} {c:10.1f} {ratio:7.2f}{flag}")

    for name in sorted(base):
        if gated(name) and name not in cur:
            failures.append(f"{name}: present in baseline, missing from "
                            "current artifact")
    for g in globs:
        if not any(fnmatch.fnmatch(n, g) for n in base):
            failures.append(f"no baseline row matches gate {g!r} — "
                            "regenerate the seed artifact")

    if failures:
        print("\nbench-compare: FAIL")
        for f in failures:
            print(f"  {f}")
        return 1
    n_gated = sum(1 for n in shared if gated(n))
    print(f"\nbench-compare: OK — {n_gated} gated row(s) within "
          f"{args.threshold}x of the seed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
