"""Architecture registry: --arch <id> resolution."""

from __future__ import annotations

import importlib

from .base import ModelConfig

ARCH_IDS = [
    "grok-1-314b", "olmoe-1b-7b", "gemma3-27b", "yi-34b", "minitron-4b",
    "starcoder2-7b", "jamba-v0.1-52b", "xlstm-1.3b", "llava-next-34b",
    "musicgen-large",
]

_MODULES = {
    "grok-1-314b": "grok_1_314b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "gemma3-27b": "gemma3_27b",
    "yi-34b": "yi_34b",
    "minitron-4b": "minitron_4b",
    "starcoder2-7b": "starcoder2_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-large": "musicgen_large",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
