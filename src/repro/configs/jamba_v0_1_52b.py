"""jamba-v0.1-52b — 32L hybrid Mamba+attention 1:7 interleave, MoE 16e
top-2 [arXiv:2403.19887; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_period=2, block_type="jamba", attn_period=8,
    ssm_state=16, ssm_expand=2, ssm_conv=4, mlp_type="swiglu",
)
