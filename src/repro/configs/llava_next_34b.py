"""llava-next-34b — yi-34b backbone + anyres vision frontend STUB
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The assignment specifies the transformer backbone only; ``input_specs``
provides precomputed patch embeddings (B, n_patches, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000,
    mlp_type="swiglu", frontend="vision", rope_theta=5e6,
)
