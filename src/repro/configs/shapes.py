"""Assigned input-shape suite (LM transformer shapes, seq_len × batch)."""

from __future__ import annotations

from .base import ModelConfig, ShapeConfig

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}

#: archs with a sub-quadratic path for 500k-token decode (SSM/hybrid/
#: windowed); pure full-attention archs skip long_500k (see DESIGN.md §6).
_LONG_OK_FAMILIES = {"ssm", "hybrid"}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.kind == "long_decode":
        if cfg.family in _LONG_OK_FAMILIES:
            return True
        # gemma3: 5:1 local:global — local layers are windowed (sub-quad)
        return cfg.local_global_period > 0
    return True


def cells(configs: dict[str, ModelConfig]):
    """All live (arch × shape) dry-run cells."""
    out = []
    for name, cfg in configs.items():
        for shape in SHAPES.values():
            if applicable(cfg, shape):
                out.append((name, shape.name))
    return out
