"""Model & run configuration dataclasses."""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1         # MoE every p-th layer (jamba: 2), rest dense
    moe_impl: str = "dense"     # dense (masked) | ragged (sort + ragged_dot)

    # attention pattern
    sliding_window: int = 0     # 0 = global attention
    local_global_period: int = 0   # gemma3: 6 → 5 local + 1 global per period
    attn_chunk: int = 1024      # flash-style KV chunking (0 = dense scores)
    gqa_grouped: bool = False   # grouped-head einsum (no KV repeat) — §Perf

    # hybrid (jamba): one attention layer per `attn_period` layers, rest Mamba
    attn_period: int = 0
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4

    block_type: str = "transformer"   # transformer | jamba | xlstm
    mlp_type: str = "swiglu"          # swiglu | geglu | gelu | relu2
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # modality frontend stub (backbone-only per assignment)
    frontend: str = "none"            # none | vision | audio
    n_codebooks: int = 1              # musicgen EnCodec streams

    dtype: str = "bfloat16"
    # distribution/training knobs
    remat: bool = True
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def active_params(self) -> int:
        """Active parameters per token (MoE counts top_k experts)."""
        return _param_count(self, active_only=True)

    @property
    def total_params(self) -> int:
        return _param_count(self, active_only=False)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            sliding_window=min(self.sliding_window, 32) if
            self.sliding_window else 0,
            local_global_period=self.local_global_period and 2,
            attn_period=self.attn_period and 2,
            ssm_state=min(self.ssm_state, 8),
            attn_chunk=0,
            dtype="float32",
            remat=False,
        )


def _param_count(c: ModelConfig, active_only: bool) -> int:
    d, hd = c.d_model, c.hd
    attn = d * hd * c.n_heads + 2 * d * hd * c.n_kv_heads \
        + hd * c.n_heads * d
    if c.mlp_type in ("swiglu", "geglu"):
        mlp_dense = 3 * d * c.d_ff
    else:
        mlp_dense = 2 * d * c.d_ff
    if c.n_experts:
        e = c.top_k if active_only else c.n_experts
        moe = mlp_dense * e + d * c.n_experts      # router
        n_moe = c.n_layers // max(c.moe_period, 1)
        mlp_avg = (moe * n_moe + mlp_dense * (c.n_layers - n_moe)) \
            / c.n_layers
        mlp = mlp_avg
    else:
        mlp = mlp_dense
    if c.block_type == "jamba":
        di = c.ssm_expand * d
        mamba = d * 2 * di + di * c.ssm_conv + di * (2 * c.ssm_state + 2) \
            + di * d
        n_attn = c.n_layers // max(c.attn_period, 1)
        per = (attn + mlp) * n_attn + (mamba + mlp) * (c.n_layers - n_attn)
        return int(per + 2 * c.vocab * d)
    if c.block_type == "xlstm":
        di = c.ssm_expand * d
        per = (4 * d * di + 4 * di) * c.n_layers
        return per + 2 * c.vocab * d
    return int((attn + mlp + 2 * d) * c.n_layers
               + (1 if c.tie_embeddings else 2) * c.vocab * d)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")
