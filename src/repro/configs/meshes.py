"""Named production mesh shapes (axis name → size, ordered).

Pure data — no jax, no devices — so the layout planner, the sharding
property tests, and the dry-run CLI all agree on what "pod16x16" means
without constructing a real ``jax.sharding.Mesh`` (the sharding rules
only ever read ``.shape``/``.axis_names``).
"""

from __future__ import annotations

#: production mesh shapes: one v5e pod (16×16 = 256 chips) and the
#: two-pod DCN-linked variant used by the multipod dry-run cells.
MESH_SHAPES: dict[str, dict[str, int]] = {
    "pod16x16": {"data": 16, "model": 16},
    "multipod2x16x16": {"pod": 2, "data": 16, "model": 16},
}


def mesh_devices(name: str) -> int:
    out = 1
    for v in MESH_SHAPES[name].values():
        out *= v
    return out
