"""grok-1-314b — 64L MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, mlp_type="geglu", rope_theta=1e4,
)
