"""xlstm-1.3b — 48L sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    block_type="xlstm", ssm_expand=2,
)
