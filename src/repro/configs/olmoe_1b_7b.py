"""olmoe-1b-7b — 16L MoE 64e top-8 [arXiv:2409.02060; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, mlp_type="swiglu", rope_theta=1e4,
)
