"""musicgen-large — 48L decoder-only over EnCodec tokens (4 codebooks)
[arXiv:2306.05284; hf].  Audio frontend is a STUB: input_specs provides
the 4-stream token ids; embeddings are summed, output heads per stream."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048,
    mlp_type="gelu", norm_type="layernorm", frontend="audio",
    n_codebooks=4,
)
