from .base import ModelConfig, ShapeConfig
from .meshes import MESH_SHAPES, mesh_devices
from .registry import ARCH_IDS, all_configs, get_config
from .shapes import SHAPES, applicable, cells
