"""The paper's own flagship workload config (ALS-CG, rank 20) for the
end-to-end recommender example."""
from dataclasses import dataclass


@dataclass(frozen=True)
class ALSConfig:
    rank: int = 20
    lam: float = 1e-3
    max_iter: int = 10
    max_inner: int = 5
    block_size: int = 128


CONFIG = ALSConfig()
