"""gemma3-27b — 62L dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144,
    sliding_window=1024, local_global_period=6,   # 5 local + 1 global
    mlp_type="geglu", rope_theta=1e6,
)
