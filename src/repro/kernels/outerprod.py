"""Pallas TPU skeleton for the **Outer** (sparsity-exploiting) template.

SystemML's SpoofOuterProduct visits each non-zero scalar X_ij, computes
w = U_i·V_jᵀ, applies the generated chain and scatters w⊙V_j.  Scalar
gathers do not exist on TPU, so the adaptation is *block-level SDDMM*: the
grid runs over the non-zero (bs×bs) blocks of a row-major-sorted BCSR; a
scalar-prefetched index list steers the BlockSpec index maps so each step
gathers U[rows[b]], V[cols[b]] panels into VMEM, computes the bs×bs outer
product on the MXU, applies the fused chain, and

  * ``right_mm``  accumulates chain @ V[cols[b]] into out[rows[b]] —
    row-major sorting keeps the output block VMEM-resident across
    consecutive blocks of the same block-row;
  * ``full_agg``  accumulates a (1,1) scalar across all blocks;
  * ``no_agg``    writes the chain back as BCSR block data.

Asymptotics match the paper: work ∝ non-zero blocks, never m×n.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cplan import (CPlan, FULL_AGG, NO_AGG, RIGHT_MM)
from . import ref
from .blocksparse import BCSR


def outer_pallas(cplan: CPlan, env: dict[int, object], *,
                 interpret: bool = False):
    X: BCSR = env[cplan.main.nid]
    nb, bs = X.nblocks, X.bs
    m, n = X.shape
    variant = cplan.variant

    fu = _bind(cplan, env, "factor_u")
    fv = _bind(cplan, env, "factor_v")
    r = fu.shape[1]
    dtype = X.data.dtype

    # inputs: [rows, cols] scalar-prefetch, then data, U, V, sides...
    side_binds = [b for b in cplan.binds
                  if b.kind in ("side", "scalar")]
    sides = [jnp.asarray(env[b.nid]) for b in side_binds]

    def u_map(b, rows, cols):
        return (rows[b], 0)

    def v_map(b, rows, cols):
        return (cols[b], 0)

    in_specs = [
        pl.BlockSpec((1, bs, bs), lambda b, rows, cols: (b, 0, 0)),  # X data
        pl.BlockSpec((bs, r), u_map),                                # U
        pl.BlockSpec((bs, r), v_map),                                # V
    ]
    for b_, s in zip(side_binds, sides):
        sr, sc = s.shape
        if (sr, sc) == (1, 1):
            in_specs.append(pl.BlockSpec((1, 1), lambda b, rows, cols: (0, 0)))
        elif (sr, sc) == (m, n):
            in_specs.append(pl.BlockSpec(
                (bs, bs), lambda b, rows, cols: (rows[b], cols[b])))
        elif sc == 1 and sr == m:
            in_specs.append(pl.BlockSpec((bs, 1), u_map))
        elif sr == 1 and sc == n:
            in_specs.append(pl.BlockSpec(
                (1, bs), lambda b, rows, cols: (0, cols[b])))
        else:
            raise NotImplementedError(f"outer side input {s.shape}")
    nid_to_pos = {b.nid: i + 3 for i, b in enumerate(side_binds)}

    if variant == RIGHT_MM:
        closer = _dense(env[cplan.close_nid])
        if cplan.close_tb:
            closer = closer.T
        k_out = closer.shape[1]
        in_specs.append(pl.BlockSpec((bs, k_out), v_map))   # V-side gather
        out_spec = pl.BlockSpec((bs, k_out), u_map)
        out_shape = jax.ShapeDtypeStruct((m, k_out), dtype)
    elif variant == FULL_AGG:
        closer = None
        out_spec = pl.BlockSpec((1, 1), lambda b, rows, cols: (0, 0))
        out_shape = jax.ShapeDtypeStruct((1, 1), dtype)
    elif variant == NO_AGG:
        closer = None
        out_spec = pl.BlockSpec((1, bs, bs), lambda b, rows, cols: (b, 0, 0))
        out_shape = jax.ShapeDtypeStruct((nb, bs, bs), dtype)
    else:
        raise NotImplementedError(f"pallas outer variant {variant}")

    mm_nid = _outer_mm_nid(cplan)

    def kernel(rows, cols, *refs):
        if variant == RIGHT_MM:
            *ins, cls, out = refs
        else:
            *ins, out = refs
            cls = None
        xb = ins[0][0]                       # (bs, bs)
        ub = ins[1][...]                     # (bs, r)
        vb = ins[2][...]                     # (bs, r)

        def read(nid: int):
            if nid == cplan.main.nid:
                return xb
            return ins[nid_to_pos[nid]][...]

        vals: dict[int, jnp.ndarray] = {}
        for (nid, op, ins_k, _shape, attrs) in cplan.prog:
            if nid == mm_nid:
                vals[nid] = jax.lax.dot_general(
                    ub, vb, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32).astype(dtype)
                continue
            argv = [vals[ref_] if kind == "n" else
                    (read(ref_) if kind == "b" else ref_)
                    for kind, ref_ in ins_k]
            vals[nid] = ref.eval_node(op, argv, dict(attrs))
        chain = (vals[cplan.prog_root] if cplan.prog_root in vals
                 else read(cplan.prog_root))

        b = pl.program_id(0)
        if variant == FULL_AGG:
            part = jnp.sum(chain).reshape(1, 1).astype(dtype)
            first = b == 0

            @pl.when(first)
            def _():
                out[...] = part

            @pl.when(jnp.logical_not(first))
            def _():
                out[...] = out[...] + part
        elif variant == NO_AGG:
            out[0] = chain.astype(dtype)
        else:                                 # RIGHT_MM
            contrib = jax.lax.dot_general(
                chain, cls[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(dtype)
            prev = rows[jnp.maximum(b - 1, 0)]
            first = jnp.logical_or(b == 0, rows[b] != prev)

            @pl.when(first)
            def _():
                out[...] = contrib

            @pl.when(jnp.logical_not(first))
            def _():
                out[...] = out[...] + contrib

    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(nb,), in_specs=in_specs,
        out_specs=out_spec)
    args = [X.data, _dense(fu), _dense(fv)] + sides
    if variant == RIGHT_MM:
        args.append(closer)
    out = pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                         interpret=interpret)(X.rows, X.cols, *args)
    if variant == RIGHT_MM:
        # rows may not cover every block-row; zero rows handled by scatter
        # semantics of revisit-accumulate only for visited rows: fix by
        # masking unvisited rows to zero.
        visited = jnp.zeros((m // bs,), jnp.bool_).at[X.rows].set(True)
        out = jnp.where(jnp.repeat(visited, bs)[:, None], out, 0)
    if variant == NO_AGG:
        return BCSR(out, X.rows, X.cols, X.shape, bs)
    return out


def _bind(cplan: CPlan, env, kind: str):
    for b in cplan.binds:
        if b.kind == kind:
            return _dense(env[b.nid])
    raise KeyError(kind)


def _dense(v):
    return v.todense() if hasattr(v, "todense") else jnp.asarray(v)


def _outer_mm_nid(cplan: CPlan):
    for (nid, op, _ins, _shape, attrs) in cplan.prog:
        if op == "matmul":
            return nid
    return -1
