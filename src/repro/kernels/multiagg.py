"""Pallas TPU skeleton for the **MAgg** (multi-aggregate) template.

k full aggregates over shared inputs evaluate in a single pass: one grid
over the shared main input's tiles, k program roots interpreted on the same
resident tiles, k accumulators in a (k,1) output block (paper Fig. 1(c):
sum(X⊙Y), sum(X⊙Z), sum(X²) share one scan of X).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cplan import CPlan
from . import ref
from .cellwise import pick_block, _tile_spec, _COMB


def multiagg_pallas(cplan: CPlan, env: dict[int, jnp.ndarray], *,
                    interpret: bool = False,
                    block: tuple[int, int] = (256, 512)) -> jnp.ndarray:
    main = env[cplan.main.nid]
    m, n = main.shape
    bm, bn = pick_block(m, block[0]), pick_block(n, block[1])

    roots = [cplan.prog_root] + [r for r, _ in cplan.extra]
    aggs = [cplan.agg_op] + [op for _, op in cplan.extra]
    k = len(roots)

    binds = list(cplan.binds)
    arrays = [jnp.asarray(env[b.nid]) for b in binds]
    dtype = arrays[0].dtype
    in_specs = [_tile_spec(a.shape, m, n, bm, bn, False) for a in arrays]
    nid_to_pos = {b.nid: i for i, b in enumerate(binds)}

    def kernel(*refs):
        *ins, out = refs
        read = lambda nid: ins[nid_to_pos[nid]][...]
        vals = ref.apply_program(cplan, read, roots)
        parts = [jnp.sum(v) if a in ("sum", "mean") else
                 (jnp.min(v) if a == "min" else jnp.max(v))
                 for v, a in zip(vals, aggs)]
        part = jnp.stack(parts).reshape(k, 1).astype(dtype)
        first = jnp.logical_and(pl.program_id(0) == 0, pl.program_id(1) == 0)

        @pl.when(first)
        def _init():
            out[...] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            old = out[...]
            new = [jnp.asarray(_COMB[a](old[i, 0], part[i, 0]))
                   for i, a in enumerate(aggs)]
            out[...] = jnp.stack(new).reshape(k, 1)

    out = pl.pallas_call(
        kernel, grid=(m // bm, n // bn), in_specs=in_specs,
        out_specs=pl.BlockSpec((k, 1), lambda o, i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), dtype),
        interpret=interpret)(*arrays)
    scale = jnp.array([[1.0 / (m * n)] if a == "mean" else [1.0]
                       for a in aggs], dtype)
    return out * scale
