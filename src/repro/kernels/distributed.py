"""Distributed execution of generated fused operators (``shard_map``).

The distributed variant of a template runs the *same* generated operator
body as the local one — the CPlan program interpreted at trace time into
one fused computation — but over a row shard of its iteration domain,
mapped across the mesh's data/FSDP axes with ``shard_map``.  With
``pallas`` enabled the body lowers through the template skeletons
(:mod:`repro.kernels.cellwise` / ``rowwise`` / ``multiagg`` /
``outerprod``) whose grids and BlockSpecs are derived from the
*shard-local* shapes the ``shard_map`` body sees, so the generated
kernels execute as ``pallas_call`` **inside** the region instead of
falling back to XLA.  What differs per template is only the wiring the
plan's :class:`~repro.core.cost.Placement` prescribes:

* **in_specs** — operands the placement marked ``sharded`` (row-aligned
  with the iteration domain) arrive as ``P(axes, None)`` row panels;
  block-sparse sharded mains arrive as
  :class:`~repro.kernels.blocksparse.ShardedBCSR` (block-row-partitioned
  outside ``jit``, leading axis sharded).  Everything else (side-input
  row vectors, scalars, the narrow matmul operands of Row/Outer
  closures) is broadcast replicated — ``shard_map`` performs the
  all-gather the cost model charged for layout-sharded side inputs.
* **epilogue** — ``"none"`` variants write their own output row panel
  (``out_specs = P(axes, None)``); ``"psum"``/``"pmin"``/``"pmax"``
  variants produce per-shard partials completed by the matching
  ``jax.lax`` collective and replicate the reduced result (multi-
  aggregates ride one ``psum`` of the stacked (k, 1) output).

**Multi-operator bodies**: a plan :class:`~repro.core.select.Segment` —
a maximal run of adjacent distributed-placed operators — lowers to *one*
``shard_map`` region whose body runs every member's generated program in
order over the local row panels.  A row-partitioned intermediate
(``"none"`` epilogue) consumed inside the segment simply stays a local
panel: no global materialization, no gather/re-scatter at the operator
boundary.  Reduced intermediates complete their collective inside the
body and flow replicated.  Only segment *outputs* exit the region.

Lowering is split into two stages so every downgrade is an explicit,
observable decision rather than a silent ``None``:

* :func:`plan_segment` runs eagerly at compile time and validates the
  placement against the mesh (realizable axes, divisible shards).  It
  returns a :class:`SegmentPlan`, or a :class:`SegmentFallback` carrying
  the human-readable reason the body must run locally (abstract
  ``LogicalMesh``, axis mismatch, indivisible rows, …).
* :func:`lower_segment` runs at trace time with the actual bound values
  and builds the ``shard_map`` callable — choosing per-operand in_specs
  from the value formats — or returns a :class:`SegmentFallback` when a
  format cannot be sharded (e.g. a sparse intermediate materialized
  under trace, which cannot be re-bucketed by concrete row index).

Callers record every ``SegmentFallback`` in the compiled plan's fallback
log (surfaced through ``explain()['execution']['fallbacks']``, checked
by the EXE005 verifier invariant and ``fusionlint --strict``); local
execution remains numerically identical by construction, since the
epilogue collectives are exact.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from repro import faults
from repro.core.cplan import CPlan, NO_AGG
from repro.core.partitions import PlanInvariantError
from . import ops as kops
from .blocksparse import BCSR, DictCompressed, ShardedBCSR, \
    partition_block_rows

faults.register_site(
    "dist.segment",
    "distributed segment planning (plan_segment): eager compile-time "
    "validation of a shard_map segment against the mesh",
    kinds=("error", "latency"),
    handler="an injected error degrades to SegmentFallback — the caller "
            "records it via CompiledPlan.record_fallback (EXE005) and "
            "the members run as local fused steps, numerically exact")

#: structural cache of compiled shard_map operators — the distributed
#: analogue of the plan cache: ``jax.jit`` memoizes per function object,
#: so rebuilding the closure every CompiledPlan (e.g. ``fuse_exprs`` in a
#: loop) would retrace+recompile each call.  Keyed by (structural CPlan
#: hash, mesh, epilogue, axes, per-bind shard mask, pallas mode, operand
#: pytree structure) — the mesh and the value formats are part of the
#: key, so one CompiledPlan re-targeted at a different real mesh (or fed
#: a sparse operand where a dense one was compiled) can never be served
#: a stale executable; bounded LRU.
_FN_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_FN_CACHE_MAX = 256
_FN_LOCK = threading.Lock()


def _collective(epilogue: str, axes) -> Optional[Callable]:
    if epilogue == "psum":
        return lambda x: jax.lax.psum(x, axes)
    if epilogue == "pmin":
        return lambda x: jax.lax.pmin(x, axes)
    if epilogue == "pmax":
        return lambda x: jax.lax.pmax(x, axes)
    return None                                    # "none": sharded write


@dataclass(frozen=True)
class SegmentItem:
    """One operator of a shard_map segment body."""
    cplan: CPlan
    placement: object              # repro.core.cost.Placement
    roots: tuple[int, ...]         # output nids (>1: combined multi-agg)
    export: bool                   # value leaves the region?


@dataclass(frozen=True)
class SegmentFallback:
    """An explicit 'this segment runs locally' decision with its reason.

    Replaces the old silent ``return None``: callers record the reason
    in the compiled plan's fallback log so ``explain()`` and
    ``fusionlint --strict`` can prove no downgrade went unexplained."""
    reason: str


@dataclass
class SegmentPlan:
    """Mesh-validated segment metadata, ready to lower at trace time."""
    items: tuple                     # tuple[SegmentItem]
    axes: tuple                      # realized mesh axis names
    n: int                           # shard count
    ext: tuple                       # external bind nids, in order
    ext_shard: dict                  # nid -> row-sharded?
    epilogues: tuple                 # exported items' epilogues
    #: per-item shard-local main-row count — the row-partitioned shape
    #: the Pallas template lowerings derive their BlockSpecs from
    shard_rows: tuple = ()
    cache_token: tuple = field(default=(), repr=False)


def _realizable_axes(mesh, placement):
    """(axes, ok): the placement's row-shard axes on this mesh, or ok=False
    when the runtime cannot realize the plan's shard group."""
    from repro.dist.sharding import axis_size
    axes = tuple(a for a in placement.axes if a in mesh.axis_names)
    if not axes or axis_size(mesh, axes) != placement.n:
        return (), False
    return axes, True


def plan_segment(items: list[SegmentItem], mesh):
    """Validate one plan segment (≥1 distributed operators in dependency
    order) against the mesh → :class:`SegmentPlan`, or a
    :class:`SegmentFallback` naming why the body must run locally.

    Raises :class:`~repro.core.partitions.PlanInvariantError` when the
    segment itself is malformed (an operand both sharded and broadcast
    across members), which ``annotate_segments`` never emits."""
    try:
        faults.fault_point("dist.segment")
    except faults.FaultInjected as e:
        return SegmentFallback(f"injected fault: {e}")
    try:
        from jax.sharding import Mesh
    except ImportError:                            # pragma: no cover
        return SegmentFallback("jax.sharding unavailable in this runtime")
    if not items:
        return SegmentFallback("empty segment")
    if not isinstance(mesh, Mesh):
        return SegmentFallback(
            "abstract mesh (cost-only layout): distributed placement is "
            "costed and reported but the body runs locally")
    axes, ok = _realizable_axes(mesh, items[0].placement)
    if not ok:
        return SegmentFallback(
            f"mesh cannot realize shard axes {items[0].placement.axes!r} "
            f"x {items[0].placement.n} shards")
    n = items[0].placement.n

    produced: set[int] = set()
    ext: list[int] = []
    ext_shard: dict[int, bool] = {}
    for it in items:
        ax_it, ok = _realizable_axes(mesh, it.placement)
        if not ok or ax_it != axes:
            return SegmentFallback(
                f"member shard axes {it.placement.axes!r} diverge from "
                f"segment axes {axes!r}")
        for b in it.cplan.binds:
            if b.nid in produced:
                continue                           # intra-segment edge
            sh = b.nid in it.placement.sharded
            if b.nid in ext_shard:
                if ext_shard[b.nid] != sh:
                    # annotate_segments only groups members with one
                    # consistent view of each external operand, so
                    # reaching this means the plan was corrupted after
                    # selection — fail loudly, not fall back
                    raise PlanInvariantError(
                        f"segment operand %{b.nid} is row-sharded for "
                        f"one member and broadcast for another — "
                        f"inconsistent shard view inside one region")
                continue
            if sh and b.shape[0] % n:
                return SegmentFallback(            # defensive: plan drift
                    f"sharded operand %{b.nid} rows {b.shape[0]} not "
                    f"divisible across {n} shards")
            ext.append(b.nid)
            ext_shard[b.nid] = sh
        produced.update(it.roots)

    if not any(it.export for it in items):
        return SegmentFallback("segment exports no value")
    epilogues = tuple(it.placement.epilogue for it in items if it.export)
    shard_rows = tuple(
        it.cplan.main.shape[0] // n
        if it.cplan.main.nid in it.placement.sharded
        else it.cplan.main.shape[0]
        for it in items)
    token = (tuple(it.cplan.cache_key() for it in items), mesh, axes,
             tuple(ext), tuple(sorted(ext_shard.items())),
             tuple((it.placement.epilogue, it.export, it.roots)
                   for it in items))
    return SegmentPlan(tuple(items), axes, n, tuple(ext), ext_shard,
                       epilogues, shard_rows, token)


def _replicated_spec(value, P):
    """An in_specs entry replicating ``value``: P() per pytree leaf (a
    plain P() for dense arrays; a matching pytree of P() for sparse
    formats so ``shard_map`` sees one spec per leaf)."""
    leaves, treedef = jax.tree_util.tree_flatten(value)
    if not isinstance(value, (ShardedBCSR, BCSR, DictCompressed)):
        return P()
    return jax.tree_util.tree_unflatten(treedef, [P()] * len(leaves))


def lower_segment(sp: SegmentPlan, mesh, values=None, *,
                  pallas: str = "never"):
    """Build the ``shard_map`` callable for a validated segment, choosing
    per-operand in_specs from the actual bound value formats (``values``
    None = all dense).  Returns the *unjitted* callable taking the
    external bind values in ``sp.ext`` order, or a
    :class:`SegmentFallback` when a value format cannot be sharded (the
    caller records the reason and runs the members locally — numerically
    identical, collectives are exact)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    axes = sp.axes
    if values is None:
        values = [None] * len(sp.ext)
    in_specs = []
    for nid, v in zip(sp.ext, values):
        if not sp.ext_shard[nid]:
            if isinstance(v, ShardedBCSR):
                return SegmentFallback(
                    f"replicated operand %{nid} arrived pre-partitioned")
            in_specs.append(_replicated_spec(v, P))
            continue
        if isinstance(v, ShardedBCSR):
            if v.nparts != sp.n:
                return SegmentFallback(
                    f"sparse operand %{nid} partitioned into {v.nparts} "
                    f"shards but the mesh has {sp.n}")
            in_specs.append(jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(v),
                [P(axes, *([None] * (leaf.ndim - 1)))
                 for leaf in jax.tree_util.tree_leaves(v)]))
        elif isinstance(v, (BCSR, DictCompressed)):
            return SegmentFallback(
                f"row-sharded operand %{nid} is "
                f"{type(v).__name__} under trace: block partitioning "
                f"needs concrete row indices (outside jit)")
        else:
            in_specs.append(P(axes, None))

    # a sparse-main no_agg export would have to re-assemble a global
    # BCSR across the region boundary — not representable as out_specs
    for it in sp.items:
        if not it.export or it.cplan.variant != NO_AGG:
            continue
        mv = values[sp.ext.index(it.cplan.main.nid)] \
            if it.cplan.main.nid in sp.ext else None
        if isinstance(mv, ShardedBCSR) and it.cplan.main.exploit:
            return SegmentFallback(
                f"sparse no_agg output of %{it.roots[0]} cannot cross "
                f"the shard_map boundary")

    out_specs = tuple(P(axes, None) if it.placement.epilogue == "none"
                      else P() for it in sp.items if it.export)
    steps = [(it.cplan, [b.nid for b in it.cplan.binds],
              _collective(it.placement.epilogue, axes), it.roots,
              it.export, m_loc)
             for it, m_loc in zip(sp.items, sp.shard_rows)]

    def body(*arrs):
        # each member's generated operator body on the local row panels;
        # intra-segment "none" outputs stay local panels.  Sharded BCSR
        # mains arrive as one-shard ShardedBCSR — squeeze to the local
        # block list; the template lowerings then derive their grids and
        # BlockSpecs from these shard-local shapes.
        env = {nid: (v.local_bcsr() if isinstance(v, ShardedBCSR) else v)
               for nid, v in zip(sp.ext, arrs)}
        outs = []
        for cplan, nids, reduce_fn, roots, export, m_loc in steps:
            out = kops.execute(cplan, {nid: env[nid] for nid in nids},
                               pallas=pallas, shard_rows=m_loc)
            if reduce_fn is not None:
                out = reduce_fn(out)
            if len(roots) > 1:                     # combined multi-agg
                for k, r in enumerate(roots):
                    env[r] = out[k].reshape(1, 1)
            else:
                env[roots[0]] = out
            if export:
                outs.append(out)
        return tuple(outs)

    return shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=out_specs, check_rep=False)


def prepare_segment_values(sp: SegmentPlan, values):
    """Partition concrete row-sharded BCSR operands into
    :class:`ShardedBCSR` (must run *outside* jit — re-bucketing needs
    concrete block-row indices).  Returns ``(prepared, fallback)``;
    ``fallback`` is a :class:`SegmentFallback` when a sparse operand
    cannot be partitioned (tracer or indivisible block rows), in which
    case ``prepared`` is the original values for local execution."""
    prepared = list(values)
    for i, (nid, v) in enumerate(zip(sp.ext, values)):
        if not sp.ext_shard[nid] or not isinstance(v, BCSR):
            continue
        part = partition_block_rows(v, sp.n)
        if part is None:
            return list(values), SegmentFallback(
                f"sparse operand %{nid}: {v.shape[0] // v.bs} block rows "
                f"not partitionable across {sp.n} shards")
        prepared[i] = part
    return prepared, None


def run_segment_local(sp: SegmentPlan, values, *, pallas: str = "never"):
    """Execute the segment's members locally on global values (the
    recorded-fallback path): same programs, no collectives needed since
    every value is whole.  Returns exported outputs in item order."""
    env = {nid: (v.unshard() if isinstance(v, ShardedBCSR) else v)
           for nid, v in zip(sp.ext, values)}
    outs = []
    for it in sp.items:
        out = kops.execute(
            it.cplan, {b.nid: env[b.nid] for b in it.cplan.binds},
            pallas=pallas)
        if len(it.roots) > 1:
            for k, r in enumerate(it.roots):
                env[r] = out[k].reshape(1, 1)
        else:
            env[it.roots[0]] = out
        if it.export:
            outs.append(out)
    return tuple(outs)


def build_segment_fn(items: list[SegmentItem], mesh, *,
                     pallas: str = "never", values=None):
    """Plan + lower in one eager step for callers holding concrete (or
    all-dense) values.  Returns ``(fn, ext_nids, epilogues)`` or a
    :class:`SegmentFallback` naming why the body must run locally."""
    sp = plan_segment(items, mesh)
    if isinstance(sp, SegmentFallback):
        return sp
    fn = lower_segment(sp, mesh, values, pallas=pallas)
    if isinstance(fn, SegmentFallback):
        return fn
    return fn, sp.ext, sp.epilogues


def build_dist_fn(cplan: CPlan, mesh, placement, *, pallas: str = "never",
                  values=None):
    """Compile one distributed fused operator for the per-operator
    dispatch path.  Returns ``(fn, None)`` with the jitted callable —
    taking the *prepared* bound values in ``cplan.binds`` order — or
    ``(None, SegmentFallback)`` naming why the placement cannot execute
    distributed here (the caller records the reason and runs the local
    generated operator; whole-plan staged execution lowers runs of
    adjacent distributed operators through :func:`plan_segment` /
    :func:`lower_segment` instead)."""
    roots = getattr(cplan, "roots", None) or (cplan.prog_root,)
    sp = plan_segment(
        [SegmentItem(cplan, placement, tuple(roots), True)], mesh)
    if isinstance(sp, SegmentFallback):
        return None, sp
    if values is None:
        values = [None] * len(sp.ext)
    prepared, fb = prepare_segment_values(sp, values)
    if fb is not None:
        return None, fb

    # structural hit: a re-traced or structurally-equal plan reuses the
    # jitted shard_map operator (binding is positional, like GeneratedOp)
    shard_mask = tuple(b.nid in placement.sharded for b in cplan.binds)
    fmt = jax.tree_util.tree_structure(tuple(prepared))
    key = (cplan.cache_key(), mesh, placement.epilogue, sp.axes,
           shard_mask, pallas, fmt)
    with _FN_LOCK:
        hit = _FN_CACHE.get(key)
        if hit is not None:
            _FN_CACHE.move_to_end(key)
            return (hit, prepared), None

    seg_fn = lower_segment(sp, mesh, prepared, pallas=pallas)
    if isinstance(seg_fn, SegmentFallback):
        return None, seg_fn
    assert sp.ext == tuple(b.nid for b in cplan.binds)
    fn = jax.jit(lambda *vals: seg_fn(*vals)[0])
    with _FN_LOCK:
        _FN_CACHE[key] = fn
        while len(_FN_CACHE) > _FN_CACHE_MAX:
            _FN_CACHE.popitem(last=False)
    return (fn, prepared), None
