"""Distributed execution of generated fused operators (``shard_map``).

The distributed variant of a template runs the *same* generated operator
body as the local one — the CPlan program interpreted at trace time into
one fused XLA computation (:mod:`repro.kernels.ref`) — but over a row
shard of its iteration domain, mapped across the mesh's data/FSDP axes
with ``shard_map``.  What differs per template is only the wiring the
plan's :class:`~repro.core.cost.Placement` prescribes:

* **in_specs** — operands the placement marked ``sharded`` (row-aligned
  with the iteration domain) arrive as ``P(axes, None)`` row panels;
  everything else (side-input row vectors, scalars, the narrow matmul
  operands of Row/Outer closures) is broadcast replicated — ``shard_map``
  performs the all-gather the cost model charged for layout-sharded side
  inputs.
* **epilogue** — ``"none"`` variants write their own output row panel
  (``out_specs = P(axes, None)``); ``"psum"``/``"pmin"``/``"pmax"``
  variants produce per-shard partials completed by the matching
  ``jax.lax`` collective and replicate the reduced result (multi-
  aggregates ride one ``psum`` of the stacked (k, 1) output).

Only *real* multi-device meshes execute here; on an abstract
``LogicalMesh`` (planning from a CPU container) or when an operand is
block-sparse, the plan's distributed placement is costed and reported but
the body runs locally — numerically identical by construction, since the
epilogue collectives are exact.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Optional

import jax

from repro.core.cplan import CPlan
from . import ref

#: structural cache of compiled shard_map operators — the distributed
#: analogue of the plan cache: ``jax.jit`` memoizes per function object,
#: so rebuilding the closure every CompiledPlan (e.g. ``fuse_exprs`` in a
#: loop) would retrace+recompile each call.  Keyed by (structural CPlan
#: hash, mesh, epilogue, axes, per-bind shard mask); bounded LRU.
_FN_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_FN_CACHE_MAX = 256
_FN_LOCK = threading.Lock()


def _collective(epilogue: str, axes) -> Optional[Callable]:
    if epilogue == "psum":
        return lambda x: jax.lax.psum(x, axes)
    if epilogue == "pmin":
        return lambda x: jax.lax.pmin(x, axes)
    if epilogue == "pmax":
        return lambda x: jax.lax.pmax(x, axes)
    return None                                    # "none": sharded write


def build_dist_fn(cplan: CPlan, mesh, placement) -> Optional[Callable]:
    """Compile one distributed fused operator, or None when the runtime
    cannot realize the placement (abstract mesh, axis mismatch, or a
    shard that would not divide) — the caller then falls back to the
    local generated operator.

    The returned callable takes the bound input arrays in ``cplan.binds``
    order and returns the operator output as a global array (row-sharded
    for "none" epilogues, replicated for reductions)."""
    try:
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
    except ImportError:                            # pragma: no cover
        return None
    if not isinstance(mesh, Mesh):
        return None                                # abstract: cost-only
    from repro.dist.sharding import axis_size
    axes = tuple(a for a in placement.axes if a in mesh.axis_names)
    n = axis_size(mesh, axes)
    if not axes or n != placement.n:
        return None
    for b in cplan.binds:
        if b.nid in placement.sharded and b.shape[0] % n:
            return None                            # defensive: plan drift

    # structural hit: a re-traced or structurally-equal plan reuses the
    # jitted shard_map operator (binding is positional, like GeneratedOp)
    shard_mask = tuple(b.nid in placement.sharded for b in cplan.binds)
    key = (cplan.cache_key(), mesh, placement.epilogue, axes, shard_mask)
    with _FN_LOCK:
        hit = _FN_CACHE.get(key)
        if hit is not None:
            _FN_CACHE.move_to_end(key)
            return hit

    in_specs = tuple(P(axes, None) if m else P() for m in shard_mask)
    reduce_fn = _collective(placement.epilogue, axes)
    out_specs = P() if reduce_fn is not None else P(axes, None)
    nids = [b.nid for b in cplan.binds]

    def body(*arrs):
        # the generated operator body, verbatim, on the local row panel
        out = ref.execute_dense(cplan, dict(zip(nids, arrs)))
        return reduce_fn(out) if reduce_fn is not None else out

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False))
    with _FN_LOCK:
        _FN_CACHE[key] = fn
        while len(_FN_CACHE) > _FN_CACHE_MAX:
            _FN_CACHE.popitem(last=False)
    return fn
