"""Distributed execution of generated fused operators (``shard_map``).

The distributed variant of a template runs the *same* generated operator
body as the local one — the CPlan program interpreted at trace time into
one fused XLA computation (:mod:`repro.kernels.ref`) — but over a row
shard of its iteration domain, mapped across the mesh's data/FSDP axes
with ``shard_map``.  What differs per template is only the wiring the
plan's :class:`~repro.core.cost.Placement` prescribes:

* **in_specs** — operands the placement marked ``sharded`` (row-aligned
  with the iteration domain) arrive as ``P(axes, None)`` row panels;
  everything else (side-input row vectors, scalars, the narrow matmul
  operands of Row/Outer closures) is broadcast replicated — ``shard_map``
  performs the all-gather the cost model charged for layout-sharded side
  inputs.
* **epilogue** — ``"none"`` variants write their own output row panel
  (``out_specs = P(axes, None)``); ``"psum"``/``"pmin"``/``"pmax"``
  variants produce per-shard partials completed by the matching
  ``jax.lax`` collective and replicate the reduced result (multi-
  aggregates ride one ``psum`` of the stacked (k, 1) output).

**Multi-operator bodies** (:func:`build_segment_fn`): a plan
:class:`~repro.core.select.Segment` — a maximal run of adjacent
distributed-placed operators — lowers to *one* ``shard_map`` region whose
body runs every member's generated program in order over the local row
panels.  A row-partitioned intermediate (``"none"`` epilogue) consumed
inside the segment simply stays a local panel: no global materialization,
no gather/re-scatter at the operator boundary.  Reduced intermediates
(``psum``/``pmin``/``pmax``) complete their collective inside the body and
flow replicated.  Only segment *outputs* — values a spec outside the
segment (or the caller) reads — exit the region, sharded or replicated per
their epilogue.

Only *real* multi-device meshes execute here; on an abstract
``LogicalMesh`` (planning from a CPU container) or when an operand is
block-sparse, the plan's distributed placement is costed and reported but
the body runs locally — numerically identical by construction, since the
epilogue collectives are exact.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from repro.core.cplan import CPlan
from repro.core.partitions import PlanInvariantError
from . import ref

#: structural cache of compiled shard_map operators — the distributed
#: analogue of the plan cache: ``jax.jit`` memoizes per function object,
#: so rebuilding the closure every CompiledPlan (e.g. ``fuse_exprs`` in a
#: loop) would retrace+recompile each call.  Keyed by (structural CPlan
#: hash, mesh, epilogue, axes, per-bind shard mask) — the mesh is part of
#: the key, so one CompiledPlan re-targeted at a different real mesh can
#: never be served a stale executable; bounded LRU.
_FN_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_FN_CACHE_MAX = 256
_FN_LOCK = threading.Lock()


def _collective(epilogue: str, axes) -> Optional[Callable]:
    if epilogue == "psum":
        return lambda x: jax.lax.psum(x, axes)
    if epilogue == "pmin":
        return lambda x: jax.lax.pmin(x, axes)
    if epilogue == "pmax":
        return lambda x: jax.lax.pmax(x, axes)
    return None                                    # "none": sharded write


@dataclass(frozen=True)
class SegmentItem:
    """One operator of a shard_map segment body."""
    cplan: CPlan
    placement: object              # repro.core.cost.Placement
    roots: tuple[int, ...]         # output nids (>1: combined multi-agg)
    export: bool                   # value leaves the region?


def _realizable_axes(mesh, placement):
    """(axes, ok): the placement's row-shard axes on this mesh, or ok=False
    when the runtime cannot realize the plan's shard group."""
    from repro.dist.sharding import axis_size
    axes = tuple(a for a in placement.axes if a in mesh.axis_names)
    if not axes or axis_size(mesh, axes) != placement.n:
        return (), False
    return axes, True


def build_segment_fn(items: list[SegmentItem], mesh):
    """Lower one plan segment (≥1 distributed operators in dependency
    order) into a single ``shard_map`` region.

    Returns ``(fn, ext_nids, epilogues)`` — ``fn`` is the *unjitted*
    ``shard_map`` callable taking the external bind arrays in ``ext_nids``
    order and returning the exported items' outputs in item order (each
    sharded ``P(axes, None)`` for a ``"none"`` epilogue, replicated
    otherwise); ``epilogues`` lists the exported epilogues.  Returns None
    when the mesh cannot realize the placement (abstract mesh, axis
    mismatch, indivisible external shard — the caller then falls back to
    per-operator execution); raises
    :class:`~repro.core.partitions.PlanInvariantError` when the segment
    itself is malformed (an operand both sharded and broadcast across
    members), which :func:`repro.core.select.annotate_segments` never
    emits."""
    try:
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
    except ImportError:                            # pragma: no cover
        return None
    if not isinstance(mesh, Mesh) or not items:
        return None
    axes, ok = _realizable_axes(mesh, items[0].placement)
    if not ok:
        return None
    n = items[0].placement.n

    produced: set[int] = set()
    ext: list[int] = []
    ext_shard: dict[int, bool] = {}
    for it in items:
        ax_it, ok = _realizable_axes(mesh, it.placement)
        if not ok or ax_it != axes:
            return None
        for b in it.cplan.binds:
            if b.nid in produced:
                continue                           # intra-segment edge
            sh = b.nid in it.placement.sharded
            if b.nid in ext_shard:
                if ext_shard[b.nid] != sh:
                    # annotate_segments only groups members with one
                    # consistent view of each external operand, so
                    # reaching this means the plan was corrupted after
                    # selection — fail loudly, not fall back
                    raise PlanInvariantError(
                        f"segment operand %{b.nid} is row-sharded for "
                        f"one member and broadcast for another — "
                        f"inconsistent shard view inside one region")
                continue
            if sh and b.shape[0] % n:
                return None                        # defensive: plan drift
            ext.append(b.nid)
            ext_shard[b.nid] = sh
        produced.update(it.roots)

    in_specs = tuple(P(axes, None) if ext_shard[nid] else P()
                     for nid in ext)
    out_specs = tuple(P(axes, None) if it.placement.epilogue == "none"
                      else P() for it in items if it.export)
    if not out_specs:
        return None
    steps = [(it.cplan, [b.nid for b in it.cplan.binds],
              _collective(it.placement.epilogue, axes), it.roots, it.export)
             for it in items]

    def body(*arrs):
        # each member's generated operator body, verbatim, on the local
        # row panels; intra-segment "none" outputs stay local panels
        env = dict(zip(ext, arrs))
        outs = []
        for cplan, nids, reduce_fn, roots, export in steps:
            out = ref.execute_dense(cplan,
                                    {nid: env[nid] for nid in nids})
            if reduce_fn is not None:
                out = reduce_fn(out)
            if len(roots) > 1:                     # combined multi-agg
                for k, r in enumerate(roots):
                    env[r] = out[k].reshape(1, 1)
            else:
                env[roots[0]] = out
            if export:
                outs.append(out)
        return tuple(outs)

    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    epilogues = tuple(it.placement.epilogue for it in items if it.export)
    return fn, tuple(ext), epilogues


def build_dist_fn(cplan: CPlan, mesh, placement) -> Optional[Callable]:
    """Compile one distributed fused operator, or None when the runtime
    cannot realize the placement (abstract mesh, axis mismatch, or a
    shard that would not divide) — the caller then falls back to the
    local generated operator.

    The returned callable takes the bound input arrays in ``cplan.binds``
    order and returns the operator output as a global array (row-sharded
    for "none" epilogues, replicated for reductions).  This is the
    per-operator dispatch path; whole-plan staged execution lowers runs
    of adjacent distributed operators through :func:`build_segment_fn`
    instead."""
    try:
        from jax.sharding import Mesh
    except ImportError:                            # pragma: no cover
        return None
    if not isinstance(mesh, Mesh):
        return None                                # abstract: cost-only
    axes, ok = _realizable_axes(mesh, placement)
    if not ok:
        return None

    # structural hit: a re-traced or structurally-equal plan reuses the
    # jitted shard_map operator (binding is positional, like GeneratedOp)
    shard_mask = tuple(b.nid in placement.sharded for b in cplan.binds)
    key = (cplan.cache_key(), mesh, placement.epilogue, axes, shard_mask)
    with _FN_LOCK:
        hit = _FN_CACHE.get(key)
        if hit is not None:
            _FN_CACHE.move_to_end(key)
            return hit

    roots = getattr(cplan, "roots", None) or (cplan.prog_root,)
    seg = build_segment_fn(
        [SegmentItem(cplan, placement, tuple(roots), True)], mesh)
    if seg is None:
        return None
    seg_fn, ext, _epil = seg
    assert ext == tuple(b.nid for b in cplan.binds)
    fn = jax.jit(lambda *vals: seg_fn(*vals)[0])
    with _FN_LOCK:
        _FN_CACHE[key] = fn
        while len(_FN_CACHE) > _FN_CACHE_MAX:
            _FN_CACHE.popitem(last=False)
    return fn
