"""Pure-jnp oracle for CPlan programs and template skeletons.

This module is the single source of truth for fused-operator semantics:

* every Pallas kernel in this package is validated against these functions
  (``tests/test_kernels_*``), and
* the XLA execution path of generated operators *is* this module —
  interpreting the CNode program at trace time emits one fused XLA
  computation, which is the TPU-native analogue of SystemML's generated
  janino operator when no custom kernel is warranted.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.cplan import (CPlan, COL_AGG, COL_T_AGG, FULL_AGG, LEFT_MM,
                              NO_AGG, RIGHT_MM, ROW_AGG)

# --------------------------------------------------------------------------
# basic-operation semantics (shared by program interpretation everywhere)
# --------------------------------------------------------------------------

_UNARY: dict[str, Callable] = {
    "exp": jnp.exp, "log": jnp.log, "sqrt": jnp.sqrt, "abs": jnp.abs,
    "sign": jnp.sign, "round": jnp.round, "floor": jnp.floor,
    "ceil": jnp.ceil, "sigmoid": jax.nn.sigmoid, "tanh": jnp.tanh,
    "relu": lambda x: jnp.maximum(x, 0), "neg": lambda x: -x,
    "recip": lambda x: 1.0 / x, "pow2": lambda x: x * x,
    "square": lambda x: x * x, "neq0": lambda x: (x != 0).astype(x.dtype),
    "sprop": lambda x: x * (1 - x), "log1p": jnp.log1p,
    "softplus": jax.nn.softplus, "gelu": jax.nn.gelu, "silu": jax.nn.silu,
    "erf": jax.scipy.special.erf,
}

_BINARY: dict[str, Callable] = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "min": jnp.minimum, "max": jnp.maximum,
    "pow": jnp.power,
    "eq": lambda a, b: (a == b), "neq": lambda a, b: (a != b),
    "lt": lambda a, b: (a < b), "le": lambda a, b: (a <= b),
    "gt": lambda a, b: (a > b), "ge": lambda a, b: (a >= b),
}

_CMP = {"eq", "neq", "lt", "le", "gt", "ge"}

_AGG_FN = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max,
           "mean": jnp.mean, "sum_sq": lambda x, **kw: jnp.sum(x * x, **kw)}


def eval_node(op: str, ins: Sequence, attrs: dict):
    """Evaluate one IR operation on jnp values (used for basic operators
    and inside program interpretation)."""
    if op in _AGG_FN and "axis" in attrs:     # min/max are also binary ops
        axis = attrs.get("axis", "full")
        ax = {"full": None, "row": 1, "col": 0}[axis]
        return jnp.asarray(_AGG_FN[op](ins[0], axis=ax, keepdims=True)
                           ).reshape((1, 1) if ax is None else
                                     ((-1, 1) if ax == 1 else (1, -1)))
    if op in _UNARY:
        return _UNARY[op](ins[0])
    if op in _BINARY:
        r = _BINARY[op](ins[0], ins[1])
        if op in _CMP:
            r = r.astype(jnp.result_type(ins[0]))
        return r
    if op == "where":
        return jnp.where(ins[0] != 0, ins[1], ins[2])
    if op == "plus_mult":
        return ins[0] + ins[1] * ins[2]
    if op == "minus_mult":
        return ins[0] - ins[1] * ins[2]
    if op == "matmul":
        a, b = ins
        ta, tb = attrs.get("ta", False), attrs.get("tb", False)
        a = a.T if ta else a
        b = b.T if tb else b
        return a @ b
    if op == "t":
        return ins[0].T
    if op == "idx":
        return ins[0][:, attrs["lo"]:attrs["hi"]]
    raise NotImplementedError(op)


# --------------------------------------------------------------------------
# program interpretation
# --------------------------------------------------------------------------

def apply_program(cplan: CPlan, read: Callable[[int], jnp.ndarray],
                  roots: Sequence[int]) -> list:
    """Interpret the CNode program; ``read(nid)`` supplies bound inputs.
    Returns the values of the requested program roots."""
    vals: dict[int, jnp.ndarray] = {}
    for (nid, op, ins, _shape, attrs) in cplan.prog:
        argv = []
        for kind, ref in ins:
            if kind == "n":
                argv.append(vals[ref])
            elif kind == "b":
                argv.append(read(ref))
            else:                          # literal
                argv.append(ref)
        vals[nid] = eval_node(op, argv, dict(attrs))
    return [vals[r] if r in vals else read(r) for r in roots]


def _agg(val, op: str, axis):
    return _AGG_FN[op](val, axis=axis, keepdims=True)


# --------------------------------------------------------------------------
# dense skeleton references (the oracle per template variant)
# --------------------------------------------------------------------------

def execute_dense(cplan: CPlan, env: dict[int, jnp.ndarray]):
    """Reference execution of a fused operator over dense inputs.
    ``env`` maps bound nids to dense arrays.  Returns the output array
    (or a (k,1) stack for multi-aggregates)."""
    read = lambda nid: env[nid]

    if cplan.extra:                       # multi-aggregate
        roots = [cplan.prog_root] + [r for r, _ in cplan.extra]
        ops = [cplan.agg_op] + [op for _, op in cplan.extra]
        vals = apply_program(cplan, read, roots)
        outs = [_agg(v, op, None).reshape(1, 1) for v, op in zip(vals, ops)]
        return jnp.concatenate(outs, axis=0)

    roots = [cplan.prog_root]
    if cplan.close_nid is not None:
        roots.append(cplan.close_nid)
    vals = apply_program(cplan, read, roots)
    val = vals[0]
    closer = vals[1] if len(vals) > 1 else None
    v = cplan.variant
    if v == NO_AGG:
        return val
    if v == FULL_AGG:
        return _agg(val, cplan.agg_op, None).reshape(1, 1)
    if v == ROW_AGG:
        return _agg(val, cplan.agg_op, 1).reshape(-1, 1)
    if v == COL_AGG:
        return _agg(val, cplan.agg_op, 0).reshape(1, -1)
    if v == COL_T_AGG:
        return closer.T @ val
    if v == RIGHT_MM:
        return val @ (closer.T if cplan.close_tb else closer)
    if v == LEFT_MM:
        return val.T @ closer
    raise NotImplementedError(v)
