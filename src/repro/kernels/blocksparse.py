"""TPU-native sparse & compressed matrix formats.

The paper's runtime accesses sparse matrices row/cell-wise (CSR + stateful
iterators) and compressed matrices via per-column dictionaries (CLA [28]).
Neither scalar gathers nor per-row code paths map onto the TPU's tile
units, so the hardware adaptation is:

* :class:`BCSR` — block-compressed sparse rows with MXU-aligned square
  blocks (default 128): only non-zero blocks are stored, sorted
  row-major, so a Pallas grid over blocks keeps output rows resident in
  VMEM while the MXU computes per-block outer products.  Sparsity
  exploitation (the paper's "sparse drivers") happens at block granularity.
* :class:`DictCompressed` — **CLA compression**: CLA-style per-column
  dictionaries of distinct values + code matrix + counts.  Qualifying
  generated operators (single-main-input full-sum chains; the precise
  rule lives on ``repro.kernels.ops._execute_dict``) evaluate over
  *distinct values only* and aggregate via counts — a direct port of the
  paper's compressed-data fast path (§5.2, Fig. 9); everything else
  decompresses via :meth:`DictCompressed.todense` and takes the dense
  paths.

Both are registered JAX pytrees so they flow through jit/vmap/pjit.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = 128


@jax.tree_util.register_pytree_node_class
@dataclass
class BCSR:
    """Block-compressed sparse matrix.

    data:  (nb, bs, bs) non-zero blocks (dense inside, may contain zeros)
    rows:  (nb,) int32 block-row index of each block (row-major sorted)
    cols:  (nb,) int32 block-col index
    shape: logical (m, n); must be divisible by bs (pad first)
    """
    data: jnp.ndarray
    rows: jnp.ndarray
    cols: jnp.ndarray
    shape: tuple[int, int]
    bs: int = DEFAULT_BLOCK

    # -- pytree ---------------------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.rows, self.cols), (self.shape, self.bs)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, rows, cols = children
        return cls(data, rows, cols, aux[0], aux[1])

    # -- properties -------------------------------------------------------------
    @property
    def nblocks(self) -> int:
        return int(self.data.shape[0])

    @property
    def block_sparsity(self) -> float:
        m, n = self.shape
        total = (m // self.bs) * (n // self.bs)
        return self.nblocks / max(total, 1)

    # -- conversion ---------------------------------------------------------------
    @staticmethod
    def from_dense(x, bs: int = DEFAULT_BLOCK) -> "BCSR":
        x = np.asarray(x)
        m, n = x.shape
        assert m % bs == 0 and n % bs == 0, f"pad {x.shape} to multiple of {bs}"
        mb, nb = m // bs, n // bs
        blocks = x.reshape(mb, bs, nb, bs).transpose(0, 2, 1, 3)
        nz = np.abs(blocks).sum(axis=(2, 3)) > 0
        ridx, cidx = np.nonzero(nz)
        order = np.lexsort((cidx, ridx))            # row-major block order
        ridx, cidx = ridx[order], cidx[order]
        data = blocks[ridx, cidx]
        if len(ridx) == 0:                           # keep at least one block
            ridx = np.array([0])
            cidx = np.array([0])
            data = np.zeros((1, bs, bs), x.dtype)
        return BCSR(jnp.asarray(data), jnp.asarray(ridx, jnp.int32),
                    jnp.asarray(cidx, jnp.int32), (m, n), bs)

    def todense(self) -> jnp.ndarray:
        m, n = self.shape
        mb, nb = m // self.bs, n // self.bs
        flat = jnp.zeros((mb * nb, self.bs, self.bs), self.data.dtype)
        flat = flat.at[self.rows * nb + self.cols].add(self.data)
        return flat.reshape(mb, nb, self.bs, self.bs) \
                   .transpose(0, 2, 1, 3).reshape(m, n)

    @property
    def T(self) -> "BCSR":
        """Transposed copy, re-sorted row-major (needed by left_mm — the
        ALS Xᵀ direction)."""
        order = jnp.lexsort((self.rows, self.cols))
        return BCSR(jnp.transpose(self.data[order], (0, 2, 1)),
                    self.cols[order], self.rows[order],
                    (self.shape[1], self.shape[0]), self.bs)

    def nnz_fraction(self) -> float:
        return self.block_sparsity


@jax.tree_util.register_pytree_node_class
@dataclass
class DictCompressed:
    """CLA-style column-compressed matrix (paper ref [28]).

    values: (ncol, ndist) per-column dictionary (padded with 0)
    codes:  (nrow, ncol) int32 indices into the column dictionary
    counts: (ncol, ndist) occurrences of each distinct value
    """
    values: jnp.ndarray
    codes: jnp.ndarray
    counts: jnp.ndarray
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.codes, self.counts), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, codes, counts = children
        return cls(values, codes, counts, aux[0])

    @staticmethod
    def from_dense(x, max_distinct: int = 256) -> "DictCompressed":
        x = np.asarray(x)
        m, n = x.shape
        ndist = 1
        vals_l, codes_l, counts_l = [], [], []
        for c in range(n):
            v, code, cnt = np.unique(x[:, c], return_inverse=True,
                                     return_counts=True)
            if len(v) > max_distinct:
                raise ValueError(f"column {c}: {len(v)} distinct values")
            ndist = max(ndist, len(v))
            vals_l.append(v)
            codes_l.append(code)
            counts_l.append(cnt)
        values = np.zeros((n, ndist), x.dtype)
        counts = np.zeros((n, ndist), np.float64)
        codes = np.stack(codes_l, axis=1).astype(np.int32)
        for c in range(n):
            values[c, :len(vals_l[c])] = vals_l[c]
            counts[c, :len(counts_l[c])] = counts_l[c]
        return DictCompressed(jnp.asarray(values), jnp.asarray(codes),
                              jnp.asarray(counts.astype(x.dtype)), (m, n))

    def todense(self) -> jnp.ndarray:
        return jnp.take_along_axis(self.values.T, self.codes, axis=0)

    @property
    def compression_ratio(self) -> float:
        m, n = self.shape
        dense = m * n * 4
        comp = (self.values.size + self.counts.size) * 4 + self.codes.size
        return dense / comp


@jax.tree_util.register_pytree_node_class
@dataclass
class ShardedBCSR:
    """Block-row-partitioned BCSR: the distributed form of :class:`BCSR`.

    ``partition_block_rows`` splits a row-major BCSR into ``nparts``
    equal block-row ranges and pads every shard to the same block count
    so the stacked representation has static shapes — the shape
    ``shard_map`` needs to row-shard a sparse main with ``P(axes)`` on
    the leading axis.  Padding blocks carry zero data and point at the
    shard's *last* real block-row, which keeps each shard's block list
    row-major sorted and makes the padded contributions exact zeros for
    every sparse execution path (sum aggregations add 0; the Outer
    skeleton's revisit-accumulate sees ``rows[b] == rows[b-1]`` and
    accumulates 0 instead of re-initializing the output block).

    data:   (nparts, nb_max, bs, bs) padded per-shard blocks
    rows:   (nparts, nb_max) int32 *shard-local* block-row indices
    cols:   (nparts, nb_max) int32 block-col indices
    shape:  global logical (m, n)
    """
    data: jnp.ndarray
    rows: jnp.ndarray
    cols: jnp.ndarray
    shape: tuple[int, int]
    bs: int = DEFAULT_BLOCK
    nparts: int = 1

    def tree_flatten(self):
        return (self.data, self.rows, self.cols), \
            (self.shape, self.bs, self.nparts)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, rows, cols = children
        return cls(data, rows, cols, aux[0], aux[1], aux[2])

    def local_bcsr(self) -> BCSR:
        """The one-shard view (inside a ``shard_map`` body, where the
        leading axis has been divided down to 1): a BCSR over this
        shard's (m/nparts, n) row panel with shard-local row indices."""
        m, n = self.shape
        return BCSR(self.data[0], self.rows[0], self.cols[0],
                    (m // self.nparts, n), self.bs)

    def unshard(self) -> BCSR:
        """Reassemble the global BCSR (works under trace: index
        arithmetic + reshape only).  Padding blocks survive as explicit
        zero blocks — semantically neutral everywhere (``todense``
        scatters with ``.add``; sparse kernels accumulate 0)."""
        m, n = self.shape
        rows_per_shard = (m // self.bs) // self.nparts
        offset = (jnp.arange(self.nparts, dtype=self.rows.dtype)
                  * rows_per_shard)[:, None]
        return BCSR(self.data.reshape(-1, self.bs, self.bs),
                    (self.rows + offset).reshape(-1),
                    self.cols.reshape(-1), (m, n), self.bs)

    def todense(self) -> jnp.ndarray:
        return self.unshard().todense()


def partition_block_rows(x: BCSR, nparts: int):
    """Split ``x`` into ``nparts`` equal block-row ranges →
    :class:`ShardedBCSR`, or None when the partition cannot be built:
    the block-row count does not divide ``nparts``, or the block index
    arrays are tracers (partitioning re-buckets by concrete row index,
    so it must run outside ``jit`` — callers fall back to local
    execution and report why)."""
    m, n = x.shape
    mb = m // x.bs
    if nparts <= 1 or mb % nparts:
        return None
    try:
        rows = np.asarray(x.rows)
        cols = np.asarray(x.cols)
    except Exception:                      # tracer: cannot re-bucket
        return None
    rows_per_shard = mb // nparts
    shard_of = rows // rows_per_shard
    counts = np.bincount(shard_of, minlength=nparts)
    nb_max = max(int(counts.max()), 1)
    data = np.asarray(x.data)
    pdata = np.zeros((nparts, nb_max, x.bs, x.bs), data.dtype)
    prows = np.zeros((nparts, nb_max), np.int32)
    pcols = np.zeros((nparts, nb_max), np.int32)
    for s in range(nparts):
        idx = np.nonzero(shard_of == s)[0]        # row-major order kept
        k = len(idx)
        if k:
            pdata[s, :k] = data[idx]
            prows[s, :k] = rows[idx] - s * rows_per_shard
            pcols[s, :k] = cols[idx]
            # padding points at the last real block-row (sorted order
            # preserved; Outer revisit-accumulate adds exact zeros)
            prows[s, k:] = prows[s, k - 1]
            pcols[s, k:] = pcols[s, k - 1]
    return ShardedBCSR(jnp.asarray(pdata), jnp.asarray(prows),
                       jnp.asarray(pcols), (m, n), x.bs, nparts)


def pad_to_blocks(x, bs: int = DEFAULT_BLOCK):
    """Zero-pad a dense matrix so both dims divide the block size."""
    m, n = x.shape
    pm, pn = (-m) % bs, (-n) % bs
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x
