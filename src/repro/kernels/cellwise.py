"""Pallas TPU skeleton for the **Cell** template (and single-output MAgg).

Hardware adaptation of SystemML's SpoofCellwise: instead of a value-at-a-
time virtual ``genexec``, the skeleton is a 2-D grid over MXU/VPU-aligned
VMEM tiles; the generated operator (the CPlan program) is interpreted at
trace time on tile values, emitting one fused kernel.  Aggregation variants
accumulate across the reduction grid axis, which is laid out innermost so
the output block stays resident in VMEM.

Broadcast binding: (m,n) matrices tile as (bm,bn); (m,1)/(1,n) vectors ride
along as (bm,1)/(1,bn) tiles; scalars as (1,1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cplan import (CPlan, COL_AGG, FULL_AGG, NO_AGG, ROW_AGG)
from . import ref


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` ≤ target (hardware path would mask
    instead; divisibility keeps the validated kernels exact)."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


def _tile_spec(shape, m, n, bm, bn, reduce_over_rows: bool):
    """BlockSpec for a broadcast-compatible input of ``shape``; grid is
    (outer, inner) where inner is the reduction axis."""
    r, c = shape
    if reduce_over_rows:     # grid = (n/bn, m/bm): o=col tile, i=row tile
        ix_m, ix_n = (lambda o, i: i), (lambda o, i: o)
    else:                    # grid = (m/bm, n/bn)
        ix_m, ix_n = (lambda o, i: o), (lambda o, i: i)
    if (r, c) == (1, 1):
        return pl.BlockSpec((1, 1), lambda o, i: (0, 0))
    if r == 1:
        return pl.BlockSpec((1, bn), lambda o, i: (0, ix_n(o, i)))
    if c == 1:
        return pl.BlockSpec((bm, 1), lambda o, i: (ix_m(o, i), 0))
    return pl.BlockSpec((bm, bn), lambda o, i: (ix_m(o, i), ix_n(o, i)))


_INIT = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf, "mean": 0.0}
_COMB = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum,
         "mean": jnp.add}


def cell_pallas(cplan: CPlan, env: dict[int, jnp.ndarray], *,
                interpret: bool = False,
                block: tuple[int, int] = (256, 512)) -> jnp.ndarray:
    main = env[cplan.main.nid]
    m, n = main.shape
    bm, bn = pick_block(m, block[0]), pick_block(n, block[1])
    variant, agg = cplan.variant, (cplan.agg_op or "sum")
    reduce_rows = variant == COL_AGG      # reduce over m → rows innermost

    binds = [b for b in cplan.binds]
    arrays = [jnp.asarray(env[b.nid]) for b in binds]
    dtype = arrays[0].dtype
    in_specs = [_tile_spec(a.shape, m, n, bm, bn, reduce_rows)
                for a in arrays]
    nid_to_pos = {b.nid: i for i, b in enumerate(binds)}

    if variant == NO_AGG:
        grid = (m // bm, n // bn)
        out_spec = pl.BlockSpec((bm, bn), lambda o, i: (o, i))
        out_shape = (m, n)
    elif variant == ROW_AGG:
        grid = (m // bm, n // bn)
        out_spec = pl.BlockSpec((bm, 1), lambda o, i: (o, 0))
        out_shape = (m, 1)
    elif variant == COL_AGG:
        grid = (n // bn, m // bm)
        out_spec = pl.BlockSpec((1, bn), lambda o, i: (0, o))
        out_shape = (1, n)
    elif variant == FULL_AGG:
        grid = (m // bm, n // bn)
        out_spec = pl.BlockSpec((1, 1), lambda o, i: (0, 0))
        out_shape = (1, 1)
    else:
        raise NotImplementedError(variant)

    def kernel(*refs):
        *ins, out = refs
        read = lambda nid: ins[nid_to_pos[nid]][...]
        (val,) = ref.apply_program(cplan, read, [cplan.prog_root])
        if variant == NO_AGG:
            out[...] = val.astype(dtype)
            return
        if variant == ROW_AGG:
            part = _reduce(val, agg, axis=1)
        elif variant == COL_AGG:
            part = _reduce(val, agg, axis=0)
        else:
            part = _reduce(val, agg, axis=None)
        part = part.astype(dtype)
        i = pl.program_id(1)
        first = i == 0
        if variant == FULL_AGG:
            first = jnp.logical_and(pl.program_id(0) == 0, first)

        @pl.when(first)
        def _init():
            out[...] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            out[...] = _COMB[agg](out[...], part)

    out = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, dtype),
        interpret=interpret)(*arrays)
    if agg == "mean":
        count = {ROW_AGG: n, COL_AGG: m, FULL_AGG: m * n}.get(variant, 1)
        out = out / count
    return out


def _reduce(val, agg: str, axis):
    fn = {"sum": jnp.sum, "mean": jnp.sum,
          "min": jnp.min, "max": jnp.max}[agg]
    return fn(val, axis=axis, keepdims=True)
