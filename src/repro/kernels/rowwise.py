"""Pallas TPU skeleton for the **Row** template.

SystemML's SpoofRowwise walks one row at a time with a ring buffer of row
intermediates; on TPU the skeleton processes (bm × n) row *panels* resident
in VMEM — row intermediates become panel registers, matvec chains become
panel @ side MXU ops, and the ``col_t_agg`` close (Xᵀ·chain, the MLogreg
pattern) accumulates a full (k×n') output block across the grid.

Binding rules: the main input tiles as (bm, n); side inputs with m rows ride
as (bm, k) panels; anything else (v, W, row vectors) stays fully resident.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cplan import (CPlan, COL_AGG, COL_T_AGG, FULL_AGG, NO_AGG,
                              ROW_AGG)
from . import ref
from .cellwise import pick_block, _COMB


def row_pallas(cplan: CPlan, env: dict[int, jnp.ndarray], *,
               interpret: bool = False, block_rows: int = 128) -> jnp.ndarray:
    main = env[cplan.main.nid]
    m, n = main.shape
    bm = pick_block(m, block_rows)
    variant, agg = cplan.variant, (cplan.agg_op or "sum")

    binds = list(cplan.binds)
    arrays = [jnp.asarray(env[b.nid]) for b in binds]
    dtype = arrays[0].dtype
    in_specs = []
    for b, a in zip(binds, arrays):
        r, c = a.shape
        if b.nid == cplan.main.nid:
            in_specs.append(pl.BlockSpec((bm, n), lambda i: (i, 0)))
        elif r == m and m > 1:                 # row-aligned side panel
            in_specs.append(pl.BlockSpec((bm, c), lambda i: (i, 0)))
        else:                                  # fully-resident side input
            in_specs.append(pl.BlockSpec((r, c), lambda i: (0, 0)))
    nid_to_pos = {b.nid: i for i, b in enumerate(binds)}

    roots = [cplan.prog_root]
    if cplan.close_nid is not None:
        roots.append(cplan.close_nid)

    # output geometry
    if variant == NO_AGG:
        n_out = cplan.out_shape[1]
        out_spec = pl.BlockSpec((bm, n_out), lambda i: (i, 0))
        out_shape = (m, n_out)
    elif variant == ROW_AGG:
        out_spec = pl.BlockSpec((bm, 1), lambda i: (i, 0))
        out_shape = (m, 1)
    elif variant in (COL_AGG, FULL_AGG):
        out_shape = (1, cplan.out_shape[1]) if variant == COL_AGG else (1, 1)
        out_spec = pl.BlockSpec(out_shape, lambda i: (0, 0))
    elif variant == COL_T_AGG:
        out_shape = cplan.out_shape
        out_spec = pl.BlockSpec(out_shape, lambda i: (0, 0))
    else:
        raise NotImplementedError(variant)

    def kernel(*refs):
        *ins, out = refs
        read = lambda nid: ins[nid_to_pos[nid]][...]
        vals = ref.apply_program(cplan, read, roots)
        val = vals[0]
        if variant == NO_AGG:
            out[...] = val.astype(dtype)
            return
        if variant == ROW_AGG:
            out[...] = _panel_reduce(val, agg, axis=1).astype(dtype)
            return
        if variant == COL_T_AGG:
            closer = vals[1]
            part = (closer.T @ val).astype(dtype)
        elif variant == COL_AGG:
            part = _panel_reduce(val, agg, axis=0).astype(dtype)
        else:  # FULL_AGG
            part = _panel_reduce(val, agg, axis=None).astype(dtype)
        first = pl.program_id(0) == 0

        @pl.when(first)
        def _init():
            out[...] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            comb = jnp.add if variant == COL_T_AGG else _COMB[agg]
            out[...] = comb(out[...], part)

    out = pl.pallas_call(
        kernel, grid=(m // bm,), in_specs=in_specs, out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, dtype),
        interpret=interpret)(*arrays)
    if agg == "mean" and variant in (ROW_AGG, COL_AGG, FULL_AGG):
        rr, rc = _root_shape(cplan)
        count = {ROW_AGG: rc, COL_AGG: rr, FULL_AGG: rr * rc}[variant]
        out = out / count
    return out


def _root_shape(cplan: CPlan) -> tuple[int, int]:
    for (nid, _op, _ins, shape, _attrs) in cplan.prog:
        if nid == cplan.prog_root:
            return shape
    for b in cplan.binds:
        if b.nid == cplan.prog_root:
            return b.shape
    return cplan.main.shape


def _panel_reduce(val, agg: str, axis):
    fn = {"sum": jnp.sum, "mean": jnp.sum, "min": jnp.min,
          "max": jnp.max}[agg]
    return fn(val, axis=axis, keepdims=True)
