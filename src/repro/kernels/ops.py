"""Dispatch layer for generated fused operators.

Given a CPlan and bound inputs, pick an execution path:

* **dense / XLA** — interpret the program at trace time (ref.execute_dense);
  XLA emits one fused computation.  Default on CPU.
* **dense / Pallas** — template-skeleton TPU kernels with explicit VMEM
  BlockSpecs (cellwise/rowwise/multiagg); ``interpret=True`` on CPU.
* **BCSR** — sparsity-exploiting paths over non-zero blocks only: the Outer
  template (SDDMM-style) and sparse-safe Cell/MAgg chains.  jnp (gather +
  segment-sum) and Pallas (scalar-prefetch grid) variants.
* **CLA** — DictCompressed single-input sum-aggregate chains evaluated
  over the per-column dictionaries and aggregated via counts (paper
  Fig. 9); the exact qualification rule is documented on
  :func:`_execute_dict`, the format in :mod:`repro.kernels.blocksparse`
  ("CLA compression").

Also hosts block-sparse *basic* operators (sparse matmul etc.) used when a
plan leaves a sparse op unfused.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import faults
from repro.core.cplan import (CPlan, COL_AGG, FULL_AGG, LEFT_MM, NO_AGG,
                              RIGHT_MM, ROW_AGG)
from repro.core.templates import TType
from . import ref
from .blocksparse import BCSR, DictCompressed

faults.register_site(
    "kernels.pallas_call",
    "generated-kernel dispatch when a Pallas path is selected "
    "(pallas != 'never'): fires while the fused operator is traced into "
    "the surrounding jit, i.e. at build time of the enclosing plan",
    kinds=("error", "latency"),
    handler="per-plan: FusionServer build ladder retries the plan at a "
            "lower tier; per-op: compile_plan(strict) surfaces the error "
            "to the caller — never cached, retries re-dispatch")


# --------------------------------------------------------------------------
# public entry: execute a CPlan on bound values
# --------------------------------------------------------------------------

def execute(cplan: CPlan, env: dict[int, object], *,
            pallas: str = "never",
            shard_rows: Optional[int] = None) -> jnp.ndarray:
    """Run one fused operator.  ``pallas`` ∈ {"never","interpret","tpu"}.

    ``shard_rows`` is the shard-local main-row count when this operator
    executes inside a ``shard_map`` body: the Pallas template lowerings
    derive their grids and BlockSpecs from it (largest divisor ≤ the
    template's tile target) instead of the global-tuned defaults, so the
    generated kernels lower as ``pallas_call`` inside the region."""
    if pallas != "never":
        faults.fault_point("kernels.pallas_call")
    main = env.get(cplan.main.nid)
    if isinstance(main, DictCompressed):
        out = _execute_dict(cplan, env)
        if out is not None:
            return out
        env = dict(env)
        env[cplan.main.nid] = main.todense()
        main = env[cplan.main.nid]
    if isinstance(main, BCSR):
        has_mm = any(op == "matmul" for (_, op, *_rest) in cplan.prog)
        from repro.core.templates import TType as _T
        if cplan.main.exploit and (cplan.ttype == _T.OUTER or not has_mm):
            if pallas != "never" and cplan.ttype == _T.OUTER \
                    and cplan.variant in (RIGHT_MM, FULL_AGG):
                from .outerprod import outer_pallas
                return outer_pallas(cplan, env,
                                    interpret=pallas == "interpret")
            return _execute_bcsr(cplan, env)
        env = dict(env)
        env[cplan.main.nid] = main.todense()   # not exploitable: decompress
    env = {k: (v.todense() if isinstance(v, (BCSR, DictCompressed)) else v)
           for k, v in env.items()}
    if pallas != "never":
        from . import cellwise, multiagg, rowwise
        from .cellwise import pick_block
        interpret = pallas == "interpret"
        if cplan.extra:
            block = (256, 512) if shard_rows is None else \
                (pick_block(shard_rows, 256), 512)
            return multiagg.multiagg_pallas(cplan, env, interpret=interpret,
                                            block=block)
        if cplan.ttype in (TType.CELL, TType.MAGG):
            block = (256, 512) if shard_rows is None else \
                (pick_block(shard_rows, 256), 512)
            return cellwise.cell_pallas(cplan, env, interpret=interpret,
                                        block=block)
        if cplan.ttype == TType.ROW:
            br = 128 if shard_rows is None else pick_block(shard_rows, 128)
            return rowwise.row_pallas(cplan, env, interpret=interpret,
                                      block_rows=br)
        # Outer over dense main: fall through to the XLA path
    return ref.execute_dense(cplan, env)


# --------------------------------------------------------------------------
# BCSR sparsity-exploiting execution (jnp path; Pallas variant in
# outerprod.py is selected by the benchmarks/tests explicitly)
# --------------------------------------------------------------------------

def _gather_blocks(x: jnp.ndarray, idx: jnp.ndarray, bs: int,
                   axis: int) -> jnp.ndarray:
    """Gather (nb, bs, k) row-panels (axis=0) or (nb, k, bs) col-panels."""
    if axis == 0:
        panels = x.reshape(x.shape[0] // bs, bs, x.shape[1])
        return panels[idx]
    panels = x.reshape(x.shape[0], x.shape[1] // bs, bs).transpose(1, 0, 2)
    return panels[idx]


def _block_env(cplan: CPlan, env: dict[int, object], X: BCSR):
    """Per-block views of every bound input: main → (nb,bs,bs) blocks, side
    inputs gathered by block row/col, scalars broadcast."""
    nb, bs = X.nblocks, X.bs
    m, n = X.shape

    def read(nid: int):
        if nid == cplan.main.nid:
            return X.data
        v = env[nid]
        if isinstance(v, (BCSR, DictCompressed)):
            v = v.todense()
        r, c = v.shape
        if (r, c) == (1, 1):
            return v.reshape(1, 1, 1)
        if (r, c) == (m, n):        # aligned matrix: gather (bs,bs) blocks
            blocks = v.reshape(m // bs, bs, n // bs, bs).transpose(0, 2, 1, 3)
            return blocks[X.rows, X.cols]
        if c == 1 and r == m:       # column vector: (nb, bs, 1)
            return v.reshape(m // bs, bs, 1)[X.rows]
        if r == 1 and c == n:       # row vector: (nb, 1, bs)
            return v.reshape(1, n // bs, bs).transpose(1, 0, 2)[X.cols]
        raise NotImplementedError(
            f"side input {v.shape} vs sparse main {X.shape}")

    return read


def _execute_bcsr(cplan: CPlan, env: dict[int, object]) -> jnp.ndarray:
    X: BCSR = env[cplan.main.nid]
    nb, bs = X.nblocks, X.bs
    m, n = X.shape
    read = _block_env(cplan, env, X)

    roots = [cplan.prog_root]
    in_prog = {nid for (nid, *_r) in cplan.prog}
    if cplan.close_nid is not None and cplan.close_nid in in_prog:
        roots.append(cplan.close_nid)

    if cplan.ttype == TType.OUTER:
        fu = _as_dense(env[_kind_nid(cplan, "factor_u")])
        fv = _as_dense(env[_kind_nid(cplan, "factor_v")])
        ub = _gather_blocks(fu, X.rows, bs, 0)       # (nb, bs, r)
        vb = _gather_blocks(fv, X.cols, bs, 0)       # (nb, bs, r)

        def read_outer(nid: int):
            # the outer matmul is evaluated per block: U_bi @ V_bjᵀ
            return read(nid)
        # patch: program contains the outer mm node; intercept by
        # evaluating the program with a special matmul handler
        vals = _apply_prog_blocked(cplan, read_outer, roots, ub, vb)
    else:
        vals = _apply_prog_blocked(cplan, read, roots, None, None)

    val = vals[0]                                     # (nb, bs, bs)
    v = cplan.variant
    if v == FULL_AGG:
        if cplan.extra:
            outs = [_block_agg(vals[0], cplan.agg_op)]
            for x_val, op in zip(vals[1:], [op for _, op in cplan.extra]):
                outs.append(_block_agg(x_val, op))
            return jnp.concatenate(outs, axis=0)
        return _block_agg(val, cplan.agg_op)
    if v == RIGHT_MM:
        closer = _as_dense(env[cplan.close_nid])
        cb = _gather_blocks(closer.T if cplan.close_tb else closer,
                            X.cols, bs, 0)            # (nb, bs, r)
        contrib = jnp.einsum("nij,njk->nik", val, cb)
        out = jax.ops.segment_sum(contrib, X.rows, num_segments=m // bs)
        return out.reshape(m, -1)
    if v == LEFT_MM:
        closer = _as_dense(env[cplan.close_nid])
        cb = _gather_blocks(closer, X.rows, bs, 0)    # (nb, bs, r)
        contrib = jnp.einsum("nij,nik->njk", val, cb)
        out = jax.ops.segment_sum(contrib, X.cols, num_segments=n // bs)
        return out.reshape(n, -1)
    if v == NO_AGG:
        return BCSR(val, X.rows, X.cols, X.shape, bs)
    if v == ROW_AGG:
        assert cplan.agg_op == "sum", "sparse row_agg supports sum"
        s = jnp.sum(val, axis=2)                      # (nb, bs)
        out = jax.ops.segment_sum(s, X.rows, num_segments=m // bs)
        return out.reshape(m, 1)
    if v == COL_AGG:
        assert cplan.agg_op == "sum", "sparse col_agg supports sum"
        s = jnp.sum(val, axis=1)                      # (nb, bs)
        out = jax.ops.segment_sum(s, X.cols, num_segments=n // bs)
        return out.reshape(1, n)
    raise NotImplementedError(f"BCSR variant {v}")


def _apply_prog_blocked(cplan: CPlan, read, roots, ub, vb):
    """Interpret the program with (nb, bs, bs) block values; an interior
    outer matmul evaluates as per-block U_bi @ V_bjᵀ on the MXU."""
    vals: dict[int, jnp.ndarray] = {}
    for (nid, op, ins, _shape, attrs) in cplan.prog:
        attrs = dict(attrs)
        if op == "matmul" and ub is not None:
            # the outer product: U @ t(V) evaluated per non-zero block
            vals[nid] = jnp.einsum("nik,njk->nij", ub, vb)
            continue
        argv = []
        for kind, r in ins:
            if kind == "n":
                argv.append(vals[r])
            elif kind == "b":
                argv.append(read(r))
            else:
                argv.append(r)
        vals[nid] = ref.eval_node(op, argv, attrs)
    return [vals[r] if r in vals else read(r) for r in roots]


def _block_agg(val: jnp.ndarray, op: str) -> jnp.ndarray:
    if op == "sum":
        return jnp.sum(val).reshape(1, 1)
    if op == "min":
        return jnp.min(val).reshape(1, 1)   # pseudo-sparse-safe: min ≤ 0
    if op == "max":
        return jnp.max(val).reshape(1, 1)
    raise NotImplementedError(op)


def _kind_nid(cplan: CPlan, kind: str) -> int:
    for b in cplan.binds:
        if b.kind == kind:
            return b.nid
    raise KeyError(kind)


def _as_dense(v):
    return v.todense() if isinstance(v, (BCSR, DictCompressed)) else v


# --------------------------------------------------------------------------
# CLA (DictCompressed) fast path — paper Fig. 9
# --------------------------------------------------------------------------

def _execute_dict(cplan: CPlan, env) -> Optional[jnp.ndarray]:
    """CLA fast path over a :class:`~repro.kernels.blocksparse.
    DictCompressed` main input: evaluate the program on the per-column
    dictionary values only, then aggregate via the occurrence counts
    (``Σ f(distinct) · count`` — paper Fig. 9).

    A plan qualifies only when the whole chain is a function of the
    compressed matrix and scalars, so per-distinct-value evaluation is
    exact:

    * exactly one non-scalar bound input (the compressed main — any
      matrix/vector side input would need per-cell alignment the
      dictionary has erased),
    * variant ``full_agg`` with ``agg_op == "sum"`` (count-weighted
      reduction; min/max/mean don't weight by counts the same way),
    * not a combined multi-aggregate (``cplan.extra`` empty),
    * every other bound value is a (1, 1) scalar — a non-scalar side
      read makes :func:`read` return None and the program evaluation
      fail, which is caught below.

    Returns the (1, 1) aggregate, or None when the plan does not
    qualify — :func:`execute` then decompresses the main via
    ``todense()`` and re-dispatches on the dense paths.  See the "CLA
    compression" section of :mod:`repro.kernels.blocksparse` for the
    format itself."""
    mats = [b for b in cplan.binds if b.kind != "scalar"]
    if len(mats) != 1 or cplan.variant != FULL_AGG \
            or cplan.agg_op not in ("sum",) or cplan.extra:
        return None
    X: DictCompressed = env[cplan.main.nid]

    def read(nid: int):
        if nid == cplan.main.nid:
            return X.values                 # (ncol, ndist)
        v = env[nid]
        if hasattr(v, "shape") and tuple(v.shape) == (1, 1):
            return v
        return None
    try:
        (val,) = ref.apply_program(cplan, read, [cplan.prog_root])
    except TypeError:
        return None
    return jnp.sum(val * X.counts).reshape(1, 1)


# --------------------------------------------------------------------------
# block-sparse basic operators (for unfused plans over sparse data)
# --------------------------------------------------------------------------

def bcsr_matmul(a: BCSR, b: jnp.ndarray) -> jnp.ndarray:
    """(m,n) BCSR @ (n,k) dense → (m,k) dense."""
    bb = _gather_blocks(b, a.cols, a.bs, 0)           # (nb, bs, k)
    contrib = jnp.einsum("nij,njk->nik", a.data, bb)
    out = jax.ops.segment_sum(contrib, a.rows,
                              num_segments=a.shape[0] // a.bs)
    return out.reshape(a.shape[0], -1)


def bcsr_cellwise(op: str, a: BCSR) -> BCSR:
    """Sparse-safe unary over non-zero blocks."""
    return BCSR(ref.eval_node(op, [a.data], {}), a.rows, a.cols,
                a.shape, a.bs)


def bcsr_mul_dense(a: BCSR, d: jnp.ndarray) -> BCSR:
    m, n = a.shape
    blocks = d.reshape(m // a.bs, a.bs, n // a.bs, a.bs).transpose(0, 2, 1, 3)
    return BCSR(a.data * blocks[a.rows, a.cols], a.rows, a.cols, a.shape,
                a.bs)
