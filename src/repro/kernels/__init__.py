"""Pallas TPU template-skeleton kernels + sparse/compressed formats.

One module per paper template (cellwise/rowwise/multiagg/outerprod), each a
``pl.pallas_call`` skeleton with explicit VMEM BlockSpecs; ``ops.py`` is the
jit'd dispatch wrapper; ``ref.py`` the pure-jnp oracle every kernel is
validated against.
"""
