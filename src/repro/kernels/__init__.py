"""Pallas TPU template-skeleton kernels + sparse/compressed formats.

One module per paper template (cellwise/rowwise/multiagg/outerprod), each a
``pl.pallas_call`` skeleton with explicit VMEM BlockSpecs; ``ops.py`` is the
jit'd dispatch wrapper; ``ref.py`` the pure-jnp oracle every kernel is
validated against; ``distributed.py`` runs generated operator bodies under
``shard_map`` with per-template collective epilogues (the hybrid
local/distributed execution arm); ``blocksparse.py`` holds the BCSR and
CLA-compressed matrix formats.
"""
