"""Distribution subsystem: compat shims, layout rules, layout search.

``repro.dist.sharding`` holds the parameter/cache/batch/activation
PartitionSpec rules consumed by the models, the launch stack, and the
dry-run coster; ``repro.dist.planner`` searches over those rules'
axis-role assignments with the shared roofline cost model (pass
``layout="auto"`` to the dry-run, hillclimb, or serve engine);
``repro.dist.compat`` backfills ``jax.sharding.AxisType`` on older JAX.
Importing this package installs the compat shims.

:class:`LogicalMesh` (re-exported from the planner) is the abstract
``.shape``/``.axis_names`` mesh stand-in every layout consumer accepts —
including the fusion planner's ``Traced.plan(layout=...)``, which uses it
to select hybrid local/distributed fused-operator plans from a CPU
container with no devices attached.
"""

from . import compat  # noqa: F401  (installs AxisType/make_mesh shims)
from .planner import LogicalMesh  # noqa: F401  (abstract mesh stand-in)
