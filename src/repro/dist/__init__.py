"""Distribution subsystem: JAX compat shims + mesh-aware layout rules.

``repro.dist.sharding`` holds the parameter/cache/batch/activation
PartitionSpec rules consumed by the models, the launch stack, and the
dry-run coster; ``repro.dist.compat`` backfills ``jax.sharding.AxisType``
on older JAX.  Importing this package installs the compat shims.
"""

from . import compat  # noqa: F401  (installs AxisType/make_mesh shims)
