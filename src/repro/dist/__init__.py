"""Distribution subsystem: compat shims, layout rules, layout search.

``repro.dist.sharding`` holds the parameter/cache/batch/activation
PartitionSpec rules consumed by the models, the launch stack, and the
dry-run coster; ``repro.dist.planner`` searches over those rules'
axis-role assignments with the shared roofline cost model (pass
``layout="auto"`` to the dry-run, hillclimb, or serve engine);
``repro.dist.compat`` backfills ``jax.sharding.AxisType`` on older JAX.
Importing this package installs the compat shims.
"""

from . import compat  # noqa: F401  (installs AxisType/make_mesh shims)
