"""Cost-based layout search over the mesh (explore → cost → select).

PR 1 shipped ``repro.dist.sharding`` as *fixed* layout rules: TP on the
``"model"`` axis, FSDP everywhere else, EP-vs-ffTP decided by a
divisibility predicate, serving layouts opt-in.  This module applies the
paper's planning philosophy — enumerate valid candidates, cost them with
an analytical model, select the argmin — to those distributed layouts,
the same way the fusion planner replaced fuse-all heuristics with
MPSkipEnum (paper §4; SPORES applies the identical move to sum-product
rewrites).

A **candidate** (:class:`Layout`) is one axis-role assignment for a
``(config, shape, mesh)`` cell:

* ``tp``        — tensor-parallel degree (the logical ``"model"`` axis
                  size; the remaining per-pod factor becomes FSDP/data),
* ``moe``       — expert weights over TP (``"ep"``) vs per-expert ff-TP
                  (``"fftp"``) for MoE configs,
* ``act``       — activation residuals data-parallel (``"dp"``) or
                  additionally sequence-parallel (``"sp"``),
* ``serve_params`` — replicate parameters over the FSDP axes (decode
                  reads weights every token; all-gathering them each
                  step is the wrong side of the roofline).

Candidates are **validated abstractly**: the PR-1 sharding rules map the
layout's logical mesh onto rank-matched, divisibility-checked
``PartitionSpec`` trees (no devices, no compile), and per-leaf shard
factors read off those trees drive exact parameter/optimizer/KV-cache
memory accounting.  Infeasible candidates (> usable HBM) are pruned.

Costing extends the dry-run roofline (``launch/roofline.py``) with
per-layer matmul terms and ring-collective volumes (all-gather /
reduce-scatter / all-to-all over ICI, cross-pod gradient traffic over
DCN) from the shared hardware substrate ``repro.hw`` — the same
constants the fusion cost model normalizes against.  Selection is the
argmin of modeled step time with deterministic tie-breaking (candidate
key order), memoized per cell like the fusion planner's memo table.

Usage::

    from repro.configs import SHAPES, get_config, MESH_SHAPES
    from repro.dist import planner

    cfg = get_config("yi-34b")
    result = planner.search(cfg, SHAPES["decode_32k"],
                            planner.signature_of(MESH_SHAPES["pod16x16"]))
    result.winner.layout        # Layout(tp=16, serve_params=True, ...)
    result.speedup              # modeled fixed/auto step-time ratio
    planner.write_report(result, name="yi-34b", mesh_name="pod16x16")

    # one-call consumer API (memoized) — what layout="auto" threads
    # through dryrun_lib / hillclimb / serve.Engine:
    layout = planner.plan_layout(mesh, cfg, SHAPES["decode_32k"])

Candidate/cost reports land under ``experiments/layouts/`` as JSON
(one per cell: every candidate, its terms, the winner) so layout-cost
drift is reviewable per PR::

    PYTHONPATH=src python -m repro.dist.planner [--mesh pod16x16]
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

from repro import hw as _hw
from repro.configs.base import ModelConfig, ShapeConfig

REPORT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "layouts"

ACT_BYTES = 2          # bf16 activations / collective payloads


# ---------------------------------------------------------------------------
# logical meshes & layouts
# ---------------------------------------------------------------------------

class LogicalMesh:
    """Abstract mesh (``.shape``/``.axis_names`` only) accepted by the
    sharding rules — same contract the tests' mesh stand-ins use.

    Also the no-devices entry point to hybrid fused-operator planning:
    ``Traced.plan(layout=LogicalMesh({"data": 8}))`` costs the
    local × distributed placement of every fused operator with this
    module's ring-collective terms (via ``repro.hw``) and reports the
    decision in ``explain()``; execution stays local until the same plan
    is made under a real ``jax.sharding.Mesh``."""

    def __init__(self, shape: dict[str, int]):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)

    def __repr__(self) -> str:           # pragma: no cover - debug aid
        return f"LogicalMesh({self.shape})"


def signature_of(mesh) -> tuple[tuple[str, int], ...]:
    """Hashable (axis, size) signature of any mesh-like object (real
    ``jax.sharding.Mesh``, :class:`LogicalMesh`, or a plain dict)."""
    if isinstance(mesh, dict):
        return tuple((a, int(n)) for a, n in mesh.items())
    return tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)


@dataclass(frozen=True)
class Layout:
    """One candidate axis-role assignment (see module docstring)."""
    tp: int
    dp: int
    pods: int = 1
    moe: str = "dense"           # dense | ep | fftp
    act: str = "dp"              # dp | sp
    serve_params: bool = False

    @property
    def devices(self) -> int:
        return self.tp * self.dp * self.pods

    def key(self) -> tuple:
        """Deterministic tie-break order (after cost)."""
        return (self.tp, self.moe, self.act, self.serve_params)

    def mesh(self) -> LogicalMesh:
        axes: dict[str, int] = {}
        if self.pods > 1:
            axes["pod"] = self.pods
        axes["data"] = self.dp
        axes["model"] = self.tp
        return LogicalMesh(axes)

    def to_dict(self) -> dict:
        return {"tp": self.tp, "dp": self.dp, "pods": self.pods,
                "moe": self.moe, "act": self.act,
                "serve_params": self.serve_params}


@dataclass
class LayoutCost:
    layout: Layout
    terms: dict[str, float]            # compute/memory/collective seconds
    collective_bytes: dict[str, float]  # per-device bytes by kind
    mem_bytes: dict[str, float]        # per-device resident bytes by kind
    feasible: bool
    step_time: float                   # seconds; inf when infeasible

    def to_dict(self) -> dict:
        return {"layout": self.layout.to_dict(), "terms": self.terms,
                "collective_bytes": self.collective_bytes,
                "mem_bytes": self.mem_bytes, "feasible": self.feasible,
                # None (not Infinity) for strict-JSON artifact tooling
                "step_time": self.step_time if self.feasible else None}


@dataclass
class PlanResult:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh_sig: tuple
    winner: LayoutCost
    fixed: LayoutCost
    candidates: list[LayoutCost] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        """Modeled fixed/auto step-time ratio (≥ 1 by construction).
        Cells where no layout fits HBM (e.g. grok training on a single
        pod) are ∞/∞ ties → 1.0."""
        import math
        if not math.isfinite(self.winner.step_time):
            return 1.0
        if self.winner.step_time <= 0:
            return 1.0
        return self.fixed.step_time / self.winner.step_time

    def to_dict(self) -> dict:
        import math
        speedup = self.speedup
        return {
            "arch": self.cfg.name, "shape": self.shape.name,
            "mesh": dict(self.mesh_sig),
            "devices": self.winner.layout.devices,
            "winner": self.winner.to_dict(),
            "fixed": self.fixed.to_dict(),
            # None when fixed fits no HBM at all (auto-only cell) — keeps
            # the artifact strict JSON
            "speedup": speedup if math.isfinite(speedup) else None,
            "n_candidates": len(self.candidates),
            "candidates": [c.to_dict() for c in self.candidates],
        }


# ---------------------------------------------------------------------------
# shard-factor accounting from the PR-1 rule trees
# ---------------------------------------------------------------------------

def _eff(dim: int, n: int) -> int:
    """Effective shard factor of ``dim`` over one ``n``-way axis.
    Delegates to ``sharding._fit`` — the planner's compute-side factors
    are *by construction* the per-dim graceful degradation the PR-1
    rules apply, so a rule change cannot silently diverge the costs."""
    from . import sharding as sh
    mesh = LogicalMesh({"model": n})
    return sh.axis_size(mesh, sh._fit(mesh, dim, "model"))


def _group_eff(dim: int, sizes: list[int]) -> int:
    """Suffix-fit of a dim over an ordered axis group — ``_fit`` over
    multiple axes: largest trailing sub-product that divides."""
    from . import sharding as sh
    mesh = LogicalMesh({f"ax{i}": s for i, s in enumerate(sizes)})
    return sh.axis_size(mesh, sh._fit(mesh, dim, tuple(mesh.axis_names)))


_ABS_CACHE: dict = {}


def _abstract_state(cfg: ModelConfig, shape: Optional[ShapeConfig] = None):
    """(params, cache) ShapeDtypeStruct trees, memoized per config/shape.
    Pure ``eval_shape`` — no allocation.  Keyed on the full (frozen)
    ShapeConfig: two shapes sharing a name (e.g. per-engine
    ``engine_decode`` cells) must not collide."""
    key = (cfg, shape)
    if key in _ABS_CACHE:
        return _ABS_CACHE[key]
    import jax
    from repro.models import LM
    model = LM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    cache = None
    if shape is not None and shape.kind != "train":
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
    _ABS_CACHE[key] = (params, cache)
    return params, cache


def _shard_factors(mesh: LogicalMesh, spec) -> tuple[int, int]:
    """(tp factor, fsdp factor) of one PartitionSpec on ``mesh``."""
    f_tp = f_F = 1
    for entry in tuple(spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        for a in axes:
            if a == "model":
                f_tp *= mesh.shape[a]
            else:
                f_F *= mesh.shape[a]
    return f_tp, f_F


def _tree_accounting(mesh: LogicalMesh, specs, abstract) -> dict[str, float]:
    """Per-device stored bytes + FSDP gather/scatter volumes for a spec
    tree against its abstract leaves (exact, per leaf)."""
    import jax
    from jax.sharding import PartitionSpec as P
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    leaves_a = jax.tree_util.tree_leaves(abstract)
    stored = gather = scatter = 0.0
    for spec, leaf in zip(leaves_s, leaves_a):
        ncells = 1
        for d in leaf.shape:
            ncells *= d
        nbytes = float(ncells) * leaf.dtype.itemsize
        f_tp, f_F = _shard_factors(mesh, spec)
        stored += nbytes / (f_tp * f_F)
        # all-gather assembles the per-TP-shard tensor across the FSDP
        # group; reduce-scatter is the f32-gradient mirror image.
        gather += _hw.all_gather_bytes(nbytes / f_tp, f_F)
        scatter += _hw.reduce_scatter_bytes(
            nbytes / f_tp * 4 / leaf.dtype.itemsize, f_F)
    return {"stored": stored, "gather": gather, "scatter": scatter}


def validate_layout(cfg: ModelConfig, shape: ShapeConfig,
                    layout: Layout) -> bool:
    """Every sharded dim of every param/cache leaf divides its mesh-axis
    product — the property the regression harness locks down."""
    import jax
    from jax.sharding import PartitionSpec as P
    from . import sharding as sh
    mesh = layout.mesh()
    params, cache = _abstract_state(cfg, shape)
    moe = None if layout.moe == "dense" else layout.moe
    trees = [(sh.param_specs(mesh, cfg, params, serve=layout.serve_params,
                             moe=moe), params)]
    if cache is not None:
        trees.append((sh.cache_specs(mesh, cfg, shape, cache), cache))
    for specs, abstract in trees:
        leaves_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        leaves_a = jax.tree_util.tree_leaves(abstract)
        if len(leaves_s) != len(leaves_a):
            return False
        for spec, leaf in zip(leaves_s, leaves_a):
            if len(tuple(spec)) > len(leaf.shape):
                return False
            for dim, entry in zip(leaf.shape, tuple(spec)):
                if entry is None:
                    continue
                axes = (entry,) if isinstance(entry, str) else tuple(entry)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                if dim % n != 0:
                    return False
    return True


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

def _tp_options(per_pod: int) -> list[int]:
    out = []
    t = 1
    while t <= per_pod:
        if per_pod % t == 0:
            out.append(t)
        t *= 2
    return out


def enumerate_layouts(cfg: ModelConfig, shape: ShapeConfig,
                      mesh_sig: tuple) -> list[Layout]:
    """All candidate axis-role assignments for the cell, deterministic
    order.  The pod (DCN) axis is never re-sliced — only the within-pod
    ICI factor splits into TP × FSDP."""
    axes = dict(mesh_sig)
    pods = axes.pop("pod", 1)
    per_pod = 1
    for n in axes.values():
        per_pod *= n

    is_serve = shape.kind != "train"
    out: list[Layout] = []
    for tp in _tp_options(per_pod):
        dp = per_pod // tp
        if cfg.n_experts > 0:
            moes = ["fftp"]
            if tp > 1 and cfg.n_experts % tp == 0:
                moes.append("ep")
        else:
            moes = ["dense"]
        acts = ("dp", "sp") if shape.kind in ("train", "prefill") else ("dp",)
        serves = (False, True) if is_serve else (False,)
        for moe in moes:
            for act in acts:
                for serve_params in serves:
                    out.append(Layout(tp=tp, dp=dp, pods=pods, moe=moe,
                                      act=act, serve_params=serve_params))
    out.sort(key=Layout.key)
    return out


def fixed_layout(cfg: ModelConfig, shape: ShapeConfig,
                 mesh_sig: tuple) -> Layout:
    """The PR-1 fixed-rule layout as a candidate: TP = the mesh's
    ``"model"`` axis, FSDP everywhere else, EP by predicate, dp
    activations, no serve-time replication."""
    axes = dict(mesh_sig)
    pods = axes.pop("pod", 1)
    tp = axes.get("model", 1)
    dp = 1
    for a, n in axes.items():
        if a != "model":
            dp *= n
    if cfg.n_experts > 0:
        moe = "ep" if (tp > 1 and cfg.n_experts % tp == 0) else "fftp"
    else:
        moe = "dense"
    return Layout(tp=tp, dp=dp, pods=pods, moe=moe, act="dp",
                  serve_params=False)


# ---------------------------------------------------------------------------
# the analytical cost model
# ---------------------------------------------------------------------------

def cost_layout(cfg: ModelConfig, shape: ShapeConfig, layout: Layout,
                hw: _hw.HardwareSpec = _hw.TPU_V5E) -> LayoutCost:
    """Modeled per-step roofline of one candidate: per-layer matmul
    compute, HBM traffic, ring-collective volumes, and exact (spec-tree)
    memory feasibility."""
    from repro.models.lm import build_pattern
    from . import sharding as sh

    mesh = layout.mesh()
    tp, pods = layout.tp, layout.pods
    train = shape.kind == "train"
    decode = shape.is_decode
    bwd = 3.0 if train else 1.0
    B, S = shape.global_batch, shape.seq_len

    # tokens per device: batch shards over the FSDP group (pod, data)
    beff = _group_eff(B, [pods, layout.dp])
    t = (B / beff) * (S if not decode else 1)
    s_ctx = float(S)                   # attended context length

    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    eff_h = _eff(H, tp)
    eff_kv = _eff(KV, tp)
    eff_f = _eff(f, tp) if f else 1
    eff_v = _eff(V, tp)

    pattern = build_pattern(cfg)
    L = cfg.n_layers
    reps = L / len(pattern)

    flops = 0.0
    coll = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
            "all-to-all": 0.0, "dcn": 0.0}
    ar_payload = t * d * ACT_BYTES     # one residual-stream tensor

    n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    for spec in pattern:
        if spec.kind == "attn":
            flops += reps * 2 * t * d * H * hd / eff_h * 2      # wq + wo
            flops += reps * 2 * t * d * KV * hd * 2 / eff_kv    # wk + wv
            w = float(min(spec.window or S, S))
            dens = 1.0 if decode else 0.5                       # causal
            flops += reps * 4 * t * min(w, s_ctx) * H * hd / eff_h * dens
            if eff_h > 1:
                coll["all-reduce"] += reps * _hw.all_reduce_bytes(
                    ar_payload, eff_h)
        else:                                    # mamba | mlstm
            di = cfg.ssm_expand * d
            eff_di = _eff(di, tp)
            if spec.kind == "mamba":
                body = 2 * t * d * di * 3 + 26 * t * di * cfg.ssm_state
            else:                                # mlstm
                hdi = di // max(H, 1)
                body = 8 * t * d * di + 5.5 * t * di * hdi
            flops += reps * body / eff_di
            if eff_di > 1:
                coll["all-reduce"] += reps * _hw.all_reduce_bytes(
                    ar_payload, eff_di)

        if spec.kind == "attn" or cfg.block_type != "xlstm":
            if cfg.n_experts > 0 and spec.use_moe:
                flops += reps * 2 * t * d * cfg.n_experts       # router
                e_div = tp if layout.moe == "ep" else eff_f
                flops += reps * n_mats * 2 * t * d * f * cfg.top_k / e_div
                if layout.moe == "ep" and tp > 1:
                    # tokens split across the EP group before dispatch,
                    # so each device re-buckets t/tp of the routed payload
                    payload = t * cfg.top_k * d * ACT_BYTES / tp
                    coll["all-to-all"] += reps * 2 * _hw.all_to_all_bytes(
                        payload, tp)
                elif eff_f > 1:
                    coll["all-reduce"] += reps * _hw.all_reduce_bytes(
                        ar_payload, eff_f)
            elif f:
                flops += reps * n_mats * 2 * t * d * f / eff_f
                if eff_f > 1:
                    coll["all-reduce"] += reps * _hw.all_reduce_bytes(
                        ar_payload, eff_f)

    # vocab-parallel head: logits matmul + (serve) logit assembly
    flops += 2 * t * d * V / eff_v
    if eff_v > 1 and not train:
        coll["all-gather"] += _hw.all_gather_bytes(t * V * ACT_BYTES, eff_v)
    flops *= bwd
    for k in ("all-reduce", "all-to-all"):
        coll[k] *= bwd

    # ---- exact per-leaf parameter / optimizer / cache accounting ----------
    params_abs, cache_abs = _abstract_state(cfg, shape)
    moe_role = None if layout.moe == "dense" else layout.moe
    pspecs = sh.param_specs(mesh, cfg, params_abs,
                            serve=layout.serve_params, moe=moe_role)
    pacc = _tree_accounting(mesh, pspecs, params_abs)

    # sequence-parallel residuals shard the checkpoint/working set over TP
    act_shard = _eff(S, tp) if layout.act == "sp" else 1

    mem = {"params": pacc["stored"]}
    if train:
        from repro.launch.train import default_microbatches
        facc = pacc        # train layouts never replicate (serve) params
        mem["optimizer"] = facc["stored"] * 4       # m + v in f32
        mem["grads"] = facc["stored"] * 2           # f32 accumulators
        # accumulation depth adapts to the activation budget: start at the
        # throughput-picked default and deepen (power of two, ≥ 1 sequence
        # per microbatch) until the remat checkpoints fit
        n_mb = default_microbatches(cfg, shape, max(beff, 1))
        budget = hw.hbm_bytes * hw.hbm_usable - (
            mem["params"] + mem["optimizer"] + mem["grads"])

        def act_of(n: int) -> float:
            return 2 * (t / n) * d * L * ACT_BYTES / act_shard

        max_mb = max(1, int(B // max(beff, 1)))
        while act_of(n_mb) > max(budget, 0.0) and n_mb * 2 <= max_mb:
            n_mb *= 2
        mem["activations"] = act_of(n_mb)
        # re-gather params per microbatch (scan body), scatter grads once
        coll["all-gather"] += facc["gather"] * n_mb
        coll["reduce-scatter"] += facc["scatter"]
        if pods > 1:
            grad_dev = facc["stored"] * 2
            coll["dcn"] += _hw.all_reduce_bytes(grad_dev, pods)
    else:
        if not layout.serve_params:
            # fixed rules keep FSDP at serve time: re-gather every step
            coll["all-gather"] += pacc["gather"]
        cacc = _tree_accounting(mesh, sh.cache_specs(
            mesh, cfg, shape, cache_abs), cache_abs)
        mem["cache"] = cacc["stored"]
        mem["activations"] = 4 * t * d * ACT_BYTES / act_shard

    # ---- HBM traffic term --------------------------------------------------
    hbm = mem["params"] * (2.0 if train else 1.0)      # weights read/updated
    if train:
        hbm += mem["optimizer"] + mem["grads"]
        hbm += mem["activations"] * 4                  # remat re-reads
    else:
        hbm += mem.get("cache", 0.0) * (1.0 if decode else 0.5)
        hbm += mem["activations"] * 4

    mem["total"] = sum(mem.values())
    feasible = mem["total"] <= hw.hbm_bytes * hw.hbm_usable

    ici_bytes = sum(coll[k] for k in ("all-gather", "all-reduce",
                                      "reduce-scatter", "all-to-all"))
    terms = {
        "compute": _hw.compute_time(flops, hw),
        "memory": _hw.memory_time(hbm, hw),
        "collective": (_hw.collective_time(ici_bytes, hw)
                       + _hw.collective_time(coll["dcn"], hw, dcn=True)),
    }
    step = _hw.step_time(**{f"{k}_s": v for k, v in terms.items()}) \
        if feasible else float("inf")
    coll["total"] = ici_bytes + coll["dcn"]
    return LayoutCost(layout, terms, coll, mem, feasible, step)


# ---------------------------------------------------------------------------
# search (memoized, deterministic)
# ---------------------------------------------------------------------------

_MEMO: dict = {}


def clear_memo() -> None:
    _MEMO.clear()
    _ABS_CACHE.clear()


def search(cfg: ModelConfig, shape: ShapeConfig, mesh_sig: tuple,
           hw: _hw.HardwareSpec = _hw.TPU_V5E) -> PlanResult:
    """Enumerate → cost → select for one cell.  The fixed-rule layout is
    always in the candidate set, so the winner beats or ties it on
    modeled step time by construction; ties break on :meth:`Layout.key`.
    Results are memoized per (config, shape, mesh, hw)."""
    key = (cfg, shape, mesh_sig, hw)
    if key in _MEMO:
        return _MEMO[key]

    fixed = fixed_layout(cfg, shape, mesh_sig)
    layouts = enumerate_layouts(cfg, shape, mesh_sig)
    if fixed not in layouts:
        layouts.append(fixed)
    costs = [cost_layout(cfg, shape, lay, hw) for lay in layouts]
    by_layout = {c.layout: c for c in costs}
    fixed_cost = by_layout[fixed]

    feasible = [c for c in costs if c.feasible]
    pool = feasible if feasible else [fixed_cost]
    winner = min(pool, key=lambda c: (c.step_time, c.layout.key()))

    result = PlanResult(cfg, shape, mesh_sig, winner, fixed_cost,
                        sorted(costs, key=lambda c: (c.step_time,
                                                     c.layout.key())))
    _MEMO[key] = result
    return result


def plan_layout(mesh, cfg: ModelConfig, shape: ShapeConfig,
                fallback: Optional[Layout] = None) -> Layout:
    """Consumer entry point: the best *realizable* searched layout for a
    real mesh.  A real mesh's axis sizes are fixed and the runtime MoE
    dispatch (``models/moe.py``) follows the EP predicate, so the
    applied candidate must match the mesh's physical TP degree and the
    predicate's expert role — the search report's overall winner may
    additionally recommend re-slicing TP or re-sharding experts, which
    stays advisory until the mesh/model is rebuilt.  When no realizable
    candidate is feasible (or the planner fails), returns ``fallback``
    (default: the fixed-rule layout) — the contract ``layout="auto"``
    relies on."""
    sig = signature_of(mesh)
    fixed = fixed_layout(cfg, shape, sig)
    if fallback is None:
        fallback = fixed
    try:
        res = search(cfg, shape, sig)
        for c in res.candidates:           # sorted (step_time, key)
            if (c.feasible and c.layout.tp == fixed.tp
                    and c.layout.moe == fixed.moe):
                return c.layout
        return fallback
    except Exception as e:                 # pragma: no cover - regression
        import warnings                    # path; consumers stay alive
        warnings.warn(f"layout planner failed for {cfg.name} × "
                      f"{shape.name} ({type(e).__name__}: {e}); "
                      "using the fixed-rule fallback", RuntimeWarning)
        return fallback


#: plan_layout fallback sentinel: lets auto_variant tell "planner chose
#: the fixed layout" apart from "planner failed / nothing realizable"
_NO_PLAN = object()


def auto_variant(mesh, cfg: ModelConfig, shape: ShapeConfig,
                 variant: Optional[dict] = None) -> dict:
    """Merge the searched layout into a dry-run variant dict without
    overriding explicit keys (explicit hillclimb arms win).  On planner
    failure or no realizable candidate the variant is returned
    *unchanged* — the lowered cell is then exactly the fixed-rule
    baseline, not a half-applied layout."""
    out = dict(variant or {})
    lay = plan_layout(mesh, cfg, shape, fallback=_NO_PLAN)
    if lay is _NO_PLAN:
        return out
    out.setdefault("act", lay.act)
    if lay.serve_params:
        out.setdefault("serve_params", True)
    return out


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

def write_report(result: PlanResult, *, name: str, mesh_name: str,
                 out_dir: Optional[Path] = None) -> Path:
    out_dir = Path(out_dir) if out_dir else REPORT_DIR
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{name}__{result.shape.name}__{mesh_name}.json"
    path.write_text(json.dumps(result.to_dict(), indent=1))
    return path


def main() -> None:
    import argparse
    from repro.configs import MESH_SHAPES, SHAPES, all_configs, applicable

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mesh", default=None,
                    choices=[None, *MESH_SHAPES], help="limit to one mesh")
    ap.add_argument("--out", default=None, help="report directory")
    args = ap.parse_args()

    meshes = {args.mesh: MESH_SHAPES[args.mesh]} if args.mesh \
        else MESH_SHAPES
    rows = ["| arch | shape | mesh | fixed ms | auto ms | speedup | "
            "winner |", "|---|---|---|---|---|---|---|"]
    for arch, cfg in all_configs().items():
        for shape in SHAPES.values():
            if not applicable(cfg, shape):
                continue
            for mesh_name, mesh_shape in meshes.items():
                res = search(cfg, shape, signature_of(mesh_shape))
                write_report(res, name=arch, mesh_name=mesh_name,
                             out_dir=args.out)
                w = res.winner.layout
                rows.append(
                    f"| {arch} | {shape.name} | {mesh_name} "
                    f"| {res.fixed.step_time * 1e3:.2f} "
                    f"| {res.winner.step_time * 1e3:.2f} "
                    f"| {res.speedup:.2f}x "
                    f"| tp={w.tp} moe={w.moe} act={w.act} "
                    f"serve_params={w.serve_params} |")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
