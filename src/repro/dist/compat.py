"""JAX version-compat shims for the distribution subsystem.

Mesh-construction sites (``launch/mesh.py``, ``launch/costing.py``, the
dry-run subprocesses and tests) target the modern explicit-sharding API:
``jax.sharding.AxisType`` plus ``jax.make_mesh(..., axis_types=...)``.
Installed JAX releases that predate ``AxisType`` raise ``AttributeError``
on the former and ``TypeError`` on the latter; :func:`install` backfills
both so mesh construction is writable one way everywhere.  Pre-AxisType
meshes behave as all-``Auto``, so dropping an all-``Auto`` request is
exactly the caller's intent (anything else raises).

The backfill deliberately patches the ``jax`` namespace process-wide:
the test suite and dry-run subprocesses use ``jax.sharding.AxisType`` /
``jax.make_mesh(..., axis_types=...)`` directly, so a local wrapper
would not cover them.  On a JAX old enough to need the shim, other
libraries feature-detecting ``AxisType`` via ``hasattr`` will see the
backfill — acceptable in this repo's pinned environments.
"""

from __future__ import annotations

import enum
import inspect

import jax


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def install() -> None:
    """Idempotently backfill ``jax.sharding.AxisType`` and the
    ``axis_types=`` kwarg of ``jax.make_mesh`` on older JAX."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax, "make_mesh"):   # predates make_mesh entirely
        return
    if getattr(jax.make_mesh, "_repro_axis_types_shim", False):
        return
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    orig = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        # Pre-AxisType meshes are implicitly all-Auto; anything else has
        # no equivalent here and must not degrade silently.
        if axis_types is not None and any(
                getattr(t, "name", t) != "Auto" for t in axis_types):
            raise NotImplementedError(
                f"installed JAX only supports Auto mesh axes, "
                f"got axis_types={axis_types}")
        return orig(axis_shapes, axis_names, devices=devices)

    make_mesh._repro_axis_types_shim = True
    make_mesh.__doc__ = orig.__doc__
    jax.make_mesh = make_mesh


def auto_axis_types(n: int) -> tuple:
    """``(AxisType.Auto,) * n`` — for explicit mesh-construction sites."""
    install()
    return (jax.sharding.AxisType.Auto,) * n


install()
