"""Layout rules for the hybrid local/distributed execution stack.

The planner costs fusion plans across *local and distributed* operators
(companion work: costing generated runtime plans), which requires knowing
how every tensor of a cell is laid out on the production mesh before
anything is compiled.  This module is that knowledge: pure, mesh-shaped
functions from abstract leaves to ``PartitionSpec`` trees.  Everything
validates abstractly — no device allocation, no compilation — so the
dry-run can cost 256/512-device pods from a CPU container.

Conventions
-----------
* ``mesh`` only needs ``.shape`` (axis name → size mapping) and
  ``.axis_names``; tests pass a lightweight stand-in.
* The tensor-parallel (TP) axis is named ``"model"``; every other mesh
  axis (``"data"``, ``"pod"``, …) is an FSDP/data axis.
* Rules degrade gracefully: an axis that is absent from the mesh or
  does not divide a dimension is dropped (that dim replicates) — never
  an error.  Within a multi-axis FSDP group, axes are dropped
  left-to-right (``"pod"`` before ``"data"``) until the rest divides.

Parameter layout (megatron-style TP × FSDP)
-------------------------------------------
* Projections *into* head/ff space (``wq``/``wk``/``wv``, dense
  ``w1``/``w3``, ``up``, ``in_proj``) shard their output dim over TP and
  their ``d_model`` dim over FSDP; projections *out of* it (``wo``,
  dense ``w2``, ``down``, ``out_proj``) are the transpose.
* Embedding shards the vocab over TP (vocab-parallel logits) and
  ``d_model`` over FSDP; an untied ``head`` is the transpose.
* MoE expert weights shard the **expert** dim over TP when the expert
  count divides it (expert parallelism — olmoe's 64/16), else fall back
  to ff-TP (grok's 8 experts on a 16-way axis).  The same predicate
  (:func:`moe_expert_parallel`) gates the ``shard_map`` all-to-all
  dispatch in ``models/moe.py``.
* Stacked leaves (the scanned ``blocks`` pytrees carry a leading layer-
  group dim) replicate every leading dim the rule doesn't name: rules
  are aligned to the *trailing* dims of each leaf.
* ``serve=True`` drops the FSDP axes (decode reads weights every step;
  all-gathering them each token is the wrong side of the roofline) and
  keeps TP.

Activation layouts are keyed by short strings (``"btd"``, ``"bthd"``,
``"btf"``, ``"btv"``) and only apply inside the
:func:`activation_rules` context — outside it :func:`constrain` is an
identity, so model code is importable and traceable with no mesh at all.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import compat  # noqa: F401  (installs AxisType/make_mesh shims)

TP_AXIS = "model"


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def tp_axis(mesh) -> Optional[str]:
    """The tensor-parallel axis name, or None if the mesh has none."""
    return TP_AXIS if TP_AXIS in mesh.axis_names else None


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Every mesh axis except the tensor-parallel one, mesh order."""
    return tuple(a for a in mesh.axis_names if a != TP_AXIS)


def axis_size(mesh, axes) -> int:
    """Product of mesh-axis sizes for a None/str/tuple spec entry."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _fit(mesh, dim: int, axes):
    """Largest suffix of ``axes`` that exists in the mesh and divides
    ``dim`` — the graceful-degradation primitive.  Returns a spec entry
    (None / str / tuple)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    while axes:
        n = axis_size(mesh, axes)
        if n > 1 and dim % n == 0:
            return axes[0] if len(axes) == 1 else axes
        axes = axes[1:]
    return None


def _spec(mesh, shape: tuple, roles: tuple) -> P:
    """Build a rank-matched PartitionSpec from per-dim axis requests.

    ``roles`` aligns to the *trailing* dims of ``shape``; leading
    (stacked) dims replicate.  Each entry is divisibility-checked
    against its dim and degrades to None via :func:`_fit`."""
    pad = len(shape) - len(roles)
    if pad < 0:
        return P()
    entries = [None] * pad + [_fit(mesh, d, r)
                              for d, r in zip(shape[pad:], roles)]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _path_keys(path) -> list:
    keys = []
    for entry in path:
        if hasattr(entry, "key"):
            keys.append(entry.key)
        elif hasattr(entry, "idx"):
            keys.append(entry.idx)
        else:
            keys.append(str(entry))
    return keys


# ---------------------------------------------------------------------------
# expert parallelism
# ---------------------------------------------------------------------------

def operand_spec(mesh, shape) -> P:
    """Layout rule for one fused-operator operand (the fusion planner's
    ``FusionLayout.auto``): rows over the FSDP axes, columns over the TP
    axis, with the usual per-dim divisibility degradation — so a (1, n)
    row vector or a matrix whose rows don't divide the data axes simply
    replicates.  This is the spec tree the hybrid local/distributed
    placement (``repro.core.cost.DistParams``) reads its row/column shard
    factors from."""
    return _spec(mesh, tuple(shape), (fsdp_axes(mesh), tp_axis(mesh)))


def moe_expert_parallel(mesh, cfg) -> bool:
    """True when expert weights shard over the TP axis (EP): the expert
    count must be a positive multiple of the axis size.  olmoe (64e) on a
    16-way axis → EP; grok (8e) → ff-TP fallback."""
    tp = tp_axis(mesh)
    return (tp is not None and cfg.n_experts > 0
            and cfg.n_experts % mesh.shape[tp] == 0)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _param_roles(name: Any, base_rank: int, F, tp, ep: bool):
    """Trailing-dim axis requests for one named parameter leaf.

    ``F`` is the FSDP axis group (or None for the serving layout); ``tp``
    the TP axis (or None).  Unknown leaves (norm scales, gate biases,
    SSM vectors) replicate."""
    if name == "embed":                       # (V, d) / (nc, V, d)
        return (tp, F)
    if name == "head":                        # (d, V) / (d, nc·V)
        return (F, tp)
    if name in ("wq", "wk", "wv", "up", "in_proj"):
        return (F, tp)                        # (d_in, heads/ff·…)
    if name in ("wo", "out_proj", "down"):
        return (tp, F)                        # (heads/ff·…, d_out)
    if name in ("w1", "w3"):
        if base_rank == 3:                    # MoE (e, d, f)
            return (tp, F, None) if ep else (None, F, tp)
        return (F, tp)                        # dense (d, f)
    if name == "w2":
        if base_rank == 3:                    # MoE (e, f, d)
            return (tp, None, F) if ep else (None, tp, F)
        return (tp, F)                        # dense (f, d)
    if name == "router":                      # (d, e) — e is tiny
        return (F, None)
    if name == "x_proj":                      # (di, 2N+1)
        return (tp, None)
    if name == "A_log":                       # (di, N)
        return (tp, None)
    if name == "conv_w":                      # (K, di)
        return (None, tp)
    if name == "wif":                         # (di, 2H)
        return (F, None)
    return ()


def param_specs(mesh, cfg, params, *, serve: bool = False,
                moe: Optional[str] = None):
    """PartitionSpec tree mirroring ``params`` (the ``LM.init`` tree).

    Every spec is rank-matched and divisibility-checked against its
    abstract leaf; ``serve=True`` drops the FSDP axes (TP only).
    ``moe`` forces the expert-weight role (``"ep"`` / ``"fftp"``) instead
    of the :func:`moe_expert_parallel` predicate — the layout planner
    costs both roles; ``None`` keeps the fixed rule."""
    F = None if serve else (fsdp_axes(mesh) or None)
    tp = tp_axis(mesh)
    ep = moe_expert_parallel(mesh, cfg) if moe is None else (moe == "ep")

    def rule(path, leaf):
        keys = _path_keys(path)
        stacked = bool(keys) and keys[0] == "blocks"
        name = keys[-1] if keys else None
        shape = tuple(leaf.shape)
        base_rank = len(shape) - 1 if stacked else len(shape)
        return _spec(mesh, shape, _param_roles(name, base_rank, F, tp, ep))

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# decode-cache specs
# ---------------------------------------------------------------------------

#: trailing-dim axis requests per cache leaf name (leading stacked layer-
#: group dims replicate).  "F"/"tp" placeholders resolved per mesh.
_CACHE_ROLES = {
    "k":    ("F", None, "tp", None),    # (B, S, KV, hd) — heads over TP
    "v":    ("F", None, "tp", None),
    "h":    ("F", "tp", None),          # mamba (B, di, N)
    "conv": ("F", None, "tp"),          # mamba (B, K-1, di)
    "C":    ("F", "tp", None, None),    # mlstm (B, H, hd, hd)
    "n":    ("F", "tp", None),          # mlstm (B, H, hd)
    "m":    ("F", "tp"),                # mlstm (B, H)
}


def cache_specs(mesh, cfg, shape, cache):
    """PartitionSpec tree for the decode cache (``LM.init_cache``
    structure): batch over the FSDP axes, head/state dims over TP, with
    per-dim divisibility fallback (e.g. 8 KV heads on a 16-way axis
    replicate)."""
    del shape  # layout depends only on leaf shapes; kept for API parity
    F = fsdp_axes(mesh) or None
    tp = tp_axis(mesh)
    resolve = {"F": F, "tp": tp, None: None}

    def rule(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else None
        roles = tuple(resolve[r] for r in _CACHE_ROLES.get(name, ()))
        return _spec(mesh, tuple(leaf.shape), roles)

    return jax.tree_util.tree_map_with_path(rule, cache)


# ---------------------------------------------------------------------------
# batch specs + lifting
# ---------------------------------------------------------------------------

def batch_spec(mesh, cfg, batch: int, n_rest: int = 0) -> P:
    """Input-batch layout: dim 0 over the FSDP axes (when divisible),
    ``n_rest`` trailing dims replicated."""
    del cfg
    return _spec(mesh, (batch,) + (1,) * n_rest,
                 (fsdp_axes(mesh) or None,) + (None,) * n_rest)


def named(mesh, specs):
    """Lift a PartitionSpec tree into NamedShardings on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation-sharding rules
# ---------------------------------------------------------------------------

_ACT = threading.local()


def current_rules():
    """The (mesh, mode) pair of the innermost active
    :func:`activation_rules` context, or None."""
    return getattr(_ACT, "rules", None)


@contextmanager
def activation_rules(mesh, mode: str = "dp"):
    """Enable activation-sharding constraints for traces inside the
    context.  ``mode``: ``"dp"`` (batch over FSDP, TP on head/ff/vocab
    dims) or ``"sp"`` (additionally sequence-parallel residuals)."""
    prev = current_rules()
    _ACT.rules = (mesh, mode)
    try:
        yield
    finally:
        _ACT.rules = prev


def activation_spec(mesh, layout: str, shape: tuple,
                    mode: str = "dp") -> Optional[P]:
    """PartitionSpec for an activation of the given layout string, or
    None for an unknown layout / rank mismatch."""
    F = fsdp_axes(mesh) or None
    tp = tp_axis(mesh)
    roles = {
        "btd": (F, tp if mode == "sp" else None, None),
        "bthd": (F, None, tp, None),
        "btf": (F, None, tp),
        "btv": (F, None, tp),
    }.get(layout)
    if roles is None or len(roles) != len(shape):
        return None
    return _spec(mesh, shape, roles)


def constrain(x, layout: str):
    """Activation-sharding annotation.  Identity outside
    :func:`activation_rules`; inside, applies the mode's layout rule via
    ``with_sharding_constraint`` (divisibility-checked per dim)."""
    rules = current_rules()
    if rules is None:
        return x
    mesh, mode = rules
    spec = activation_spec(mesh, layout, tuple(x.shape), mode)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
