"""Small shared helpers for the algorithm suite."""

import jax.numpy as jnp


def fs(x) -> float:
    """Python float from any single-element array (fused ops return (1,1))."""
    return float(jnp.asarray(x).reshape(()))
