"""Two-layer sigmoid autoencoder (H1=500, H2=2, batch=512) — SystemML
`autoencoder-2layer.dml`.

Mini-batch SGD with momentum.  The whole forward (4 GEMMs + the
bias+activation Cell chains + the loss aggregate) is one fused region;
the hand-written backprop (the δ ⊙ h ⊙ (1−h) sprop chains) is gone —
``jax.grad`` of the fused forward plans the gradient DAG through
explore → select, which regenerates exactly those sprop Cell chains as
fused backward operators (the paper's AutoEncoder fusion profile, §5.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir, fused, FusionContext


@fused
def _recon_loss(Xb, W1, b1, W2, b2, W3, b3, W4, b4):
    """Σ (dec(enc(Xb)) − Xb)² — the full forward as one expression DAG."""
    H1 = ir.sigmoid(Xb @ W1 + b1)
    H2 = ir.sigmoid(H1 @ W2 + b2)
    H3 = ir.sigmoid(H2 @ W3 + b3)
    O = H3 @ W4 + b4
    return ((O - Xb) ** 2).sum()


def run(X, h1: int = 64, h2: int = 2, batch: int = 128, epochs: int = 1,
        lr: float = 0.1, mu: float = 0.9, mode: str = "gen",
        pallas: str = "never", seed: int = 0, staged: bool = True):
    """Returns (params, loss per step).  ``staged=False`` drops the fused
    forward/backward to per-operator dispatch (debug path)."""
    if mode == "hand":
        return _run_hand(X, h1, h2, batch, epochs, lr, mu, seed)
    m, n = X.shape
    rng = np.random.default_rng(seed)

    def init(i, o):
        return jnp.asarray(rng.normal(size=(i, o)).astype(np.float32)
                           * np.sqrt(2.0 / i))

    Ws = [init(n, h1), init(h1, h2), init(h2, h1), init(h1, n)]
    bs = [jnp.zeros((1, d), jnp.float32) for d in (h1, h2, h1, n)]
    vel = [jnp.zeros_like(w) for w in Ws]
    losses = []
    steps = max(1, (m // batch) * epochs)
    with FusionContext(mode=mode, pallas=pallas, staged=staged):
        def loss_fn(Xb, Ws_, bs_):
            return _recon_loss(Xb, Ws_[0], bs_[0], Ws_[1], bs_[1],
                               Ws_[2], bs_[2], Ws_[3], bs_[3])[0, 0] / batch
        val_grads = jax.value_and_grad(loss_fn, argnums=(1, 2))
        for step in range(steps):
            lo = (step * batch) % max(m - batch, 1)
            Xb = X[lo:lo + batch]
            val, (grads, dbs) = val_grads(Xb, Ws, bs)
            losses.append(float(val))
            for i in range(4):
                vel[i] = mu * vel[i] - lr * grads[i]
                Ws[i] = Ws[i] + vel[i]
                bs[i] = bs[i] - lr * dbs[i]
    return (Ws, bs), losses


def _run_hand(X, h1, h2, batch, epochs, lr, mu, seed):
    m, n = X.shape
    rng = np.random.default_rng(seed)

    def init(i, o):
        return jnp.asarray(rng.normal(size=(i, o)).astype(np.float32)
                           * np.sqrt(2.0 / i))

    Ws = [init(n, h1), init(h1, h2), init(h2, h1), init(h1, n)]
    bs = [jnp.zeros((1, d), jnp.float32) for d in (h1, h2, h1, n)]
    vel = [jnp.zeros_like(w) for w in Ws]
    sig = lambda z: 1 / (1 + jnp.exp(-z))
    losses = []
    steps = max(1, (m // batch) * epochs)
    for step in range(steps):
        lo = (step * batch) % max(m - batch, 1)
        Xb = X[lo:lo + batch]
        H1 = sig(Xb @ Ws[0] + bs[0])
        H2 = sig(H1 @ Ws[1] + bs[1])
        H3 = sig(H2 @ Ws[2] + bs[2])
        O = H3 @ Ws[3] + bs[3]
        R = O - Xb
        losses.append(float(jnp.sum(R * R)) / batch)
        D4 = 2.0 * R / batch
        G4 = H3.T @ D4
        D3 = (D4 @ Ws[3].T) * H3 * (1 - H3)
        G3 = H2.T @ D3
        D2 = (D3 @ Ws[2].T) * H2 * (1 - H2)
        G2 = H1.T @ D2
        D1 = (D2 @ Ws[1].T) * H1 * (1 - H1)
        G1 = Xb.T @ D1
        grads = [G1, G2, G3, G4]
        dbs = [D1.sum(0, keepdims=True), D2.sum(0, keepdims=True),
               D3.sum(0, keepdims=True), D4.sum(0, keepdims=True)]
        for i in range(4):
            vel[i] = mu * vel[i] - lr * grads[i]
            Ws[i] = Ws[i] + vel[i]
            bs[i] = bs[i] - lr * dbs[i]
    return (Ws, bs), losses
