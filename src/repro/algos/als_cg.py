"""ALS-CG matrix factorization (rank 20, weighted-L2) — SystemML `ALS-CG.dml`.

The paper's flagship sparsity workload.  Each factor update runs conjugate
gradient where gradient and Hessian-action are Outer-template operators
over the block-sparse ratings:

    grad_U = ((X≠0) ⊙ (UVᵀ))·V − X·V + λU          (Expression (1))
    H_U(s) = ((X≠0) ⊙ (sVᵀ))·V + λs

Work is ∝ non-zero blocks of X — never the dense m×n product.  The V
update runs the same operators against Xᵀ (BCSR transpose).
"""

from __future__ import annotations

import jax.numpy as jnp

from .util import fs
from repro.core import ir, fused, FusionContext
from repro.kernels.blocksparse import BCSR
from repro.kernels.ops import bcsr_matmul


@fused
def _wsq_mm(X, U, V):
    """((X≠0) ⊙ (U Vᵀ)) V — the sparsity-exploiting right_mm."""
    return (ir.neq0(X) * (U @ V.T)) @ V


@fused
def _loss_terms(X, U, V):
    """Σ ((X≠0)⊙(UVᵀ − X))² — sparse-safe squared error over non-zeros.

    (X≠0)⊙X = X, so the residual chain stays sparse-safe w.r.t. X."""
    R = ir.neq0(X) * (U @ V.T) - X
    return (R ** 2).sum()


def _grad_U(X, U, V, lam):
    return _wsq_mm(X, U, V) - bcsr_matmul(X, V) + lam * U


def _hvp_U(X, s, V, lam):
    return _wsq_mm(X, s, V) + lam * s


def _cg_update(X, U, V, lam, max_inner, eps):
    g = _grad_U(X, U, V, lam)
    d = jnp.zeros_like(U)
    r = -g
    p = r
    rs = float(jnp.sum(r * r))
    for _ in range(max_inner):
        Hp = _hvp_U(X, p, V, lam)
        alpha = rs / max(float(jnp.sum(p * Hp)), 1e-30)
        d = d + alpha * p
        r = r - alpha * Hp
        rs_new = float(jnp.sum(r * r))
        if rs_new < eps:
            break
        p = r + (rs_new / rs) * p
        rs = rs_new
    return U + d


def run(X: BCSR, rank: int = 20, lam: float = 1e-3, max_iter: int = 6,
        max_inner: int = 5, eps: float = 1e-12, mode: str = "gen",
        pallas: str = "never", seed: int = 0):
    """Returns (U, V, loss per outer iteration)."""
    if mode == "hand":
        return _run_hand(X, rank, lam, max_iter, max_inner, eps, seed)
    import numpy as np
    rng = np.random.default_rng(seed)
    m, n = X.shape
    U = jnp.asarray(rng.normal(size=(m, rank)).astype(np.float32)) * 0.1
    V = jnp.asarray(rng.normal(size=(n, rank)).astype(np.float32)) * 0.1
    XT = X.T
    losses = []
    with FusionContext(mode=mode, pallas=pallas):
        for _ in range(max_iter):
            U = _cg_update(X, U, V, lam, max_inner, eps)
            V = _cg_update(XT, V, U, lam, max_inner, eps)
            losses.append(fs(_loss_terms(X, U, V))
                          + lam * (float(jnp.sum(U * U))
                                   + float(jnp.sum(V * V))))
    return U, V, losses


def _run_hand(X: BCSR, rank, lam, max_iter, max_inner, eps, seed):
    """Dense-mask jnp baseline (hand-fused): materializes W=(X≠0) once."""
    import numpy as np
    rng = np.random.default_rng(seed)
    m, n = X.shape
    Xd = X.todense()
    W = (Xd != 0).astype(jnp.float32)
    U = jnp.asarray(rng.normal(size=(m, rank)).astype(np.float32)) * 0.1
    V = jnp.asarray(rng.normal(size=(n, rank)).astype(np.float32)) * 0.1

    def upd(Xd, W, U, V):
        def grad(U):
            return (W * (U @ V.T)) @ V - Xd @ V + lam * U
        g = grad(U)
        d = jnp.zeros_like(U)
        r = -g
        p = r
        rs = float(jnp.sum(r * r))
        for _ in range(max_inner):
            Hp = (W * (p @ V.T)) @ V + lam * p
            alpha = rs / max(float(jnp.sum(p * Hp)), 1e-30)
            d = d + alpha * p
            r = r - alpha * Hp
            rs_new = float(jnp.sum(r * r))
            if rs_new < eps:
                break
            p = r + (rs_new / rs) * p
            rs = rs_new
        return U + d

    losses = []
    for _ in range(max_iter):
        U = upd(Xd, W, U, V)
        V = upd(Xd.T, W.T, V, U)
        losses.append(float(jnp.sum((W * (U @ V.T) - Xd) ** 2))
                      + lam * (float(jnp.sum(U * U))
                               + float(jnp.sum(V * V))))
    return U, V, losses
