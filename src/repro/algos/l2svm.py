"""L2-regularized squared-hinge SVM (2 classes) — SystemML `l2-svm.dml`.

Outer conjugate-direction iterations with an exact inner Newton line
search.  Fusion sites: the hinge chain relu(1 − y⊙(Xw)) (Cell), the
line-search and objective multi-aggregates (MAgg), and Xᵀ(out⊙y) (Row).

The gradient is ``jax.grad`` of the fused objective: the backward pass is
planned through explore → select, so ∇obj executes the same generated
Row-template operator the hand-derived ``_grad`` expression pins in
``tests/golden/plans.json`` (the parity harness keeps both in lockstep).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .util import fs
from repro.core import ir, fused, FusionContext

# fused regions ---------------------------------------------------------------

@fused
def _hinge(X, w, y):
    return ir.relu(1.0 - y * (X @ w))


@fused
def _objective_full(X, w, y, lam):
    """0.5·Σ relu(1 − y⊙(Xw))² + 0.5·λ·Σ w² — differentiable fused forward;
    jax.grad of this replaces the hand-written −Xᵀ(out⊙y) + λw."""
    out = ir.relu(1.0 - y * (X @ w))
    return 0.5 * (out ** 2).sum() + 0.5 * lam * (w ** 2).sum()


# hand-derived gradient + split objective: golden-plan pins and the
# jax.grad parity harness (tests/test_staged_api.py) — not used by run().
@fused
def _grad(X, out, y, w, lam):
    return -1.0 * (X.T @ (out * y)) + lam * w


@fused
def _search_terms(out, yXs):
    act = out > 0.0
    return (act * out * yXs).sum(), (act * yXs * yXs).sum()


@fused
def _objective(out, w):
    return (out ** 2).sum(), (w ** 2).sum()


def run(X, y, lam: float = 1e-3, max_iter: int = 20, eps: float = 1e-12,
        mode: str = "gen", pallas: str = "never", layout=None,
        staged: bool = True):
    """Returns (w, objective per iteration).

    ``layout`` (a mesh or ``FusionLayout``) scopes every fused region
    through hybrid local/distributed planning: row-parallel operators over
    X run mesh-wide (psum/row-partitioned epilogues), the small w-space
    aggregates stay local.  ``staged=False`` drops to per-operator
    dispatch (debug path; default is one jitted computation per plan)."""
    if mode == "hand":
        return _run_hand(X, y, lam, max_iter, eps)
    m, n = X.shape
    w = jnp.zeros((n, 1), jnp.float32)
    lam_s = jnp.full((1, 1), lam, jnp.float32)
    objs = []
    with FusionContext(mode=mode, pallas=pallas, layout=layout,
                       staged=staged):
        obj_grad = jax.value_and_grad(
            lambda w_: _objective_full(X, w_, y, lam_s)[0, 0])
        _, g = obj_grad(w)
        s = -g
        for _ in range(max_iter):
            Xs = X @ s                        # basic GEMV
            out = _hinge(X, w, y)
            num_t, den_t = _search_terms(out, y * Xs)
            num = fs(num_t) - lam * float(jnp.sum(w * s))
            den = fs(den_t) + lam * float(jnp.sum(s * s))
            step = num / max(den, 1e-30)
            w = w + step * s
            val, g_new = obj_grad(w)          # fused forward + fused backward
            objs.append(float(val))
            beta = float(jnp.sum(g_new * g_new)) / max(
                float(jnp.sum(g * g)), 1e-30)
            s = -g_new + beta * s
            g = g_new
            if float(jnp.sum(g * g)) < eps:
                break
    return w, objs


def _run_hand(X, y, lam, max_iter, eps):
    """Hand-written jnp baseline (the paper's 'Fused' arm)."""
    m, n = X.shape
    w = jnp.zeros((n, 1), jnp.float32)
    out = jnp.maximum(1.0 - y * (X @ w), 0.0)
    g = -(X.T @ (out * y)) + lam * w
    s = -g
    objs = []
    for _ in range(max_iter):
        Xs = X @ s
        out = jnp.maximum(1.0 - y * (X @ w), 0.0)
        act = (out > 0).astype(jnp.float32)
        yXs = y * Xs
        num = float(jnp.sum(act * out * yXs)) - lam * float(jnp.sum(w * s))
        den = float(jnp.sum(act * yXs * yXs)) + lam * float(jnp.sum(s * s))
        step = num / max(den, 1e-30)
        w = w + step * s
        out = jnp.maximum(1.0 - y * (X @ w), 0.0)
        objs.append(0.5 * float(jnp.sum(out ** 2))
                    + 0.5 * lam * float(jnp.sum(w ** 2)))
        g_new = -(X.T @ (out * y)) + lam * w
        beta = float(jnp.sum(g_new * g_new)) / max(float(jnp.sum(g * g)),
                                                   1e-30)
        s = -g_new + beta * s
        g = g_new
        if float(jnp.sum(g * g)) < eps:
            break
    return w, objs
