"""GLM, binomial-probit — SystemML `GLM.dml` (dfam=2, link=probit) via
iteratively re-weighted least squares with an inner CG solve.

Fusion sites: the probit link/mean/variance chain over η (Cell; erf-based),
the working-response chain (Cell), weighted cross-products Xᵀ(w⊙Xv) (Row),
and the deviance multi-aggregate.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from .util import fs
from repro.core import ir, fused, FusionContext

_SQRT2 = math.sqrt(2.0)
_SQRT2PI = math.sqrt(2.0 * math.pi)


@fused
def _link_chain(eta, y):
    """mu, dens, working weight w = dens²/var, working residual r."""
    mu = 0.5 * (ir.erf(eta / _SQRT2) + 1.0)
    mu = ir.minimum(ir.maximum(mu, 1e-7), 1.0 - 1e-7)
    dens = ir.exp(-0.5 * eta * eta) / _SQRT2PI
    var = mu * (1.0 - mu)
    w = dens * dens / var
    r = (y - mu) / ir.maximum(dens, 1e-30)
    return w, r


@fused
def _wxv(X, w, v):
    """Xᵀ (w ⊙ (X v)) — the IRLS normal-equation HVP (Row template)."""
    return X.T @ (w * (X @ v))


@fused
def _wz(X, w, r):
    return X.T @ (w * r)


@fused
def _deviance(y, eta):
    mu = 0.5 * (ir.erf(eta / _SQRT2) + 1.0)
    mu = ir.minimum(ir.maximum(mu, 1e-7), 1.0 - 1e-7)
    return (y * ir.log(mu) + (1.0 - y) * ir.log(1.0 - mu)).sum()


def run(X, y, lam: float = 1e-3, max_outer: int = 8, max_inner: int = 10,
        eps: float = 1e-12, mode: str = "gen", pallas: str = "never"):
    """Returns (beta, deviance per outer iteration)."""
    if mode == "hand":
        return _run_hand(X, y, lam, max_outer, max_inner, eps)
    m, n = X.shape
    beta = jnp.zeros((n, 1), jnp.float32)
    devs = []
    with FusionContext(mode=mode, pallas=pallas):
        for _ in range(max_outer):
            eta = X @ beta
            w, r = _link_chain(eta, y)
            devs.append(-2.0 * fs(_deviance(y, eta)))
            rhs = _wz(X, w, r) - lam * beta
            # CG on (XᵀWX + lam I) d = rhs
            d = jnp.zeros_like(beta)
            res = rhs
            p = res
            rs = float(jnp.sum(res * res))
            for _ in range(max_inner):
                Hp = _wxv(X, w, p) + lam * p
                alpha = rs / max(float(jnp.sum(p * Hp)), 1e-30)
                d = d + alpha * p
                res = res - alpha * Hp
                rs_new = float(jnp.sum(res * res))
                if rs_new < eps:
                    break
                p = res + (rs_new / rs) * p
                rs = rs_new
            beta = beta + d
    return beta, devs


def _run_hand(X, y, lam, max_outer, max_inner, eps):
    from jax.scipy.special import erf
    m, n = X.shape
    beta = jnp.zeros((n, 1), jnp.float32)
    devs = []
    for _ in range(max_outer):
        eta = X @ beta
        mu = jnp.clip(0.5 * (erf(eta / _SQRT2) + 1.0), 1e-7, 1 - 1e-7)
        dens = jnp.exp(-0.5 * eta * eta) / _SQRT2PI
        w = dens * dens / (mu * (1 - mu))
        r = (y - mu) / jnp.maximum(dens, 1e-30)
        devs.append(-2.0 * float(jnp.sum(y * jnp.log(mu)
                                         + (1 - y) * jnp.log(1 - mu))))
        rhs = X.T @ (w * r) - lam * beta
        d = jnp.zeros_like(beta)
        res = rhs
        p = res
        rs = float(jnp.sum(res * res))
        for _ in range(max_inner):
            Hp = X.T @ (w * (X @ p)) + lam * p
            alpha = rs / max(float(jnp.sum(p * Hp)), 1e-30)
            d = d + alpha * p
            res = res - alpha * Hp
            rs_new = float(jnp.sum(res * res))
            if rs_new < eps:
                break
            p = res + (rs_new / rs) * p
            rs = rs_new
        beta = beta + d
    return beta, devs
