"""Multinomial logistic regression via trust-region Newton-CG — SystemML
`MultiLogReg.dml`.

The Hessian-vector product is the paper's Expression (2):

    Q = P[,1:k] ⊙ (X v)
    H = Xᵀ (Q − P[,1:k] ⊙ rowSums(Q))     — one Row-template pass over X.

Fusion sites: softmax probabilities (Row), the HVP (Row col_t_agg), the
gradient Xᵀ(P−Y) (Row), and the log-likelihood aggregate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ir, fused, FusionContext


def _softmax_probs_expr(X, B):
    """P (m,k) from logits X@B with an implicit 0-logit baseline class is
    omitted — we use full k-class softmax (Icpt=0, paper config)."""
    Z = X @ B
    m = Z.rowmaxs()
    E = ir.exp(Z - m)
    return E / E.rowsums()


_probs = fused(_softmax_probs_expr)


@fused
def _nll_obj(X, B, Y):
    """−Σ Y⊙log P — differentiable fused forward; jax.grad of this w.r.t.
    B replaces the hand-written Xᵀ(P−Y) (the backward pass is planned, and
    the rowmaxs subgradient cancels by softmax shift-invariance)."""
    Z = X @ B
    m = Z.rowmaxs()
    E = ir.exp(Z - m)
    P = E / E.rowsums()
    return 0.0 - (Y * ir.log(P + 1e-30)).sum()


@fused
def _nll_obj_reg(X, B, Y, lam):
    """−Σ Y⊙log P + 0.5·λ·Σ B² — the full regularized objective as one
    fused region.  Its HOP DAG has two plan partitions with different
    natural placements: the X-row-parallel softmax/NLL chain (mesh-wide
    under a layout, psum epilogue) and the tiny B-space regularizer
    multi-aggregate (local) — the canonical hybrid plan."""
    Z = X @ B
    m = Z.rowmaxs()
    E = ir.exp(Z - m)
    P = E / E.rowsums()
    return (0.0 - (Y * ir.log(P + 1e-30)).sum()
            + 0.5 * lam * (B ** 2).sum())


@fused
def _hvp(X, v, P):
    Q = P * (X @ v)
    return X.T @ (Q - P * Q.rowsums())


# hand-derived gradient + NLL aggregate: golden-plan pins and the jax.grad
# parity harness — run() differentiates the regularized _nll_obj_reg.
@fused
def _grad(X, P, Y):
    return X.T @ (P - Y)


@fused
def _nll_terms(P, Y):
    return (Y * ir.log(P + 1e-30)).sum()


# the fit sufficient statistic ⟨XᵀY, B⟩ = Σ B⊙(XᵀY), written in its
# textbook form.  As written the planner needs two operators (the (n,k)
# XᵀY product, then the weighted aggregate); the SPORES rotation
# sum(B⊙(XᵀY)) = sum((X@B)⊙Y) is a single Row-template pass over X with
# no (n,k) intermediate — the rewrite sweep's demonstrable win, pinned by
# tests/golden/explain_rewrite_mlogreg.json.
@fused
def _fit_terms(X, B, Y):
    return (B * (X.T @ Y)).sum()


def run(X, Y, lam: float = 1e-3, max_outer: int = 10, max_inner: int = 20,
        eps: float = 1e-12, mode: str = "gen", pallas: str = "never",
        layout=None, staged: bool = True):
    """Returns (B, regularized objective per outer iteration).

    ``layout`` (a mesh or ``FusionLayout``) plans every fused region
    hybrid local/distributed — see :func:`_nll_obj_reg`.
    ``staged=False`` drops to per-operator dispatch (debug path)."""
    if mode == "hand":
        return _run_hand(X, Y, lam, max_outer, max_inner, eps)
    m, n = X.shape
    k = Y.shape[1]
    B = jnp.zeros((n, k), jnp.float32)
    lam_s = jnp.full((1, 1), lam, jnp.float32)
    nlls = []
    with FusionContext(mode=mode, pallas=pallas, layout=layout,
                       staged=staged):
        obj_grad = jax.value_and_grad(
            lambda B_: _nll_obj_reg(X, B_, Y, lam_s)[0, 0])
        for _ in range(max_outer):
            P = _probs(X, B)
            val, G = obj_grad(B)          # fused forward + fused backward
            nlls.append(float(val))       # == NLL + 0.5·λ‖B‖² as before
            # CG solve (H + lam I) d = -G with fused HVPs
            d = jnp.zeros_like(B)
            r = -G
            p = r
            rs = float(jnp.sum(r * r))
            for _ in range(max_inner):
                Hp = _hvp(X, p, P) + lam * p
                alpha = rs / max(float(jnp.sum(p * Hp)), 1e-30)
                d = d + alpha * p
                r = r - alpha * Hp
                rs_new = float(jnp.sum(r * r))
                if rs_new < eps:
                    break
                p = r + (rs_new / rs) * p
                rs = rs_new
            B = B + d
    return B, nlls


def _run_hand(X, Y, lam, max_outer, max_inner, eps):
    m, n = X.shape
    k = Y.shape[1]
    B = jnp.zeros((n, k), jnp.float32)
    nlls = []

    def probs(B):
        Z = X @ B
        Z = Z - Z.max(axis=1, keepdims=True)
        E = jnp.exp(Z)
        return E / E.sum(axis=1, keepdims=True)

    for _ in range(max_outer):
        P = probs(B)
        nll = -float(jnp.sum(Y * jnp.log(P + 1e-30))) \
            + 0.5 * lam * float(jnp.sum(B * B))
        nlls.append(nll)
        G = X.T @ (P - Y) + lam * B
        d = jnp.zeros_like(B)
        r = -G
        p = r
        rs = float(jnp.sum(r * r))
        for _ in range(max_inner):
            Q = P * (X @ p)
            Hp = X.T @ (Q - P * Q.sum(axis=1, keepdims=True)) + lam * p
            alpha = rs / max(float(jnp.sum(p * Hp)), 1e-30)
            d = d + alpha * p
            r = r - alpha * Hp
            rs_new = float(jnp.sum(r * r))
            if rs_new < eps:
                break
            p = r + (rs_new / rs) * p
            rs = rs_new
        B = B + d
    return B, nlls
