"""Synthetic data generators for the algorithm suite (paper §5.1 'rand and
algorithm-specific data generation scripts')."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.blocksparse import BCSR


def classification(m: int, n: int, k: int = 2, seed: int = 0,
                   sparsity: float = 1.0):
    """Linearly-separable-ish multiclass data; labels one-hot (m,k) and
    binary ±1 (m,1) for 2-class."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n)).astype(np.float32)
    if sparsity < 1.0:
        X *= (rng.random((m, n)) < sparsity)
    w_true = rng.normal(size=(n, k)).astype(np.float32)
    logits = X @ w_true + 0.5 * rng.normal(size=(m, k)).astype(np.float32)
    y_idx = logits.argmax(axis=1)
    Y = np.eye(k, dtype=np.float32)[y_idx]
    y_pm = (2.0 * (y_idx == 0) - 1.0).astype(np.float32).reshape(m, 1)
    return jnp.asarray(X), jnp.asarray(Y), jnp.asarray(y_pm)


def regression(m: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n)).astype(np.float32)
    w = rng.normal(size=(n, 1)).astype(np.float32)
    p = 1 / (1 + np.exp(-(X @ w)))
    y = (rng.random((m, 1)) < p).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y)


def clusters(m: int, n: int, k: int = 5, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, n)).astype(np.float32) * 4.0
    asg = rng.integers(0, k, size=m)
    X = centers[asg] + rng.normal(size=(m, n)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(centers)


def ratings(m: int, n: int, rank: int = 8, bs: int = 128,
            block_density: float = 0.25, seed: int = 0):
    """Low-rank block-sparse rating matrix (ALS-CG input) as BCSR."""
    rng = np.random.default_rng(seed)
    mb, nb = m // bs, n // bs
    Ut = rng.normal(size=(m, rank)).astype(np.float32) / np.sqrt(rank)
    Vt = rng.normal(size=(n, rank)).astype(np.float32) / np.sqrt(rank)
    mask = rng.random((mb, nb)) < block_density
    mask.flat[0] = True
    dense = (Ut @ Vt.T + 0.1 * rng.normal(size=(m, n))).astype(np.float32)
    dense *= np.kron(mask, np.ones((bs, bs), np.float32))
    return BCSR.from_dense(dense, bs=bs)


def images(m: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = (rng.random((m, n)) < 0.25) * rng.random((m, n))
    return jnp.asarray(X.astype(np.float32))
