"""The paper's Table-2 algorithm suite, built on the fusion API.

Every algorithm runs under any experimental arm:
  mode ∈ {"gen", "fa", "fnr", "none"}  — planner arms (Gen / Gen-FA /
  Gen-FNR / Base), plus ``"hand"`` — direct jnp, the stand-in for
  SystemML's hand-coded fused operators (XLA fuses locally).
"""

from . import als_cg, autoencoder, data, glm, kmeans, l2svm, mlogreg

ALGOS = {
    "l2svm": l2svm,
    "mlogreg": mlogreg,
    "glm": glm,
    "kmeans": kmeans,
    "als_cg": als_cg,
    "autoencoder": autoencoder,
}
