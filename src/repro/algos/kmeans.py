"""K-Means (Lloyd's, 1 run, k=5) — SystemML `Kmeans.dml`.

Fusion sites: the distance-matrix post-processing chain
D = rowSums(X²) − 2·XCᵀ + rowSums(C²)ᵀ with the row-min reduction (Row),
and the WCSS multi-aggregate.  The assignment matmuls stay basic GEMMs.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import fused, FusionContext


@fused
def _sq_rowsums(X):
    return (X ** 2).rowsums()


@fused
def _min_dist(XC, xsq, csq):
    """Row-wise min over D = xsq − 2·XC + csqᵀ (distances to centroids)."""
    D = xsq - 2.0 * XC + csq
    return D._agg("min", "row")


def run(X, C0, max_iter: int = 20, eps: float = 1e-12, mode: str = "gen",
        pallas: str = "never"):
    """Returns (C, within-cluster sum of squares per iteration)."""
    if mode == "hand":
        return _run_hand(X, C0, max_iter, eps)
    m, n = X.shape
    k = C0.shape[0]
    C = C0
    wcss_hist = []
    with FusionContext(mode=mode, pallas=pallas):
        xsq = _sq_rowsums(X)                       # constant across iters
        for _ in range(max_iter):
            XC = X @ C.T                           # basic GEMM
            csq = jnp.sum(C * C, axis=1).reshape(1, k)
            dmin = _min_dist(XC, xsq, csq)
            # hard assignment (argmin) — data movement, not LA: jnp
            D = xsq - 2.0 * XC + csq
            A = jnp.equal(D, dmin).astype(jnp.float32)
            A = A / A.sum(axis=1, keepdims=True)   # break ties evenly
            wcss = float(jnp.sum(dmin))
            wcss_hist.append(wcss)
            counts = A.sum(axis=0).reshape(k, 1)
            C_new = (A.T @ X) / jnp.maximum(counts, 1.0)
            if float(jnp.max(jnp.abs(C_new - C))) < eps:
                C = C_new
                break
            C = C_new
    return C, wcss_hist


def _run_hand(X, C0, max_iter, eps):
    m, n = X.shape
    k = C0.shape[0]
    C = C0
    xsq = jnp.sum(X * X, axis=1, keepdims=True)
    hist = []
    for _ in range(max_iter):
        D = xsq - 2.0 * (X @ C.T) + jnp.sum(C * C, axis=1)[None, :]
        dmin = D.min(axis=1, keepdims=True)
        A = jnp.equal(D, dmin).astype(jnp.float32)
        A = A / A.sum(axis=1, keepdims=True)
        hist.append(float(jnp.sum(dmin)))
        counts = A.sum(axis=0).reshape(k, 1)
        C_new = (A.T @ X) / jnp.maximum(counts, 1.0)
        if float(jnp.max(jnp.abs(C_new - C))) < eps:
            C = C_new
            break
        C = C_new
    return C, hist
