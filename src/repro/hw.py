"""Shared hardware model — the costing substrate.

Single source of truth for the accelerator roofline constants and the
bandwidth-normalized time/volume terms used by *both* cost models in this
repo:

* the fusion planner's analytical operator costs (``core/cost.py``,
  paper §4.3 Eq. 4 — read/write/compute bandwidths), and
* the distributed layer: the layout planner (``dist/planner.py``) and the
  dry-run roofline analysis (``launch/roofline.py``).

Everything is expressed per chip: FLOP/s, HBM B/s, ICI B/s per link, and
HBM capacity for memory-feasibility pruning.  Collective volume helpers
follow the standard ring formulations (per-device bytes moved over ICI),
so ``collective_time(all_reduce_bytes(size, n))`` is the modeled ring
all-reduce latency at full link utilization.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 MXU FLOP/s
    hbm_bw: float = 819e9            # HBM B/s
    ici_bw: float = 50e9             # ICI B/s per link
    dcn_bw: float = 6.25e9           # cross-pod (DCN) B/s per chip
    hbm_bytes: float = 16e9          # HBM capacity per chip
    #: fraction of HBM usable for program state (rest: XLA scratch,
    #: fragmentation) — the layout planner's feasibility threshold.
    hbm_usable: float = 0.9


TPU_V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# roofline time terms (seconds, per chip)
# ---------------------------------------------------------------------------

def compute_time(flops: float, hw: HardwareSpec = TPU_V5E) -> float:
    return flops / hw.peak_flops


def memory_time(nbytes: float, hw: HardwareSpec = TPU_V5E) -> float:
    return nbytes / hw.hbm_bw


def collective_time(nbytes: float, hw: HardwareSpec = TPU_V5E, *,
                    dcn: bool = False) -> float:
    return nbytes / (hw.dcn_bw if dcn else hw.ici_bw)


def step_time(compute_s: float, memory_s: float, collective_s: float) -> float:
    """Modeled step latency: compute overlaps HBM traffic (the MXU pulls
    operands while it works), but ICI collectives on the critical path
    overlap poorly at large TP spans — they serialize after the overlapped
    pair.  This is deliberately pessimistic about communication so layout
    search does not hide collective volume behind compute."""
    return max(compute_s, memory_s) + collective_s


# ---------------------------------------------------------------------------
# ring-collective per-device volumes (bytes moved over the interconnect)
# ---------------------------------------------------------------------------

def all_reduce_bytes(size: float, n: int) -> float:
    """Ring all-reduce of a ``size``-byte tensor over ``n`` devices:
    reduce-scatter + all-gather, each (n-1)/n · size per device."""
    return 0.0 if n <= 1 else 2.0 * (n - 1) / n * size


def all_gather_bytes(size: float, n: int) -> float:
    """Ring all-gather assembling a ``size``-byte full tensor on each
    device from 1/n shards."""
    return 0.0 if n <= 1 else (n - 1) / n * size


def reduce_scatter_bytes(size: float, n: int) -> float:
    return 0.0 if n <= 1 else (n - 1) / n * size


def all_to_all_bytes(size: float, n: int) -> float:
    """All-to-all re-bucketing of a ``size``-byte per-device payload:
    (n-1)/n of it leaves the device."""
    return 0.0 if n <= 1 else (n - 1) / n * size
