from .pipeline import DataConfig, ShardedLoader, TokenSource
