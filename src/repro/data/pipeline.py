"""Deterministic, restart-exact, host-sharded token pipeline.

Design for 1000+ nodes: every host computes its shard of every global
batch purely from (seed, step, host_index) — no coordinator, no state to
checkpoint beyond the step counter, and elastic re-sharding is just a
change of (host_index, n_hosts).  Sources: synthetic LM stream (default)
or a memory-mapped token file.  A background prefetch thread keeps
``depth`` batches ready.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab: int = 256
    seed: int = 0
    n_codebooks: int = 1
    token_file: Optional[str] = None     # memmap int32 tokens
    prefetch_depth: int = 2


class TokenSource:
    """Maps (step, global example index) → token sequence, statelessly."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.int32, mode="r")

    def example(self, step: int, index: int) -> np.ndarray:
        cfg = self.cfg
        L = cfg.seq_len + 1
        if self._mm is not None:
            n_windows = (len(self._mm) - 1) // L
            # deterministic shuffled window id
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) * 1_000_003 + index)
            w = int(rng.integers(0, n_windows))
            seq = np.asarray(self._mm[w * L:(w + 1) * L])
        else:
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) * 1_000_003 + index)
            shape = (L, cfg.n_codebooks) if cfg.n_codebooks > 1 else (L,)
            seq = rng.integers(0, cfg.vocab, size=shape, dtype=np.int32)
        return seq


class ShardedLoader:
    """Yields this host's shard of each global batch."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 n_hosts: int = 1, start_step: int = 0):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.source = TokenSource(cfg)
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _build(self, step: int) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // self.n_hosts
        lo = self.host_index * per_host
        seqs = np.stack([self.source.example(step, lo + i)
                         for i in range(per_host)])
        return {"tokens": seqs[:, :-1], "targets": seqs[:, 1:],
                "step": step}

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self._build(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        batch = self._q.get()
        self.step = batch["step"] + 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
