"""AdamW with fully-sharded state, bf16-state option, global-norm clipping.

State shards exactly like the parameters (same PartitionSpec tree), so
optimizer memory scales 1/N with the mesh — the ZeRO-3/FSDP layout.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"      # bf16 halves optimizer memory


def schedule(step, cfg: OptConfig):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params, cfg: OptConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads, state, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(count, cfg)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat = jax.tree_util.tree_map(upd, params, grads, state["m"],
                                  state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
