from . import adamw
from .adamw import OptConfig
