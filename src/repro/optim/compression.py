"""Gradient compression with error feedback, for slower-than-ICI links
(cross-pod DCN): int8 linear quantization or top-k sparsification.

Applied to the DP gradient all-reduce: compress locally, reduce, decode,
and carry the quantization residual into the next step (error feedback
keeps SGD convergence; Karimireddy et al., 2019).  Off by default — ICI
is fast; designed for the 'pod' axis.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01


def compress_decompress(g: jnp.ndarray, residual: jnp.ndarray,
                        cfg: CompressionConfig):
    """Returns (decoded gradient, new residual).  The decoded value is
    what the collective would transport; residual = g - decoded."""
    if cfg.kind == "none":
        return g, jnp.zeros_like(residual)
    g = g + residual                        # error feedback
    if cfg.kind == "int8":
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127)
        dec = q * scale
    elif cfg.kind == "topk":
        k = max(1, int(g.size * cfg.topk_frac))
        flat = g.reshape(-1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        dec = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(g.shape)
    else:
        raise ValueError(cfg.kind)
    return dec, g - dec


def apply_tree(grads, residuals, cfg: CompressionConfig):
    if cfg.kind == "none":
        return grads, residuals
    pairs = jax.tree_util.tree_map(
        lambda g, r: compress_decompress(g, r, cfg), grads, residuals)
    dec = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return dec, res


def init_residuals(grads_like):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
