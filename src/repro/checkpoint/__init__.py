from .store import CheckpointStore
