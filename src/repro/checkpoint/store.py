"""Sharded, atomic, async checkpointing with elastic restore.

Layout: ``<dir>/step_<n>/{manifest.json, arrays.npz}`` written to a temp
directory and atomically renamed on commit — a crash mid-save never
corrupts the latest checkpoint.  Saves run on a background thread
(training continues; ``wait()`` joins).  Restore re-shards to *any* mesh:
arrays are saved unsharded-logical (gathered), and ``restore`` applies the
target sharding — elastic scaling = restore onto a different mesh.

On a real multi-host fleet each host writes its own shard files and the
manifest lists them; the single-process layout here keeps the same commit
protocol (temp dir + atomic rename + manifest-last).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointStore:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        flat = _flatten(tree)          # device→host copy happens here
        meta = {"step": step, "extra": extra or {},
                "keys": sorted(flat), "time": time.time()}

        def _write():
            try:
                tmp = self.dir / f".tmp_step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "arrays.npz", **flat)
                (tmp / "manifest.json").write_text(json.dumps(meta))
                final = self.dir / f"step_{step}"
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:   # surfaced by wait()
                self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; apply ``shardings``
        (a NamedSharding tree) if given — the elastic path: the target
        mesh may differ from the mesh that saved."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "manifest.json").read_text())
        arrays = np.load(d / "arrays.npz")

        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, leaf), shd in zip(paths, shard_leaves):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            arr = arrays[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), meta["extra"]
