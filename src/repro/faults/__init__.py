"""Deterministic, seeded fault injection for the fused-plan stack.

The serving layer's resilience claims (degradation ladders, circuit
breakers, worker respawn — see ``docs/robustness.md``) are only worth
anything if they are *exercised*: this module provides the chaos
harness that exercises them reproducibly.  Production code declares
named **fault sites** at the points where real systems fail — the
whole-plan jit build, ``pallas_call`` dispatch, distributed segment
planning, the vmap-batched serving dispatch, the worker loop — and
calls :func:`fault_point` there.  With no schedule installed the call
is one global read and a ``None`` check (nanoseconds; the hot path
stays hot).  Tests install a :class:`FaultSchedule` — a seeded,
deterministic list of :class:`FaultRule`\\ s — and the same seed always
produces the same fault sequence, so every chaos scenario is a normal
reproducible test, not a flake generator.

Fault kinds::

    error      raise FaultInjected at the site
    crash      raise WorkerCrash (worker loop: thread dies, pool respawns)
    latency    time.sleep(delay_s) at the site
    nonfinite  fault_point returns the rule; the caller poisons the
               site's *outputs* with NaN (runtime sites only — a NaN
               injected at trace time would be baked into the cached
               jitted function forever)

Every registered site names its **handler** — the subsystem that turns
the injected fault into a degradation instead of a lost request.
``fusionlint --faults`` fails if any site lacks one: an injection point
nothing recovers from is a liability, not coverage.

Usage::

    from repro import faults
    sched = faults.FaultSchedule([
        faults.FaultRule("serve.batch_dispatch", kind="error", at=(0,)),
        faults.FaultRule("serve.worker", kind="crash", p=0.05),
    ], seed=7)
    with faults.inject(sched):
        ...  # first batched dispatch fails; workers crash w.p. 0.05
    sched.events()   # what actually fired, in order
"""

from __future__ import annotations

import contextlib
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

__all__ = [
    "FaultSite", "FaultRule", "FaultSchedule", "FaultInjected",
    "WorkerCrash", "register_site", "sites", "ensure_registered",
    "install", "uninstall", "active", "inject", "fault_point", "poison",
]


class FaultInjected(RuntimeError):
    """An injected fault (kind ``error``) surfacing at a fault site.

    Handlers treat it exactly like the real failure it stands in for;
    nothing in the recovery path special-cases injected errors."""

    def __init__(self, site: str, kind: str = "error",
                 message: str = "") -> None:
        self.site = site
        self.kind = kind
        super().__init__(
            f"injected fault at {site}" + (f": {message}" if message else ""))


class WorkerCrash(FaultInjected):
    """An injected worker-thread crash (kind ``crash``) — escapes the
    per-batch error handling on purpose, so the respawn path is what
    catches it."""

    def __init__(self, site: str, message: str = "") -> None:
        super().__init__(site, "crash", message)


# --------------------------------------------------------------------------
# site registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultSite:
    """One named injection point.  ``kinds`` is the subset of fault
    kinds meaningful there; ``handler`` names the recovery mechanism
    (``fusionlint --faults`` fails on an empty one)."""
    name: str
    description: str
    kinds: tuple[str, ...]
    handler: str


_SITES: dict[str, FaultSite] = {}
_SITES_LOCK = threading.Lock()


def register_site(name: str, description: str, kinds: tuple[str, ...],
                  handler: str) -> FaultSite:
    """Declare a fault site (idempotent; module import time)."""
    site = FaultSite(name, description, tuple(kinds), handler)
    with _SITES_LOCK:
        _SITES[name] = site
    return site


def sites() -> list[FaultSite]:
    """Every registered fault site (import the stack first, or use
    :func:`ensure_registered`)."""
    with _SITES_LOCK:
        return list(_SITES.values())


def ensure_registered() -> list[FaultSite]:
    """Import every module that declares fault sites, then list them —
    the ``fusionlint --faults`` entry point."""
    import repro.core.codegen      # noqa: F401  plan.jit_build
    import repro.kernels.ops       # noqa: F401  kernels.pallas_call
    import repro.kernels.distributed  # noqa: F401  dist.segment
    import repro.serve.fusion      # noqa: F401  serve.batch_dispatch/worker
    return sites()


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultRule:
    """One line of a fault schedule.

    Fires at site ``site`` either on exact hit indices ``at`` (the
    site's 0-based invocation counter under the installed schedule) or
    with probability ``p`` per hit, capped at ``count`` total firings.
    ``delay_s`` is the sleep for ``latency`` faults."""
    site: str
    kind: str = "error"
    p: float = 0.0
    at: tuple[int, ...] = ()
    count: Optional[int] = None
    delay_s: float = 0.0
    message: str = ""

    def __post_init__(self):
        if self.kind not in ("error", "crash", "latency", "nonfinite"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class _RuleState:
    rule: FaultRule
    rng: random.Random
    fired: int = 0


class FaultSchedule:
    """A deterministic fault plan: same rules + same seed → the same
    fault sequence, independent of wall clock (each rule draws from its
    own seeded RNG, one draw per site hit, whether or not it fires)."""

    def __init__(self, rules: list[FaultRule], seed: int = 0) -> None:
        self.rules = tuple(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._hits: dict[str, int] = {}
        self._states = [
            _RuleState(r, random.Random(f"{self.seed}:{i}"))
            for i, r in enumerate(self.rules)]
        self._events: list[tuple[str, str, int]] = []

    def poke(self, site: str) -> Optional[FaultRule]:
        """Advance ``site``'s hit counter; return the rule that fires
        at this hit (first match wins), or None."""
        with self._lock:
            hit = self._hits.get(site, 0)
            self._hits[site] = hit + 1
            fired: Optional[FaultRule] = None
            for st in self._states:
                if st.rule.site != site:
                    continue
                # one draw per hit keeps the sequence deterministic even
                # when an earlier rule already fired this hit
                draw = st.rng.random() if st.rule.p > 0.0 else 1.0
                if fired is not None:
                    continue
                if st.rule.count is not None and st.fired >= st.rule.count:
                    continue
                if hit in st.rule.at or draw < st.rule.p:
                    st.fired += 1
                    fired = st.rule
            if fired is not None:
                self._events.append((site, fired.kind, hit))
            return fired

    def hits(self, site: str) -> int:
        with self._lock:
            return self._hits.get(site, 0)

    def events(self) -> list[tuple[str, str, int]]:
        """Every fault that fired: ``(site, kind, hit_index)`` in order."""
        with self._lock:
            return list(self._events)


# --------------------------------------------------------------------------
# installation + the injection point
# --------------------------------------------------------------------------

#: process-global on purpose: server worker threads must observe the
#: schedule the test thread installed
_ACTIVE: Optional[FaultSchedule] = None
_ACTIVE_LOCK = threading.Lock()


def install(schedule: FaultSchedule) -> None:
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = schedule


def uninstall() -> None:
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = None


def active() -> Optional[FaultSchedule]:
    return _ACTIVE


@contextlib.contextmanager
def inject(schedule: FaultSchedule):
    """Install ``schedule`` for the duration of the block."""
    install(schedule)
    try:
        yield schedule
    finally:
        uninstall()


def fault_point(site: str) -> Optional[FaultRule]:
    """The injection point production code calls at a registered site.

    No schedule installed: one global read, returns None.  Otherwise
    applies the schedule's firing rule for this hit — raising for
    ``error``/``crash``, sleeping for ``latency``, and *returning* the
    rule for ``nonfinite`` so the caller can :func:`poison` the site's
    outputs (only runtime sites declare the kind)."""
    sched = _ACTIVE
    if sched is None:
        return None
    rule = sched.poke(site)
    if rule is None:
        return None
    if rule.kind == "crash":
        raise WorkerCrash(site, rule.message)
    if rule.kind == "error":
        raise FaultInjected(site, "error", rule.message)
    if rule.kind == "latency":
        time.sleep(rule.delay_s)
        return None
    return rule          # nonfinite: caller poisons its outputs


def poison(value):
    """NaN-poison one output structure (NumPy arrays / scalars, tuples
    thereof) — the runtime half of ``nonfinite`` injection."""
    import numpy as np
    if isinstance(value, tuple):
        return tuple(poison(v) for v in value)
    return np.asarray(value) * np.float32("nan")
