"""repro — cost-based operator-fusion-plan optimization for JAX/TPU.

Reimplementation of Boehm et al., "On Optimizing Operator Fusion Plans for
Large-Scale Machine Learning in SystemML" (PVLDB 2018), embedded in a
multi-pod JAX training/serving framework.
"""

__version__ = "0.1.0"
