"""repro — cost-based operator-fusion-plan optimization for JAX/TPU.

Reimplementation of Boehm et al., "On Optimizing Operator Fusion Plans for
Large-Scale Machine Learning in SystemML" (PVLDB 2018), embedded in a
multi-pod JAX training/serving framework.
"""

__version__ = "0.1.0"

# Installed for every entrypoint (tests, dry-run subprocesses, CLIs):
# backfills jax.sharding.AxisType / make_mesh(axis_types=) on older JAX.
# Imports jax but never initializes a backend — XLA_FLAGS set by an
# entrypoint after this still take effect at first device use.
from repro.dist import compat as _compat  # noqa: E402,F401
