"""Linear-algebra operator IR (the HOP-DAG analogue of SystemML).

The fusion planner (explore/select/codegen) operates on this IR, not on
jaxprs: the paper's templates reason about *linear-algebra semantics*
(cell-wise vs row-wise access, aggregation axes, sparse-safety, outer-product
shapes), which are first-class here and erased in a jaxpr.

Nodes are immutable after construction; a :class:`Graph` snapshots a set of
output nodes into a topologically ordered, id-indexed DAG with consumer
counts — the unit of optimization (one HOP DAG at-a-time, paper §4.1).

Shapes are static 2-D ``(rows, cols)``; column vectors are ``(n, 1)``, row
vectors ``(1, n)``, scalars ``(1, 1)`` literals.  Sparsity is an nnz-fraction
estimate propagated through construction (paper's size/sparsity propagation
via IPA); it drives sparse-safe fusion decisions and the cost model.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional

import numpy as np

# --------------------------------------------------------------------------
# Operator taxonomy
# --------------------------------------------------------------------------

#: cell-wise unary ops f(x); SPARSE_SAFE_UNARY ⊆ UNARY_OPS have f(0) == 0.
UNARY_OPS = frozenset({
    "exp", "log", "sqrt", "abs", "sign", "round", "floor", "ceil",
    "sigmoid", "tanh", "relu", "neg", "recip", "pow2", "neq0", "sprop",
    "log1p", "softplus", "gelu", "silu", "square", "erf",
})
SPARSE_SAFE_UNARY = frozenset({
    "sqrt", "abs", "sign", "round", "floor", "ceil", "tanh", "relu", "neg",
    "pow2", "neq0", "sprop", "log1p", "gelu", "silu", "square", "erf",
})

#: cell-wise binary ops g(x, y) with numpy-style broadcasting over
#: (m,n)·(m,1)/(1,n)/(1,1) operands.
BINARY_OPS = frozenset({
    "add", "sub", "mul", "div", "min", "max", "pow",
    "eq", "neq", "lt", "le", "gt", "ge",
})

#: cell-wise ternary ops.
TERNARY_OPS = frozenset({"where", "plus_mult", "minus_mult"})

#: aggregations; axis ∈ {"full", "row", "col"} (rowSums → axis="row",
#: producing an (m,1) vector; colSums → axis="col", producing (1,n)).
AGG_OPS = frozenset({"sum", "min", "max", "mean", "sum_sq"})

CELL_OPS = UNARY_OPS | BINARY_OPS | TERNARY_OPS

# structural / non-cell ops
STRUCT_OPS = frozenset({"input", "lit", "matmul", "t", "idx", "diagv"})

ALL_OPS = CELL_OPS | AGG_OPS | STRUCT_OPS

_counter = itertools.count()


def _fresh_id() -> int:
    return next(_counter)


# --------------------------------------------------------------------------
# Nodes
# --------------------------------------------------------------------------

@dataclass(eq=False)
class Node:
    """One operator in the DAG.  Identity semantics (eq=False) — CSE is the
    caller's job (see :func:`Graph.build` dedup)."""

    op: str
    inputs: tuple["Node", ...]
    shape: tuple[int, int]
    dtype: Any = np.float32
    sparsity: float = 1.0
    name: Optional[str] = None          # for inputs: bind-time key
    attrs: dict = field(default_factory=dict)
    nid: int = field(default_factory=_fresh_id)

    # -- classification helpers used throughout the planner ---------------
    @property
    def is_input(self) -> bool:
        return self.op in ("input", "lit")

    @property
    def is_cellwise(self) -> bool:
        return self.op in CELL_OPS and "axis" not in self.attrs

    @property
    def is_agg(self) -> bool:
        # min/max are also binary cell ops; aggregations carry an axis attr
        return self.op in AGG_OPS and "axis" in self.attrs

    @property
    def agg_axis(self) -> Optional[str]:
        return self.attrs.get("axis") if self.is_agg else None

    @property
    def is_matmul(self) -> bool:
        return self.op == "matmul"

    @property
    def is_scalar(self) -> bool:
        return self.shape == (1, 1)

    @property
    def is_vector(self) -> bool:
        return (self.shape[0] == 1) != (self.shape[1] == 1)

    @property
    def ncells(self) -> int:
        return int(self.shape[0]) * int(self.shape[1])

    @property
    def nnz(self) -> float:
        return self.ncells * self.sparsity

    # matmul structure -----------------------------------------------------
    @property
    def ta(self) -> bool:
        return bool(self.attrs.get("ta", False))

    @property
    def tb(self) -> bool:
        return bool(self.attrs.get("tb", False))

    def mm_dims(self) -> tuple[int, int, int]:
        """(m, k, n) of this matmul after folding transposes."""
        assert self.is_matmul
        a, b = self.inputs
        m, k = (a.shape[1], a.shape[0]) if self.ta else a.shape
        k2, n = (b.shape[1], b.shape[0]) if self.tb else b.shape
        assert k == k2, f"matmul dim mismatch {a.shape}/{b.shape}"
        return m, k, n

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        ins = ",".join(str(i.nid) for i in self.inputs)
        nm = f":{self.name}" if self.name else ""
        return f"%{self.nid}={self.op}{nm}({ins}){self.shape}"


# --------------------------------------------------------------------------
# Shape / sparsity inference
# --------------------------------------------------------------------------

def _broadcast_shape(a: tuple[int, int], b: tuple[int, int]) -> tuple[int, int]:
    r = a[0] if b[0] == 1 else (b[0] if a[0] == 1 else a[0])
    c = a[1] if b[1] == 1 else (b[1] if a[1] == 1 else a[1])
    for (x, y) in ((a[0], r), (b[0], r), (a[1], c), (b[1], c)):
        if x not in (1, y):
            raise ValueError(f"cannot broadcast {a} with {b}")
    return (r, c)


def infer_shape(op: str, in_shapes: list[tuple[int, int]],
                attrs: dict) -> Optional[tuple[int, int]]:
    """Re-derive the output shape of ``op`` bottom-up from its input shapes
    — the single source of the IR's shape semantics, shared by expression
    construction invariants and the plan verifier's metadata cross-check
    (:mod:`repro.core.verify`).  Returns None when the op carries no
    derivable shape (leaves, ops with free output shape); raises
    ``ValueError`` on inconsistent operand shapes (dimension mismatch)."""
    if op in ("input", "lit", "diagv"):
        return None
    if op == "t":
        (r, c), = in_shapes
        return (c, r)
    if op == "idx":
        return (in_shapes[0][0], int(attrs["hi"]) - int(attrs["lo"]))
    if op == "matmul":
        a, b = in_shapes
        m, k = (a[1], a[0]) if attrs.get("ta") else a
        k2, n = (b[1], b[0]) if attrs.get("tb") else b
        if k != k2:
            raise ValueError(f"matmul contraction mismatch {a} @ {b}")
        return (m, n)
    if op in AGG_OPS and "axis" in attrs:
        r, c = in_shapes[0]
        return {"full": (1, 1), "row": (r, 1), "col": (1, c)}[attrs["axis"]]
    if op in UNARY_OPS:
        return in_shapes[0]
    if op in BINARY_OPS or op in TERNARY_OPS:
        out = in_shapes[0]
        for s in in_shapes[1:]:
            out = _broadcast_shape(out, s)
        return out
    return None


def _unary_sparsity(op: str, s: float) -> float:
    return s if op in SPARSE_SAFE_UNARY else 1.0


def _binary_sparsity(op: str, a: Node, b: Node) -> float:
    sa, sb = a.sparsity, b.sparsity
    if op == "mul":
        return min(sa, sb)
    if op == "div":
        return sa                       # 0/x == 0 (x!=0 assumed)
    if op in ("add", "sub", "min", "max"):
        return min(1.0, sa + sb)
    return 1.0


# --------------------------------------------------------------------------
# Expression construction (user-facing; re-exported by core.api)
# --------------------------------------------------------------------------

class Expr:
    """Thin operator-overloading wrapper producing :class:`Node` DAGs."""

    __array_priority__ = 100  # beat numpy scalars

    def __init__(self, node: Node):
        self.node = node

    # constructors ---------------------------------------------------------
    @property
    def shape(self):
        return self.node.shape

    @property
    def T(self) -> "Expr":
        n = self.node
        if n.op == "t":                      # t(t(X)) == X
            return Expr(n.inputs[0])
        return Expr(Node("t", (n,), (n.shape[1], n.shape[0]),
                         n.dtype, n.sparsity))

    # cell-wise ------------------------------------------------------------
    def _bin(self, other, op: str, rev: bool = False) -> "Expr":
        o = as_expr(other, like=self)
        a, b = (o.node, self.node) if rev else (self.node, o.node)
        shape = _broadcast_shape(a.shape, b.shape)
        sp = _binary_sparsity(op, a, b)
        return Expr(Node(op, (a, b), shape, a.dtype, sp))

    def __add__(self, o):  return self._bin(o, "add")
    def __radd__(self, o): return self._bin(o, "add", rev=True)
    def __sub__(self, o):  return self._bin(o, "sub")
    def __rsub__(self, o): return self._bin(o, "sub", rev=True)
    def __mul__(self, o):  return self._bin(o, "mul")
    def __rmul__(self, o): return self._bin(o, "mul", rev=True)
    def __truediv__(self, o):  return self._bin(o, "div")
    def __rtruediv__(self, o): return self._bin(o, "div", rev=True)
    def __pow__(self, o):
        if isinstance(o, (int, float)) and o == 2:
            return self.unary("pow2")
        return self._bin(o, "pow")
    def __neg__(self): return self.unary("neg")
    def __eq__(self, o):  return self._bin(o, "eq")    # type: ignore[override]
    def __ne__(self, o):  return self._bin(o, "neq")   # type: ignore[override]
    def __lt__(self, o):  return self._bin(o, "lt")
    def __le__(self, o):  return self._bin(o, "le")
    def __gt__(self, o):  return self._bin(o, "gt")
    def __ge__(self, o):  return self._bin(o, "ge")
    __hash__ = object.__hash__

    def unary(self, op: str) -> "Expr":
        assert op in UNARY_OPS, op
        n = self.node
        return Expr(Node(op, (n,), n.shape, n.dtype,
                         _unary_sparsity(op, n.sparsity)))

    # matmul (folds adjacent transposes into ta/tb attrs) ------------------
    def __matmul__(self, other) -> "Expr":
        a, b = self.node, as_expr(other, like=self).node
        ta = a.op == "t"
        tb = b.op == "t"
        ai = a.inputs[0] if ta else a
        bi = b.inputs[0] if tb else b
        m = ai.shape[1] if ta else ai.shape[0]
        k = ai.shape[0] if ta else ai.shape[1]
        k2 = bi.shape[1] if tb else bi.shape[0]
        n = bi.shape[0] if tb else bi.shape[1]
        if k != k2:
            raise ValueError(f"matmul mismatch {a.shape} @ {b.shape}")
        # sparsity: P(out nonzero) = 1 - (1 - sa*sb)^k
        sp = float(min(1.0, 1.0 - (1.0 - ai.sparsity * bi.sparsity) ** max(k, 1)))
        return Expr(Node("matmul", (ai, bi), (m, n), ai.dtype, sp,
                         attrs={"ta": ta, "tb": tb}))

    # aggregations ----------------------------------------------------------
    def _agg(self, op: str, axis: str) -> "Expr":
        n = self.node
        shape = {"full": (1, 1), "row": (n.shape[0], 1),
                 "col": (1, n.shape[1])}[axis]
        return Expr(Node(op, (n,), shape, n.dtype, 1.0, attrs={"axis": axis}))

    def sum(self):      return self._agg("sum", "full")
    def rowsums(self):  return self._agg("sum", "row")
    def colsums(self):  return self._agg("sum", "col")
    def rowmaxs(self):  return self._agg("max", "row")
    def max_(self):     return self._agg("max", "full")
    def min_(self):     return self._agg("min", "full")
    def mean(self):     return self._agg("mean", "full")
    def rowmeans(self): return self._agg("mean", "row")
    def colmeans(self): return self._agg("mean", "col")

    # indexing (column range only — the paper's P[, 1:k]) -------------------
    def cols(self, lo: int, hi: int) -> "Expr":
        n = self.node
        assert 0 <= lo < hi <= n.shape[1]
        return Expr(Node("idx", (n,), (n.shape[0], hi - lo), n.dtype,
                         n.sparsity, attrs={"lo": lo, "hi": hi}))


def as_expr(x, like: Optional[Expr] = None) -> Expr:
    if isinstance(x, Expr):
        return x
    if isinstance(x, Node):
        return Expr(x)
    if isinstance(x, (int, float, np.floating, np.integer)):
        dt = like.node.dtype if like is not None else np.float32
        return Expr(Node("lit", (), (1, 1), dt,
                         0.0 if float(x) == 0.0 else 1.0,
                         attrs={"value": float(x)}))
    raise TypeError(f"cannot lift {type(x)} into Expr")


def matrix(name: str, shape: tuple[int, int], *, sparsity: float = 1.0,
           dtype=np.float32) -> Expr:
    """Declare a leaf input matrix."""
    assert len(shape) == 2
    return Expr(Node("input", (), (int(shape[0]), int(shape[1])), dtype,
                     float(sparsity), name=name))


def scalar(name: str, *, dtype=np.float32) -> Expr:
    return matrix(name, (1, 1), dtype=dtype)


# convenience free functions (mirror SystemML builtins)
def exp(x): return as_expr(x).unary("exp")
def log(x): return as_expr(x).unary("log")
def sqrt(x): return as_expr(x).unary("sqrt")
def abs_(x): return as_expr(x).unary("abs")
def sign(x): return as_expr(x).unary("sign")
def sigmoid(x): return as_expr(x).unary("sigmoid")
def tanh(x): return as_expr(x).unary("tanh")
def relu(x): return as_expr(x).unary("relu")
def gelu(x): return as_expr(x).unary("gelu")
def silu(x): return as_expr(x).unary("silu")
def neq0(x): return as_expr(x).unary("neq0")
def erf(x): return as_expr(x).unary("erf")
def minimum(a, b): return as_expr(a)._bin(b, "min")
def maximum(a, b): return as_expr(a)._bin(b, "max")
def where(c, a, b):
    c, a = as_expr(c), as_expr(a, like=as_expr(c))
    b = as_expr(b, like=a)
    shape = _broadcast_shape(_broadcast_shape(c.node.shape, a.node.shape),
                             b.node.shape)
    sp = min(1.0, a.node.sparsity + b.node.sparsity)
    return Expr(Node("where", (c.node, a.node, b.node), shape,
                     a.node.dtype, sp))


# --------------------------------------------------------------------------
# Graph
# --------------------------------------------------------------------------

class Graph:
    """Immutable snapshot of a DAG for a set of outputs.

    Performs structural CSE at build time (SystemML's HOP DAGs share CSEs —
    multiple consumers are exactly what makes plan selection interesting).
    """

    def __init__(self, nodes: list[Node], outputs: list[Node],
                 consumers: dict[int, list[int]]):
        self.nodes = nodes                          # topo order
        self.outputs = outputs
        self.by_id = {n.nid: n for n in nodes}
        self.consumers = consumers                  # nid -> consumer nids
        self.output_ids = {o.nid for o in outputs}

    # -- construction -------------------------------------------------------
    @staticmethod
    def build(outputs: Iterable[Expr | Node]) -> "Graph":
        outs = [o.node if isinstance(o, Expr) else o for o in outputs]
        # structural CSE: key = (op, input ids, shape, frozen attrs, name)
        canon: dict[tuple, Node] = {}
        memo: dict[int, Node] = {}

        def key(n: Node, ins: tuple[Node, ...]) -> tuple:
            return (n.op, tuple(i.nid for i in ins), n.shape, n.name,
                    tuple(sorted(n.attrs.items())))

        order: list[Node] = []

        def visit(n: Node) -> Node:
            if n.nid in memo:
                return memo[n.nid]
            ins = tuple(visit(i) for i in n.inputs)
            k = key(n, ins)
            if k in canon:
                memo[n.nid] = canon[k]
                return canon[k]
            nn = n if ins == n.inputs else Node(
                n.op, ins, n.shape, n.dtype, n.sparsity, n.name, dict(n.attrs))
            canon[k] = nn
            memo[n.nid] = nn
            order.append(nn)
            return nn

        new_outs = [visit(o) for o in outs]
        consumers: dict[int, list[int]] = {n.nid: [] for n in order}
        for n in order:
            for i in n.inputs:
                consumers[i.nid].append(n.nid)
        return Graph(order, new_outs, consumers)

    # -- queries -------------------------------------------------------------
    def n_consumers(self, nid: int) -> int:
        # graph outputs count as an extra (external) consumer
        return len(self.consumers[nid]) + (1 if nid in self.output_ids else 0)

    def multi_consumer_ids(self) -> set[int]:
        return {nid for nid in self.by_id
                if len(self.consumers[nid]) + (1 if nid in self.output_ids else 0) > 1
                and not self.by_id[nid].is_input}

    def inputs(self) -> list[Node]:
        return [n for n in self.nodes if n.op == "input"]

    def __len__(self) -> int:
        return len(self.nodes)

    def __repr__(self) -> str:  # pragma: no cover
        lines = [repr(n) for n in self.nodes]
        lines.append("outputs: " + ", ".join(f"%{o.nid}" for o in self.outputs))
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Sparse-safety analysis (drives Cell sparse-safe binding + Outer validity)
# --------------------------------------------------------------------------

def sparse_safe_wrt(node: Node, driver: Node,
                    _memo: Optional[dict] = None) -> bool:
    """True iff evaluating ``node`` only at the non-zero cells of ``driver``
    is exact — i.e. the value at any cell where driver==0 is itself 0.

    This is the paper's sparse-safety condition for Cell/Outer templates
    ("sparse drivers", §1 Fig. 1(d)); conservative (False on unknown ops).
    """
    if _memo is None:
        _memo = {}
    k = node.nid
    if k in _memo:
        return _memo[k]
    r: bool
    if node.nid == driver.nid:
        r = True
    elif node.op in UNARY_OPS:
        r = node.op in SPARSE_SAFE_UNARY and \
            sparse_safe_wrt(node.inputs[0], driver, _memo)
    elif node.op == "mul":
        r = any(sparse_safe_wrt(i, driver, _memo) for i in node.inputs)
    elif node.op == "div":
        r = sparse_safe_wrt(node.inputs[0], driver, _memo)
    elif node.op in ("add", "sub"):
        r = all(sparse_safe_wrt(i, driver, _memo) for i in node.inputs)
    else:
        r = False
    _memo[k] = r
    return r


def reaches(src: Node, dst: Node) -> bool:
    """DAG reachability src ->* dst (following inputs from dst upward)."""
    seen: set[int] = set()
    stack = [dst]
    while stack:
        n = stack.pop()
        if n.nid == src.nid:
            return True
        if n.nid in seen:
            continue
        seen.add(n.nid)
        stack.extend(n.inputs)
    return False
