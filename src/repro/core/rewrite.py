"""Rewrite-based plan exploration (SPORES-style, PAPERS.md 2002.07951).

The explorer enumerates fusion plans over the HOP DAG *as written*; this
module widens the plan space with a bounded algebraic rewrite pass between
``trace`` and ``plan``: it generates semantically-equal DAG variants from a
small, documented rule set, each of which ``Traced.plan()`` verifies
(:func:`repro.core.verify.verify_variant`, RW001–RW004), plans through the
existing explore → select pipeline, and admits into the global cost argmin.
``explain()["rewrite"]`` reports the rules applied, per-variant cost, and
the winner; the winning rule chain also enters the whole-plan cache key
(:func:`repro.core.codegen.staged_plan_key`).

Rule catalog (all over *full* aggregates — the bounded set; shapes in
comments use M:(m,k), N:(k,n), A:(m,n)):

``spores_rotate``
    ``sum((M@N) ⊙ A)  ⇄  sum((A@Nᵀ) ⊙ M)  ⇄  sum((Mᵀ@A) ⊙ N)`` — the
    SPORES sum-product rotation.  The matmul under the aggregate moves to
    whichever pair of operands contracts cheapest; with one factor sparse
    it exposes the sparsity-exploiting Outer form.  (The classical
    ``trace(X@Y) → sum(X ⊙ Yᵀ)`` identity is this rotation with ``A = I``;
    the 2-D IR has no trace/diag expression, so the identity appears only
    through its ⊙-form, which these rotations cover.)
``sum_transpose``
    ``agg_full(Xᵀ) → agg_full(X)`` for sum/sum_sq/min/max/mean — a full
    aggregate is permutation-invariant, so the transpose is dead.
``sum_mm_factor``
    ``sum(M@N) → sum(colsums(M)ᵀ ⊙ rowsums(N))`` — sum-of-product
    reassociation: Σᵢⱼₖ MᵢₖNₖⱼ contracted as Σₖ (ΣᵢMᵢₖ)(ΣⱼNₖⱼ), turning an
    O(mkn) contraction with an (m,n) intermediate into two vector sums.
``sum_add_split``
    ``sum(A ± B) → sum(A) ± sum(B)`` when A and B have the full shape, or
    ``sum(A ± s) → sum(A) ± ncells·s`` for a scalar operand — distributing
    ``sum`` over ``+`` so each term aggregates (and fuses) independently.
``scalar_hoist``
    ``sum(A ⊙ s) → s ⊙ sum(A)`` and ``sum(A / s) → sum(A) / s`` for scalar
    ``s`` — hoists the scalar out of the aggregate so the reduction runs
    over the raw cells.

Every rule preserves output shape/dtype, the named-input set, and static
zero-forcing w.r.t. each input (sparse-zero-preservation) — the properties
RW001–RW004 re-check per variant, so an ill-formed rule application is
rejected before it can be planned.

The engine is a bounded breadth-first closure: rules are applied at every
matching node in topological order, compositions up to ``max_depth`` deep,
deduplicated by structural digest, truncated at ``max_variants``.  Rule
applications are labelled ``"<rule>@<topo-index>"`` (topological position,
not node id) so variant identity is stable across re-traces of the same
expression — the property the whole-plan cache key and the golden explain
snapshots rely on.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable

from .ir import Expr, Graph, Node

#: bounded search knobs (module-level so tests/tools can widen them)
MAX_VARIANTS = 16
MAX_DEPTH = 2

#: full-aggregate ops every rule keys on
_FULL_AGGS = ("sum", "sum_sq", "min", "max", "mean")


def graph_digest(graph: Graph) -> str:
    """Structural sha256 of a HOP DAG with node ids canonicalized to
    topological indices — equal for structurally-equal graphs from
    different traces, the dedup/identity token of the rewrite engine."""
    idx = {n.nid: i for i, n in enumerate(graph.nodes)}
    toks: list = []
    for n in graph.nodes:
        toks.append((n.op, n.name or "", n.shape, str(n.dtype),
                     round(float(n.sparsity), 6),
                     tuple(sorted((k, repr(v)) for k, v in n.attrs.items())),
                     tuple(idx[i.nid] for i in n.inputs)))
    toks.append(("outputs", tuple(idx[o.nid] for o in graph.outputs)))
    return hashlib.sha256(repr(toks).encode()).hexdigest()


@dataclass(frozen=True)
class RewriteVariant:
    """One semantically-equal DAG produced by the rewrite pass."""

    graph: Graph
    #: rule-application chain, e.g. ``("spores_rotate@7",)``
    rules: tuple[str, ...]
    #: structural digest of :attr:`graph` (see :func:`graph_digest`)
    digest: str


# --------------------------------------------------------------------------
# rule implementations
# --------------------------------------------------------------------------
#
# A rule is ``fn(node) -> list[Node]``: zero or more replacement roots for
# ``node``, each built over the *original* operand nodes (so the engine's
# graph rebuild shares everything below the match).  Construction goes
# through the Expr layer, which keeps shape/sparsity propagation and
# transpose folding identical to trace-time construction.

def _full_agg(node: Node, ops=_FULL_AGGS) -> bool:
    return node.is_agg and node.agg_axis == "full" and node.op in ops


def _logical_mm(mm: Node) -> tuple[Expr, Expr]:
    """The logical (M, N) operands of a matmul with its ta/tb flags
    unfolded into explicit transposes (Expr.T collapses t(t(X)))."""
    a, b = mm.inputs
    M = Expr(a).T if mm.ta else Expr(a)
    N = Expr(b).T if mm.tb else Expr(b)
    return M, N


def rule_spores_rotate(node: Node) -> list[Node]:
    """sum((M@N) ⊙ A) ⇄ sum((A@Nᵀ) ⊙ M) ⇄ sum((Mᵀ@A) ⊙ N)."""
    if not _full_agg(node, ops=("sum",)):
        return []
    x = node.inputs[0]
    if x.op != "mul":
        return []
    out: list[Node] = []
    for mm, other in (x.inputs, x.inputs[::-1]):
        if not mm.is_matmul or other.shape != mm.shape:
            continue                     # rotation needs a non-broadcast ⊙
        M, N = _logical_mm(mm)
        A = Expr(other)
        out.append(((A @ N.T) * M).sum().node)
        out.append(((M.T @ A) * N).sum().node)
    return out


def rule_sum_transpose(node: Node) -> list[Node]:
    """agg_full(t(X)) → agg_full(X): full aggregates ignore cell order."""
    if not _full_agg(node):
        return []
    x = node.inputs[0]
    if x.op != "t":
        return []
    return [Expr(x.inputs[0])._agg(node.op, "full").node]


def rule_sum_mm_factor(node: Node) -> list[Node]:
    """sum(M@N) → sum(colsums(M)ᵀ ⊙ rowsums(N)): Σₖ (ΣᵢMᵢₖ)(ΣⱼNₖⱼ)."""
    if not _full_agg(node, ops=("sum",)):
        return []
    mm = node.inputs[0]
    if not mm.is_matmul:
        return []
    M, N = _logical_mm(mm)
    return [(M.colsums().T * N.rowsums()).sum().node]


def rule_sum_add_split(node: Node) -> list[Node]:
    """sum(A ± B) → sum(A) ± sum(B) (full-shape or scalar operands)."""
    if not _full_agg(node, ops=("sum",)):
        return []
    x = node.inputs[0]
    if x.op not in ("add", "sub"):
        return []
    terms: list[Expr] = []
    for side in x.inputs:
        if side.shape == x.shape:
            terms.append(Expr(side).sum())
        elif side.is_scalar:
            # a scalar broadcast over the sum's cells contributes ncells·s
            terms.append(Expr(side) * float(x.ncells))
        else:
            return []                   # row/col broadcast: out of scope
    a, b = terms
    return [(a + b).node if x.op == "add" else (a - b).node]


def rule_scalar_hoist(node: Node) -> list[Node]:
    """sum(A ⊙ s) → s ⊙ sum(A);  sum(A / s) → sum(A) / s  (s scalar)."""
    if not _full_agg(node, ops=("sum",)):
        return []
    x = node.inputs[0]
    if x.op == "mul":
        a, b = x.inputs
        if b.is_scalar and not a.is_scalar:
            return [(Expr(b) * Expr(a).sum()).node]
        if a.is_scalar and not b.is_scalar:
            return [(Expr(a) * Expr(b).sum()).node]
    elif x.op == "div":
        a, b = x.inputs
        if b.is_scalar and not a.is_scalar:
            return [(Expr(a).sum() / Expr(b)).node]
    return []


#: the documented rule set, applied in this (deterministic) order
RULES: tuple[tuple[str, Callable[[Node], list[Node]]], ...] = (
    ("spores_rotate", rule_spores_rotate),
    ("sum_transpose", rule_sum_transpose),
    ("sum_mm_factor", rule_sum_mm_factor),
    ("sum_add_split", rule_sum_add_split),
    ("scalar_hoist", rule_scalar_hoist),
)


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------

def _replace_node(graph: Graph, target_nid: int, replacement: Node) -> Graph:
    """Rebuild ``graph`` with ``target_nid`` substituted by ``replacement``
    (whose subtree references original nodes below the target, so the
    rebuild shares everything else; Graph.build re-runs CSE)."""
    memo: dict[int, Node] = {}

    def rb(n: Node) -> Node:
        got = memo.get(n.nid)
        if got is not None:
            return got
        if n.nid == target_nid:
            memo[n.nid] = replacement
            return replacement
        ins = tuple(rb(i) for i in n.inputs)
        nn = n if ins == n.inputs else Node(
            n.op, ins, n.shape, n.dtype, n.sparsity, n.name, dict(n.attrs))
        memo[n.nid] = nn
        return nn

    return Graph.build([rb(o) for o in graph.outputs])


def applicable(graph: Graph) -> bool:
    """Cheap prefilter: can any rule possibly match this DAG?"""
    return any(_full_agg(n) for n in graph.nodes)


def rewrite_variants(graph: Graph, max_variants: int = MAX_VARIANTS,
                     max_depth: int = MAX_DEPTH,
                     rules=RULES) -> list[RewriteVariant]:
    """Bounded BFS closure of the rule set over ``graph``.

    Deterministic: nodes are visited in topological order and rules in
    catalog order, so the same expression always yields the same variant
    list (labels use topological indices, stable across re-traces).  The
    original graph itself is never in the result."""
    if not applicable(graph):
        return []
    seen = {graph_digest(graph)}
    out: list[RewriteVariant] = []
    frontier: list[tuple[Graph, tuple[str, ...]]] = [(graph, ())]
    for _depth in range(max_depth):
        nxt: list[tuple[Graph, tuple[str, ...]]] = []
        for g, chain in frontier:
            for topo, node in enumerate(g.nodes):
                for rname, fn in rules:
                    for ri, rep in enumerate(fn(node)):
                        if len(out) >= max_variants:
                            return out
                        ng = _replace_node(g, node.nid, rep)
                        d = graph_digest(ng)
                        if d in seen:
                            continue
                        seen.add(d)
                        # rules yielding several replacements at one site
                        # get a .k suffix so every chain label is unique
                        lab = (f"{rname}@{topo}" if ri == 0
                               else f"{rname}@{topo}.{ri}")
                        v = RewriteVariant(ng, chain + (lab,), d)
                        out.append(v)
                        nxt.append((ng, v.rules))
        frontier = nxt
        if not frontier:
            break
    return out
