"""Candidate selection driver (paper §4): chooses the optimal, non-conflicting
set of fusion plans per HOP DAG and induces the runtime plan.

Modes mirror the paper's experimental arms:
  * ``gen``  — cost-based MPSkipEnum per partition (the contribution),
  * ``fa``   — fuse-all heuristic (maximal fusion, redundant CSE compute),
  * ``fnr``  — fuse-no-redundancy (materialize every multi-consumer
               intermediate),
  * ``none`` — no fusion at all (Base): every operator basic.

Multi-aggregate combining: selected MAgg-rooted fused operators that share
at least one input merge into a single multi-output fused operator (paper
§5.2: "Gen compiles a multi-aggregate with a 2×1 output matrix"), dedup-ing
their shared scans.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro import hw as _hw
from .cost import (CostParams, FusedOpSpec, Placement, TPU_V5E, node_bytes,
                   resolve_partition, row_partitioned, spec_cost,
                   spec_placement)
from .enumerate import EnumStats, mp_skip_enum
from .explore import ExploreStats, explore
from .ir import Graph
from .memo import MemoTable
from .partitions import (Partition, PlanInvariantError, Point,
                         build_partitions)
from .templates import TType

_EPILOGUES = ("none", "psum", "pmin", "pmax")

MODES = ("gen", "fa", "fnr", "none")


@dataclass
class MultiAggSpec:
    """k combined full aggregates sharing a single scan of their inputs."""
    roots: list[int]
    parts: list[FusedOpSpec]
    inputs: list[int]
    #: local/distributed decision (see :class:`repro.core.cost.Placement`)
    placement: Optional[Placement] = None

    root = property(lambda self: self.roots[0])
    ttype = TType.MAGG
    fused = True
    driver = None


@dataclass(frozen=True)
class Segment:
    """A maximal run of adjacent distributed-placed operators that
    executes inside a single ``shard_map`` region: intra-segment
    row-partitioned intermediates flow shard-to-shard instead of being
    gathered and re-scattered at every operator boundary."""

    indices: tuple[int, ...]       # positions in ExecPlan.specs, in order
    axes: tuple[str, ...]          # row-shard mesh axes
    n: int                         # row-shard degree
    #: (producer spec idx, consumer spec idx, nid) row-sharded edges
    sharded_edges: tuple[tuple[int, int, int], ...]
    #: boundary all-gather volume the fused region removes (bytes): one
    #: ring all-gather of each row-sharded intra-segment intermediate
    removed_gather_bytes: float


@dataclass
class ExecPlan:
    graph: Graph
    specs: list          # FusedOpSpec | MultiAggSpec, dependency order
    cost: float
    memo: Optional[MemoTable] = None
    enum_stats: Optional[EnumStats] = None
    explore_stats: Optional[ExploreStats] = None
    #: contiguous distributed runs (see :class:`Segment`); empty when the
    #: plan was selected without distributed geometry
    segments: tuple = ()
    #: cost parameters the plan was selected under — the verifier replays
    #: placement/segment derivations and constraint checks against these
    params: Optional[CostParams] = None
    #: winning rewrite-rule chain (:mod:`repro.core.rewrite` labels, e.g.
    #: ``("spores_rotate@7",)``) when this plan was selected for a rewritten
    #: variant of the traced DAG; () for the DAG as written.  Part of the
    #: whole-plan cache key (:func:`repro.core.codegen.staged_plan_key`).
    rewrite: tuple = ()

    def fused_specs(self) -> list:
        return [s for s in self.specs if getattr(s, "fused", False)]


def select(graph: Graph, memo: MemoTable, mode: str = "gen",
           params: CostParams = TPU_V5E,
           enum_stats: Optional[EnumStats] = None) -> tuple[list, float]:
    """Run selection, returning (specs in dependency order, total cost)."""
    assert mode in MODES, mode
    st = enum_stats if enum_stats is not None else EnumStats()
    parts = build_partitions(graph, memo) if mode != "none" else []

    specs: list = []
    covered: set[int] = set()
    produced: set[int] = set()
    total_cost = 0.0
    for part in parts:
        st.partitions += 1
        st.points_total += len(part.points)
        st.space_size += 2 ** len(part.points)
        banned = _assignment(graph, memo, part, mode, params, st)
        probe = "greedy" if mode in ("fa", "fnr") else "cost"
        part_specs = resolve_partition(graph, memo, part, banned, params,
                                       probe=probe)
        total_cost += sum(spec_cost(graph, s, params) for s in part_specs)
        for s in part_specs:
            specs.append(s)
            produced.add(s.root)
            covered.update(s.cover)

    # demand-driven fill-in: basic operators for every node that some spec
    # (or the graph outputs) reads but no partition plan produces.  Nodes
    # covered inside fused operators and consumed only there need nothing.
    demanded: list[int] = list(graph.output_ids)
    for s in specs:
        demanded.extend(s.inputs)
    while demanded:
        nid = demanded.pop()
        node = graph.by_id[nid]
        if nid in produced or node.is_input:
            continue
        spec = FusedOpSpec(nid, None, {nid: None},
                           [i.nid for i in node.inputs])
        specs.append(spec)
        produced.add(nid)
        total_cost += spec_cost(graph, spec, params)
        demanded.extend(i.nid for i in node.inputs)

    specs = _topo_order(graph, specs)
    specs = _combine_multi_aggs(graph, specs, params)
    if params.dist is not None and params.dist.n > 1:
        # re-walk the final plan in dependency order: pin placements with
        # chain-aware pricing and make that walk the authoritative plan
        # cost (the executed plan is the costed plan)
        total_cost = _annotate_placements(graph, specs, params)
    return specs, total_cost


def plan(graph: Graph, mode: str = "gen", params: CostParams = TPU_V5E,
         prune_dominated: Optional[bool] = None) -> ExecPlan:
    """Explore + select in one call (the paper's codegen compiler steps 1-2)."""
    if mode == "none":
        memo = MemoTable()
        ex_st = ExploreStats()
    else:
        ex_st = ExploreStats()
        dom = prune_dominated if prune_dominated is not None else mode in ("fa", "fnr")
        memo = explore(graph, prune_dominated=dom, stats=ex_st)
    en_st = EnumStats()
    specs, cost = select(graph, memo, mode, params, enum_stats=en_st)
    segments = annotate_segments(graph, specs, params)
    return ExecPlan(graph, specs, cost, memo, en_st, ex_st,
                    segments=segments, params=params)


# -- assignment policies -----------------------------------------------------

def _assignment(graph: Graph, memo: MemoTable, part: Partition, mode: str,
                params: CostParams, st: EnumStats) -> set[Point]:
    if mode == "fa" or not part.points:
        if mode == "gen" and not part.points:
            st.plans_costed += 1
        return set()                       # maximal fusion
    if mode == "fnr":
        # materialize every multi-consumer intermediate
        mat = set(part.mat_points)
        return {p for p in part.points if p[1] in mat}
    q, _ = mp_skip_enum(graph, memo, part, params, stats=st)
    return {p for p, v in zip(part.points, q) if v}


# -- local/distributed placement (hybrid plans) --------------------------------

def resolved_placements(graph: Graph, specs: list, params: CostParams
                        ) -> tuple[list, float]:
    """The authoritative local-vs-distributed walk, as a pure function:
    returns ``(placements, total cost)`` with one
    :class:`~repro.core.cost.Placement` (or None, for basic operators)
    per spec, **without** mutating the specs.  Walks the plan in
    dependency order threading the interior-producer state (a
    row-partitioned intermediate anchors its distributed consumers and
    charges local ones the boundary gather).

    A combined multi-aggregate distributes only when *every* member
    aggregate does (all sum-reduced partials ride one ``psum`` of the
    stacked (k, 1) output); a single local member keeps the whole
    operator local rather than splitting one scan across arms.  Raises
    :class:`~repro.core.partitions.PlanInvariantError` when the members'
    distributed placements disagree on the row-shard group — one scan
    cannot straddle two shard geometries.

    Also the plan verifier's replay (`SEL014`): re-running this walk over
    a plan's specs must reproduce the pinned placements exactly."""
    interior: dict[int, bool] = {}
    placements: list = []
    total = 0.0
    for s in specs:
        if isinstance(s, MultiAggSpec):
            pls = [spec_placement(graph, p, params, interior)
                   for p in s.parts]
            if pls and all(p.arm == "distributed" and p.epilogue == "psum"
                           for p in pls):
                n = pls[0].n
                if any((p.axes, p.n) != (pls[0].axes, n) for p in pls):
                    raise PlanInvariantError(
                        f"multi-aggregate %{s.root}: member placements "
                        f"disagree on the row-shard group "
                        f"{sorted({(p.axes, p.n) for p in pls})} — one "
                        f"combined scan cannot straddle shard geometries")
                out_b = len(s.roots) * params.dtype_bytes
                gather = sum(p.gather_bytes for p in pls)
                coll = gather + _hw.all_reduce_bytes(out_b, n)
                sharded = frozenset().union(*(p.sharded for p in pls))
                pl = Placement(
                    "distributed", sum(p.cost for p in pls),
                    sum(p.local_cost for p in pls),
                    sum(p.dist_cost for p in pls), "psum",
                    pls[0].axes, n, coll, gather, sharded)
            else:
                # keep the per-part distributed evidence: a finite
                # dist_cost here means "possible but not chosen", which
                # is what explain() debugging needs to see
                local = sum(p.local_cost for p in pls) if pls else 0.0
                dist = sum(p.dist_cost for p in pls) if pls else math.inf
                pl = Placement("local", local, local, dist)
            placements.append(pl)
            total += pl.cost
            for r in s.roots:
                interior[r] = False       # psum output is replicated
        elif getattr(s, "fused", False):
            pl = spec_placement(graph, s, params, interior)
            placements.append(pl)
            total += pl.cost
            interior[s.root] = row_partitioned(pl)
        else:
            placements.append(None)
            total += spec_cost(graph, s, params, interior)
    return placements, total


def _annotate_placements(graph: Graph, specs: list,
                         params: CostParams) -> float:
    """Pin the local-vs-distributed decision :func:`spec_cost` already
    priced onto every fused operator, so codegen executes — and
    ``explain()`` reports — exactly the costed arm.  Returns the
    resulting total plan cost (see :func:`resolved_placements`)."""
    placements, total = resolved_placements(graph, specs, params)
    for s, pl in zip(specs, placements):
        if pl is not None:
            s.placement = pl
    return total


def annotate_segments(graph: Graph, specs: list,
                      params: CostParams) -> tuple:
    """Group maximal runs of *adjacent* distributed-placed operators into
    :class:`Segment`\\ s — the units codegen lowers into a single
    ``shard_map`` region.

    Two consecutive distributed specs stay in one run when they share the
    row-shard group (axes, n) and their data flow is representable inside
    one region: a value produced row-partitioned in the run (``"none"``
    epilogue) must be read as a row shard by every in-run consumer, a
    reduced value (replicated after its collective) must be read
    broadcast, and an external operand consumed by several run members
    must be sharded for all of them or none.  Violations split the run —
    correctness over region length.

    Raises :class:`~repro.core.partitions.PlanInvariantError` when a
    spec's placement is not even internally consistent — an unknown
    collective epilogue, a sharded operand the spec does not bind, or two
    specs producing the same value: splitting runs cannot repair those,
    and lowering them would compute garbage."""
    if params.dist is None or params.dist.n <= 1:
        return ()
    segments: list[Segment] = []
    run: list[int] = []

    def roots_of(s) -> tuple[int, ...]:
        return tuple(s.roots) if isinstance(s, MultiAggSpec) else (s.root,)

    roots_seen: dict[int, int] = {}
    for idx, s in enumerate(specs):
        for r in roots_of(s):
            if r in roots_seen:
                raise PlanInvariantError(
                    f"value %{r} is produced by both spec "
                    f"[{roots_seen[r]}] and spec[{idx}] — segment "
                    f"grouping needs a single producer per value")
            roots_seen[r] = idx
        pl = getattr(s, "placement", None)
        if pl is None or pl.arm != "distributed":
            continue
        if pl.epilogue not in _EPILOGUES:
            raise PlanInvariantError(
                f"spec[{idx}] (root %{s.root}) has unknown collective "
                f"epilogue {pl.epilogue!r}; expected one of "
                f"{_EPILOGUES}")
        extra = set(pl.sharded) - set(s.inputs)
        if extra:
            raise PlanInvariantError(
                f"spec[{idx}] (root %{s.root}) placement marks "
                f"{sorted(extra)} row-sharded but the spec does not "
                f"bind them — placement and binding drifted apart")

    def compatible(idx: int) -> bool:
        s = specs[idx]
        pl = s.placement
        head = specs[run[0]].placement
        if pl.axes != head.axes or pl.n != head.n:
            return False
        produced = {r: specs[j].placement.epilogue
                    for j in run for r in roots_of(specs[j])}
        for i in s.inputs:
            epil = produced.get(i)
            if epil == "none" and i not in pl.sharded:
                return False          # would need an in-region gather
            if epil is not None and epil != "none" and i in pl.sharded:
                return False          # replicated value read as a shard
            if epil is None:          # shared external operand: one view
                for j in run:
                    pj = specs[j].placement
                    if i in specs[j].inputs and \
                            (i in pj.sharded) != (i in pl.sharded):
                        return False
        return True

    def flush() -> None:
        if len(run) >= 2:
            head = specs[run[0]].placement
            produced = {r: (j, specs[j].placement.epilogue)
                        for j in run for r in roots_of(specs[j])}
            edges = []
            saved = 0.0
            for c in run:
                for i in specs[c].inputs:
                    hit = produced.get(i)
                    if hit is not None and hit[1] == "none" \
                            and i in specs[c].placement.sharded:
                        edges.append((hit[0], c, i))
                        saved += _hw.all_gather_bytes(
                            node_bytes(graph.by_id[i], params), head.n)
            segments.append(Segment(tuple(run), head.axes, head.n,
                                    tuple(edges), saved))
        run.clear()

    for idx, s in enumerate(specs):
        pl = getattr(s, "placement", None)
        if pl is None or pl.arm != "distributed":
            flush()
            continue
        if run and not compatible(idx):
            flush()
        run.append(idx)
    flush()
    return tuple(segments)


# -- helpers -------------------------------------------------------------------

def _topo_order(graph: Graph, specs: list) -> list:
    pos = {n.nid: i for i, n in enumerate(graph.nodes)}
    return sorted(specs, key=lambda s: pos[s.root])


def _combine_multi_aggs(graph: Graph, specs: list,
                        params: CostParams) -> list:
    """Greedily merge MAgg fused ops sharing ≥1 input and a common main
    shape into multi-output fused operators."""
    groups: list[list[FusedOpSpec]] = []
    rest: list = []
    for s in specs:
        if isinstance(s, FusedOpSpec) and s.ttype == TType.MAGG and s.fused:
            placed = False
            for g in groups:
                if (set(g[0].inputs) & set(s.inputs)
                        and _main_shape(graph, g[0]) == _main_shape(graph, s)
                        and len(g) < 4):
                    g.append(s)
                    placed = True
                    break
            if not placed:
                groups.append([s])
        else:
            rest.append(s)

    out: list = list(rest)
    for g in groups:
        if len(g) == 1:
            out.append(g[0])
        else:
            inputs: list[int] = []
            for s in g:
                for i in s.inputs:
                    if i not in inputs:
                        inputs.append(i)
            out.append(MultiAggSpec([s.root for s in g], g, inputs))
    return _topo_order(graph, out)


def _main_shape(graph: Graph, spec: FusedOpSpec) -> tuple[int, int]:
    shapes = [graph.by_id[i].shape for i in spec.inputs
              if not graph.by_id[i].is_scalar]
    if not shapes:
        return (1, 1)
    return max(shapes, key=lambda s: s[0] * s[1])
