"""Candidate exploration — the OFMC algorithm (paper §3.2, Algorithm 1).

A single bottom-up, depth-first pass over the HOP DAG populates the memo
table with all valid partial fusion plans.  Template-oblivious: all
template-specific logic lives behind the open/fuse/merge/close predicates in
:mod:`templates`.  Linear in the number of operators (memoized); per
operator at most O(2^|inputs| · |T|) entries.

Placement-oblivious too: the same memo entries serve both execution arms
of the ``local × distributed`` dimension.  A distributed variant of a
template changes *where* the generated body runs and which collective
epilogue closes it (:data:`repro.core.templates.DIST_VARIANTS`), not
which fusion structures are valid — so exploration enumerates structure
once, and selection (:mod:`repro.core.select` / :func:`repro.core.cost.
spec_cost`) prices each surviving candidate on both arms when a mesh
layout is in scope.
"""

from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations

from .ir import Graph, Node
from .memo import MemoEntry, MemoTable
from .templates import TEMPLATES, Status, Template


@dataclass
class ExploreStats:
    operators: int = 0
    entries_created: int = 0
    entries_kept: int = 0
    opens: int = 0
    fuses: int = 0


def explore(graph: Graph, *, prune_dominated: bool = False,
            stats: ExploreStats | None = None) -> MemoTable:
    """Populate a memo table for ``graph`` (Algorithm 1 driver)."""
    memo = MemoTable()
    st = stats if stats is not None else ExploreStats()
    for out in graph.outputs:
        _ofmc_explore(out, graph, memo, st)
    if prune_dominated:
        single = _single_consumer_ids(graph)
        for nid in list(memo.groups()):
            memo.prune_dominated(nid, single)
    return memo


def _single_consumer_ids(graph: Graph) -> set[int]:
    return {nid for nid in graph.by_id if graph.n_consumers(nid) <= 1}


def _ofmc_explore(h: Node, graph: Graph, memo: MemoTable,
                  st: ExploreStats) -> None:
    # -- memoization of processed operators (lines 1-3) ---------------------
    if memo.processed(h.nid):
        return
    # -- recursive candidate exploration (lines 4-6) -------------------------
    for gin in h.inputs:
        _ofmc_explore(gin, graph, memo, st)
    if h.is_input:
        memo.mark_processed(h.nid)
        return
    st.operators += 1

    entries: list[MemoEntry] = []
    # -- open initial operator plans (lines 7-10) -----------------------------
    for t in TEMPLATES.values():
        if t.open(h):
            st.opens += 1
            entries.extend(_create_plans(h, None, t, memo))
    # -- fuse and merge operator plans (lines 11-15) ---------------------------
    for j, gin in enumerate(h.inputs):
        for tt in memo.distinct_types(gin.nid):
            t = TEMPLATES[tt]
            if memo.has_open(gin.nid, tt) and t.fuse(h, gin):
                st.fuses += 1
                entries.extend(_create_plans(h, j, t, memo))
    st.entries_created += len(entries)

    # -- close operator plans (lines 16-20) -------------------------------------
    kept: list[MemoEntry] = []
    for e in entries:
        status = TEMPLATES[e.ttype].close(h, graph)
        if status == Status.CLOSED_INVALID:
            continue
        kept.append(e.with_status(status))
    memo.add_all(h.nid, kept)

    # -- prune redundant plans and memoize (lines 21-24) --------------------------
    memo.prune_redundant(h.nid, len(h.inputs))
    st.entries_kept += len(memo.entries(h.nid))
    memo.mark_processed(h.nid)


def _create_plans(h: Node, fuse_j: int | None, t: Template,
                  memo: MemoTable) -> list[MemoEntry]:
    """CREATEPLANS (paper §3.2): build entries for the fused operator at h
    under template t, enumerating all *local* input combinations that satisfy
    the pair-wise merge condition.  ``fuse_j`` (if given) is the input whose
    open plan triggered the fuse — it is always referenced."""
    n = len(h.inputs)
    fusable: list[bool] = []
    for j, gin in enumerate(h.inputs):
        if gin.is_input:
            fusable.append(False)            # leaves have no groups
        elif j == fuse_j:
            fusable.append(True)
        else:
            fusable.append(t.merge(h, gin)
                           and memo.has_compatible_open(gin.nid, t.ttype))
    cand = [j for j in range(n) if fusable[j] and j != fuse_j]

    entries: list[MemoEntry] = []
    for k in range(len(cand) + 1):
        for sub in combinations(cand, k):
            chosen = set(sub)
            if fuse_j is not None:
                chosen.add(fuse_j)
            refs = tuple(h.inputs[j].nid if j in chosen else -1
                         for j in range(n))
            entries.append(MemoEntry(t.ttype, refs))
    return entries
