"""Code generation & runtime integration (paper §2.1-2.2).

Turns selected plans into executable operators and whole ExecPlans into
callables.  The **plan cache** memoizes generated operators by structural
CPlan hash (shapes/ops/binding/variant) so dynamic recompilation and
repeated tracing reuse compiled operators — the paper's Fig. 11 mechanism.

Execution paths per operator are chosen by the dispatcher in
``kernels/ops.py`` (dense XLA, dense Pallas, BCSR sparsity-exploiting,
CLA-compressed).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.blocksparse import BCSR, DictCompressed
from .cost import FusedOpSpec
from .cplan import CPlan, build_cplan
from .ir import Graph, Node
from .select import ExecPlan, MultiAggSpec


# --------------------------------------------------------------------------
# plan cache
# --------------------------------------------------------------------------

@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    codegen_time_s: float = 0.0

    @property
    def total(self) -> int:
        return self.hits + self.misses


class PlanCache:
    """Thread-safe LRU cache of generated operators keyed by structural
    CPlan hash.  Bounded: least-recently-used operators are evicted past
    ``maxsize`` (XLA still holds its own executable cache; this bounds the
    python-side operator objects)."""

    def __init__(self, maxsize: int = 512) -> None:
        self.maxsize = int(maxsize)
        self._ops: "OrderedDict[str, GeneratedOp]" = OrderedDict()
        self._lock = threading.RLock()
        self.stats = PlanCacheStats()

    def get_or_build(self, graph: Graph, spec) -> tuple["GeneratedOp", "CPlan"]:
        """Returns (generated operator, this spec's CPlan).  The operator
        may come from a structurally-equal plan of a *different* graph, so
        callers bind inputs positionally via the returned CPlan."""
        t0 = time.perf_counter()
        cplan = build_cplan(graph, spec)
        key = cplan.cache_key()
        with self._lock:
            hit = self._ops.get(key)
            if hit is not None:
                self._ops.move_to_end(key)
                self.stats.hits += 1
                return hit, cplan
            op = GeneratedOp(cplan)
            self._ops[key] = op
            while len(self._ops) > self.maxsize:
                self._ops.popitem(last=False)
                self.stats.evictions += 1
            self.stats.misses += 1
            self.stats.size = len(self._ops)
            self.stats.codegen_time_s += time.perf_counter() - t0
            return op, cplan

    def __len__(self) -> int:
        with self._lock:
            return len(self._ops)

    def clear(self) -> None:
        with self._lock:
            self._ops.clear()
            self.stats = PlanCacheStats()


PLAN_CACHE = PlanCache()


def plan_cache_stats() -> PlanCacheStats:
    """Snapshot of the global plan-cache counters (public API).

    Returns a :class:`PlanCacheStats` value (not a live view) with
    ``hits`` / ``misses`` / ``total`` (get-or-build calls), ``evictions``
    (LRU past the 512-operator bound), ``size`` (operators currently
    cached), and ``codegen_time_s`` (cumulative CPlan-build time on
    misses).  The cache keys operators by *structural* CPlan hash, so a
    hit means some structurally-equal plan — any expression, any
    trace — already generated the operator.  Useful assertions:
    ``stats.total`` grows when a backward pass compiles, ``misses`` stays
    flat across re-traces of the same shapes."""
    with PLAN_CACHE._lock:
        return replace(PLAN_CACHE.stats, size=len(PLAN_CACHE._ops))


# --------------------------------------------------------------------------
# generated operators
# --------------------------------------------------------------------------

@dataclass
class GeneratedOp:
    """A fused operator: CPlan + execution dispatch (SystemML's SpoofOp).

    The program is interpreted at trace time under ``jax.jit`` — the jitted
    computation is the compiled generated operator (the janino-compile
    analogue); jax caches it per input shape/format signature.
    """
    cplan: CPlan
    _jits: dict = field(default_factory=dict)

    def _run(self, env: dict[int, object], pallas: str):
        cp = self.cplan
        main = env.get(cp.main.nid)
        from repro.core.templates import TType
        if isinstance(main, BCSR) and cp.ttype == TType.OUTER \
                and pallas != "never" and cp.variant in ("right_mm",
                                                         "full_agg"):
            from repro.kernels.outerprod import outer_pallas
            return outer_pallas(cp, env, interpret=pallas == "interpret")
        return kops.execute(cp, env, pallas=pallas)

    def __call__(self, env: dict[int, object], pallas: str = "never"):
        if pallas == "interpret":
            return self._run(env, pallas)     # validation path: stay eager
        fn = self._jits.get(pallas)
        if fn is None:
            import jax
            fn = jax.jit(lambda e: self._run(e, pallas))
            self._jits[pallas] = fn
        return fn(env)


def _eval_basic(graph: Graph, node: Node, env: dict[int, object]):
    """Basic (unfused) operator, sparse-format aware."""
    ins = [env[i.nid] if i.op != "lit" else
           jnp.asarray(float(i.attrs["value"]), jnp.float32).reshape(1, 1)
           for i in node.inputs]
    if node.is_matmul and isinstance(ins[0], BCSR):
        b = ins[1]
        b = b.todense() if hasattr(b, "todense") else b
        b = b.T if node.tb else b
        # ta=True: transpose the block structure (BCSR.T is exact and
        # O(nnz)) instead of densifying the sparse operand.
        a = ins[0].T if node.ta else ins[0]
        return kops.bcsr_matmul(a, b)
    if node.op == "mul" and isinstance(ins[0], BCSR) \
            and not isinstance(ins[1], BCSR) \
            and getattr(ins[1], "shape", None) == ins[0].shape:
        return kops.bcsr_mul_dense(ins[0], ins[1])
    ins = [v.todense() if hasattr(v, "todense") else v for v in ins]
    return kref.eval_node(node.op, ins, node.attrs)


# --------------------------------------------------------------------------
# executable plans
# --------------------------------------------------------------------------

@dataclass
class CompiledPlan:
    """Executable form of an ExecPlan: run specs in dependency order,
    freeing intermediates when their last consumer has run (the paper's
    'fewer materialized intermediates' at the plan level).

    When the plan was selected under a mesh layout, fused operators whose
    placement is ``"distributed"`` execute their generated body inside
    ``shard_map`` over the layout's real mesh with the template's
    collective epilogue (:mod:`repro.kernels.distributed`); everything
    else — and every operator when the mesh is abstract or an operand is
    sparse — runs the local generated operator.  One plan, hybrid
    execution."""
    plan: ExecPlan
    pallas: str = "never"
    cache: PlanCache = field(default_factory=lambda: PLAN_CACHE)
    #: FusionLayout the plan was selected under (None: local-only)
    layout: Optional[object] = None
    #: per-spec-index compiled shard_map callables (False: not realizable)
    _dist_fns: dict = field(default_factory=dict, repr=False)

    def _dist_call(self, idx: int, spec, cplan, env: dict[int, object]):
        """Run one distributed-placed operator, or None to fall back."""
        pl = getattr(spec, "placement", None)
        if pl is None or pl.arm != "distributed" or self.layout is None:
            return None
        vals = [env[b.nid] for b in cplan.binds]
        if any(hasattr(v, "todense") for v in vals):
            return None                    # sparse operand: local fallback
        fn = self._dist_fns.get(idx)
        if fn is None:
            from repro.kernels.distributed import build_dist_fn
            fn = build_dist_fn(cplan, getattr(self.layout, "mesh", None), pl)
            self._dist_fns[idx] = fn if fn is not None else False
        if not fn:
            return None
        return fn(*vals)

    def __call__(self, bindings: dict[str, object]):
        graph = self.plan.graph
        env: dict[int, object] = {}
        for node in graph.inputs():
            if node.name not in bindings:
                raise KeyError(f"missing binding for input '{node.name}'")
            env[node.nid] = bindings[node.name]
        for node in graph.nodes:     # literals
            if node.op == "lit":
                env[node.nid] = jnp.full((1, 1), float(node.attrs["value"]),
                                         jnp.float32)

        last_use = _last_uses(self.plan)
        for idx, spec in enumerate(self.plan.specs):
            if isinstance(spec, MultiAggSpec) or (
                    isinstance(spec, FusedOpSpec) and spec.fused):
                op, my_cplan = self.cache.get_or_build(graph, spec)
                out = self._dist_call(idx, spec, my_cplan, env)
                if out is None:
                    # positional re-binding: cached operator's nids ≠ ours
                    op_env = {ob.nid: env[mb.nid] for ob, mb in
                              zip(op.cplan.binds, my_cplan.binds)}
                    out = op(op_env, pallas=self.pallas)
                if isinstance(spec, MultiAggSpec):
                    for k, r in enumerate(spec.roots):
                        env[r] = out[k].reshape(1, 1)
                else:
                    env[spec.root] = out
            else:
                env[spec.root] = _eval_basic(graph, graph.by_id[spec.root],
                                             env)
            for dead in last_use.get(idx, ()):    # free intermediates
                if dead not in graph.output_ids:
                    env.pop(dead, None)
        outs = [env[o.nid] for o in graph.outputs]
        return outs[0] if len(outs) == 1 else tuple(outs)


def _last_uses(plan: ExecPlan) -> dict[int, list[int]]:
    last: dict[int, int] = {}
    for idx, spec in enumerate(plan.specs):
        for i in spec.inputs:
            last[i] = idx
    out: dict[int, list[int]] = {}
    for nid, idx in last.items():
        out.setdefault(idx, []).append(nid)
    return out


def compile_plan(plan: ExecPlan, pallas: str = "never",
                 layout=None) -> CompiledPlan:
    return CompiledPlan(plan, pallas=pallas, layout=layout)
