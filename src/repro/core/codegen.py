"""Code generation & runtime integration (paper §2.1-2.2).

Turns selected plans into executable operators and whole ExecPlans into
callables.  Two cache layers memoize the generated code:

* the **plan cache** memoizes generated *operators* by structural CPlan
  hash (shapes/ops/binding/variant) so dynamic recompilation and repeated
  tracing reuse compiled operators — the paper's Fig. 11 mechanism;
* the **whole-plan cache** memoizes the *staged plan function* — the
  entire ExecPlan (fused operators, basic ops, literals, multi-aggregate
  unpacking, distributed segments) traced into one function and jitted
  once — by structural plan signature, so structurally-equal plans share
  one XLA executable.

Staged execution is the default for **every** operand format and Pallas
mode — dense, BCSR, CLA-compressed, ``pallas="interpret"`` — one
dispatch per plan call, literals folded as trace constants, dead
intermediates released via ``_last_uses`` (XLA then reuses their
buffers — plan-level buffer donation), and runs of adjacent distributed
operators lowered into a single ``shard_map`` region whose body runs the
generated kernels over shard-local shapes
(:mod:`repro.kernels.distributed`).  Only ``compile_plan(staged=False)``
selects the per-operator interpreter dispatch, kept as an explicit debug
path.  Any remaining downgrade (e.g. a sparse operand whose block rows
do not partition across the mesh) is *recorded*, never silent: the
reasons surface in ``explain()['execution']['fallbacks']``, are checked
by the EXE005 verifier invariant and by ``fusionlint --strict``, and
raise under ``FusionContext(verify="strict")`` when a costed distributed
placement is abandoned at execution time.

Execution paths per operator are chosen by the dispatcher in
``kernels/ops.py`` (dense XLA, dense Pallas, BCSR sparsity-exploiting,
CLA-compressed); the full kernel-dispatch decision table lives in
``docs/architecture.md``.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax.numpy as jnp

from repro import faults
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.blocksparse import (BCSR, DictCompressed, ShardedBCSR)
from .cost import FusedOpSpec
from .cplan import CPlan, NO_AGG, build_cplan
from .ir import Graph, Node
from .partitions import PlanInvariantError
from .select import ExecPlan, MultiAggSpec


faults.register_site(
    "plan.jit_build",
    "whole-plan XLA build: jit(plan_fn) / jit(vmap(plan_fn)) inside the "
    "whole-plan cache builder (first call per structural plan key)",
    kinds=("error", "latency"),
    handler="FusionServer._entry build ladder (batched → exact-shape → "
            "per-op) + build circuit breaker; failed builds are not "
            "cached, so retries rebuild")


def _mesh_of(layout):
    """Mesh carried by a layout-ish object: a FusionLayout (``.mesh``),
    a bare mesh passed directly (``.axis_names``), or None."""
    if layout is None:
        return None
    mesh = getattr(layout, "mesh", None)
    if mesh is None and hasattr(layout, "axis_names"):
        return layout
    return mesh


def _is_real_mesh(mesh) -> bool:
    """True for an executable jax Mesh (vs an abstract LogicalMesh used
    for cost-only planning, or None)."""
    try:
        from jax.sharding import Mesh
    except ImportError:                            # pragma: no cover
        return False
    return isinstance(mesh, Mesh)


# --------------------------------------------------------------------------
# plan cache
# --------------------------------------------------------------------------

@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    capacity: int = 0
    codegen_time_s: float = 0.0

    @property
    def total(self) -> int:
        return self.hits + self.misses


class PlanCache:
    """Thread-safe LRU cache of generated operators keyed by structural
    CPlan hash.  Bounded: least-recently-used operators are evicted past
    ``maxsize`` (XLA still holds its own executable cache; this bounds the
    python-side operator objects).  The bound is configurable — pass
    ``maxsize``, set ``REPRO_PLAN_CACHE_CAPACITY`` in the environment, or
    call :meth:`resize` on a live cache (long-lived serving processes
    churn through thousands of plan structures; unbounded growth is a
    slow leak)."""

    def __init__(self, maxsize: Optional[int] = None) -> None:
        if maxsize is None:
            import os
            maxsize = int(os.environ.get("REPRO_PLAN_CACHE_CAPACITY", 512))
        self.maxsize = int(maxsize)
        self._ops: "OrderedDict[str, GeneratedOp]" = OrderedDict()
        self._lock = threading.RLock()
        self.stats = PlanCacheStats(capacity=self.maxsize)

    def resize(self, maxsize: int) -> None:
        """Change the LRU capacity, evicting LRU entries past the new
        bound immediately."""
        with self._lock:
            self.maxsize = int(maxsize)
            self.stats.capacity = self.maxsize
            while len(self._ops) > self.maxsize:
                self._ops.popitem(last=False)
                self.stats.evictions += 1
            self.stats.size = len(self._ops)

    def get_or_build(self, graph: Graph, spec) -> tuple["GeneratedOp", "CPlan"]:
        """Returns (generated operator, this spec's CPlan).  The operator
        may come from a structurally-equal plan of a *different* graph, so
        callers bind inputs positionally via the returned CPlan."""
        t0 = time.perf_counter()
        cplan = build_cplan(graph, spec)
        key = cplan.cache_key()
        with self._lock:
            hit = self._ops.get(key)
            if hit is not None:
                self._ops.move_to_end(key)
                self.stats.hits += 1
                return hit, cplan
            op = GeneratedOp(cplan)
            self._ops[key] = op
            while len(self._ops) > self.maxsize:
                self._ops.popitem(last=False)
                self.stats.evictions += 1
            self.stats.misses += 1
            self.stats.size = len(self._ops)
            self.stats.codegen_time_s += time.perf_counter() - t0
            return op, cplan

    def __len__(self) -> int:
        with self._lock:
            return len(self._ops)

    def clear(self) -> None:
        with self._lock:
            self._ops.clear()
            self.stats = PlanCacheStats(capacity=self.maxsize)


PLAN_CACHE = PlanCache()


def plan_cache_stats() -> PlanCacheStats:
    """Snapshot of the global plan-cache counters (public API).

    Returns a :class:`PlanCacheStats` value (not a live view) with
    ``hits`` / ``misses`` / ``total`` (get-or-build calls), ``evictions``
    (LRU past the configurable ``capacity`` bound — 512 operators by
    default), ``size`` (operators currently cached), ``capacity`` (the
    current LRU bound), and ``codegen_time_s`` (cumulative CPlan-build
    time on misses).  The cache keys operators by *structural* CPlan
    hash, so a hit means some structurally-equal plan — any expression,
    any trace — already generated the operator.  Useful assertions:
    ``stats.total`` grows when a backward pass compiles, ``misses`` stays
    flat across re-traces of the same shapes."""
    with PLAN_CACHE._lock:
        return replace(PLAN_CACHE.stats, size=len(PLAN_CACHE._ops),
                       capacity=PLAN_CACHE.maxsize)


# --------------------------------------------------------------------------
# whole-plan cache (staged plan functions, layered on the plan cache)
# --------------------------------------------------------------------------

@dataclass
class WholePlanCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    capacity: int = 0
    build_time_s: float = 0.0
    #: per-key stat records currently tracked / dropped past the bound
    tracked_keys: int = 0
    dropped_keys: int = 0

    @property
    def total(self) -> int:
        return self.hits + self.misses


#: per-key stat records kept across entry churn (bounded separately from
#: the function LRU so eviction metrics survive the evicted entries)
KEY_STATS_CAPACITY = 4096


class WholePlanCache:
    """Thread-safe LRU of jitted whole-plan functions keyed by structural
    plan signature (per-operator CPlan hashes + env wiring + literals +
    segment/placement structure + pallas policy + mesh).  A hit returns
    the *same* jitted function object, so XLA's executable cache is shared
    across structurally-equal CompiledPlans (``fuse_exprs`` in a loop,
    re-traced shapes, the backward of an identical forward).

    **Build-once:** :meth:`get_or_create` serializes concurrent misses on
    the same key — one thread builds, the rest wait and share the result —
    so N threads compiling structurally-equal plans produce exactly one
    jitted function (duplicate jit wrappers would each pay their own XLA
    compile later).

    **Lifecycle:** the LRU bound is configurable (``maxsize`` /
    ``REPRO_WHOLE_PLAN_CACHE_CAPACITY`` / :meth:`resize`) and per-key
    hit/miss/eviction/build-time counters (:meth:`key_stats`) survive
    entry eviction, so a serving process churning through thousands of
    plan structures can still report which keys thrash."""

    def __init__(self, maxsize: Optional[int] = None) -> None:
        if maxsize is None:
            import os
            maxsize = int(os.environ.get(
                "REPRO_WHOLE_PLAN_CACHE_CAPACITY", 256))
        self.maxsize = int(maxsize)
        self._fns: "OrderedDict[tuple, Callable]" = OrderedDict()
        self._lock = threading.RLock()
        self._pending: dict[tuple, threading.Event] = {}
        self._key_stats: "OrderedDict[str, dict]" = OrderedDict()
        self.stats = WholePlanCacheStats(capacity=self.maxsize)

    # -- per-key metrics ----------------------------------------------------
    @staticmethod
    def key_digest(key: tuple) -> str:
        """Short stable-within-process label for one structural key."""
        return format(hash(key) & 0xFFFFFFFFFFFF, "012x")

    def _key_record(self, key: tuple) -> dict:
        # caller holds the lock
        digest = self.key_digest(key)
        rec = self._key_stats.get(digest)
        if rec is None:
            rec = {"key": digest, "hits": 0, "misses": 0, "evictions": 0,
                   "build_time_s": 0.0}
            self._key_stats[digest] = rec
            while len(self._key_stats) > KEY_STATS_CAPACITY:
                self._key_stats.popitem(last=False)
                self.stats.dropped_keys += 1
        else:
            self._key_stats.move_to_end(digest)
        return rec

    def key_stats(self, top: Optional[int] = None) -> list[dict]:
        """Per-key counter records, most recently touched last; records
        outlive their cache entries (eviction is itself a counter)."""
        with self._lock:
            recs = [dict(r) for r in self._key_stats.values()]
        if top is not None:
            recs = recs[-top:]
        return recs

    # -- LRU ----------------------------------------------------------------
    def resize(self, maxsize: int) -> None:
        """Change the LRU capacity, evicting past the new bound now."""
        with self._lock:
            self.maxsize = int(maxsize)
            self.stats.capacity = self.maxsize
            self._evict_over_capacity()
            self.stats.size = len(self._fns)

    def _evict_over_capacity(self) -> None:
        # caller holds the lock
        while len(self._fns) > self.maxsize:
            old_key, _ = self._fns.popitem(last=False)
            self.stats.evictions += 1
            self._key_record(old_key)["evictions"] += 1

    def get(self, key: tuple) -> Optional[Callable]:
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self._fns.move_to_end(key)
                self.stats.hits += 1
                self._key_record(key)["hits"] += 1
            return fn

    def put(self, key: tuple, fn: Callable, build_s: float) -> None:
        with self._lock:
            self._fns[key] = fn
            self._evict_over_capacity()
            self.stats.misses += 1
            self.stats.size = len(self._fns)
            self.stats.build_time_s += build_s
            rec = self._key_record(key)
            rec["misses"] += 1
            rec["build_time_s"] += build_s

    def get_or_create(self, key: tuple, builder: Callable[[], Callable],
                      extra_build_s: float = 0.0) -> Callable:
        """Hit, or build exactly once under concurrency: the first thread
        to miss a key runs ``builder`` (outside the lock) while racing
        threads block on an in-flight event and then share the built
        function.  ``extra_build_s`` lets the caller account lowering
        work done before the key existed (e.g. tracing the plan body)."""
        while True:
            with self._lock:
                fn = self._fns.get(key)
                if fn is not None:
                    self._fns.move_to_end(key)
                    self.stats.hits += 1
                    self._key_record(key)["hits"] += 1
                    return fn
                ev = self._pending.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._pending[key] = ev
                    break                      # we own the build
            ev.wait()                          # another thread is building
        t0 = time.perf_counter()
        try:
            fn = builder()
            self.put(key, fn, time.perf_counter() - t0 + extra_build_s)
            return fn
        finally:
            with self._lock:
                self._pending.pop(key, None)
            ev.set()

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()
            self._key_stats.clear()
            self.stats = WholePlanCacheStats(capacity=self.maxsize)


WHOLE_PLAN_CACHE = WholePlanCache()


def whole_plan_cache_stats() -> WholePlanCacheStats:
    """Snapshot of the whole-plan cache counters (public API): ``hits``
    (a structurally-equal ExecPlan reused an existing staged function —
    and with it the XLA executable), ``misses`` (staged functions built,
    concurrent builders coalesced to one build per key), ``size``,
    ``capacity`` (the configurable LRU bound), ``evictions``,
    ``build_time_s`` (cumulative staged-lowering time on misses), and
    ``tracked_keys``/``dropped_keys`` (per-key stat records alive /
    aged out — see :meth:`WholePlanCache.key_stats`)."""
    with WHOLE_PLAN_CACHE._lock:
        return replace(WHOLE_PLAN_CACHE.stats,
                       size=len(WHOLE_PLAN_CACHE._fns),
                       capacity=WHOLE_PLAN_CACHE.maxsize,
                       tracked_keys=len(WHOLE_PLAN_CACHE._key_stats))


# --------------------------------------------------------------------------
# generated operators
# --------------------------------------------------------------------------

@dataclass
class GeneratedOp:
    """A fused operator: CPlan + execution dispatch (SystemML's SpoofOp).

    The program is interpreted at trace time under ``jax.jit`` — the jitted
    computation is the compiled generated operator (the janino-compile
    analogue); jax caches it per input shape/format signature.
    """
    cplan: CPlan
    _jits: dict = field(default_factory=dict)

    def _run(self, env: dict[int, object], pallas: str):
        # format routing (incl. BCSR+Outer → outer_pallas) lives in the
        # kops.execute dispatcher, shared with the staged path
        return kops.execute(self.cplan, env, pallas=pallas)

    def __call__(self, env: dict[int, object], pallas: str = "never"):
        if pallas == "interpret":
            return self._run(env, pallas)     # validation path: stay eager
        fn = self._jits.get(pallas)
        if fn is None:
            import jax
            fn = jax.jit(lambda e: self._run(e, pallas))
            self._jits[pallas] = fn
        return fn(env)


def _eval_basic(graph: Graph, node: Node, env: dict[int, object]):
    """Basic (unfused) operator, sparse-format aware."""
    ins = [env[i.nid] if i.op != "lit" else
           jnp.asarray(float(i.attrs["value"]), jnp.float32).reshape(1, 1)
           for i in node.inputs]
    # an input partitioned for a shard_map segment but also consumed
    # here re-assembles to its global block list (exact: zero padding)
    ins = [v.unshard() if isinstance(v, ShardedBCSR) else v for v in ins]
    if node.is_matmul and isinstance(ins[0], BCSR):
        b = ins[1]
        b = b.todense() if hasattr(b, "todense") else b
        b = b.T if node.tb else b
        # ta=True: transpose the block structure (BCSR.T is exact and
        # O(nnz)) instead of densifying the sparse operand.
        a = ins[0].T if node.ta else ins[0]
        return kops.bcsr_matmul(a, b)
    if node.op == "mul" and isinstance(ins[0], BCSR) \
            and not isinstance(ins[1], BCSR) \
            and getattr(ins[1], "shape", None) == ins[0].shape:
        return kops.bcsr_mul_dense(ins[0], ins[1])
    ins = [v.todense() if hasattr(v, "todense") else v for v in ins]
    return kref.eval_node(node.op, ins, node.attrs)


# --------------------------------------------------------------------------
# executable plans
# --------------------------------------------------------------------------

def _spec_roots(spec) -> tuple[int, ...]:
    return tuple(spec.roots) if isinstance(spec, MultiAggSpec) \
        else (spec.root,)


def _segment_items(graph: Graph, plan: ExecPlan, seg,
                   cache: PlanCache) -> list:
    """SegmentItems for one plan Segment — shared by the staged lowering
    and the static fallback report so the two can never drift."""
    from repro.kernels.distributed import SegmentItem
    specs = plan.specs
    output_ids = set(graph.output_ids)
    cons: dict[int, set[int]] = {}
    for j, s in enumerate(specs):
        for i in s.inputs:
            cons.setdefault(i, set()).add(j)
    seg_set = set(seg.indices)
    items = []
    for j in seg.indices:
        spec = specs[j]
        _op, cplan = cache.get_or_build(graph, spec)
        roots = _spec_roots(spec)
        export = any(r in output_ids or (cons.get(r, set()) - seg_set)
                     for r in roots)
        items.append(SegmentItem(cplan, spec.placement, roots, export))
    return items


@dataclass
class CompiledPlan:
    """Executable form of an ExecPlan.

    **Staged path (default).**  The entire plan — fused operators, basic
    ops, literals, multi-aggregate unpacking, and distributed segments —
    is traced into *one* function and jitted once, so a plan call is a
    single XLA dispatch: operator boundaries are XLA values instead of
    Python round-trips, literals are trace constants, and dead
    intermediates are released at their last use (``_last_uses``) so XLA
    reuses their buffers — the paper's 'fewer materialized intermediates'
    lifted from the operator level to the plan level.  Inputs are never
    donated: re-calling with the same arrays is always valid.  Staged
    functions are shared across structurally-equal plans via the
    :class:`WholePlanCache`.

    **Per-operator path** (``staged=False`` only — an explicit debug
    request, never an automatic downgrade): run specs in dependency
    order, one dispatch per fused operator, freeing intermediates when
    their last consumer has run — the pre-staging interpreter.

    When the plan was selected under a mesh layout, fused operators whose
    placement is ``"distributed"`` execute their generated body inside
    ``shard_map`` over the layout's real mesh with the template's
    collective epilogue (:mod:`repro.kernels.distributed`); the staged
    path lowers each plan :class:`~repro.core.select.Segment` — a run of
    adjacent distributed operators — into a *single* ``shard_map`` region
    whose row-sharded intermediates flow shard-to-shard and whose body
    runs the Pallas template kernels over shard-local shapes when
    ``pallas`` is enabled.  Row-sharded BCSR operands are block-row-
    partitioned outside ``jit`` (:class:`~repro.kernels.blocksparse.
    ShardedBCSR`) so sparse mains execute inside the region too.  Every
    downgrade to local execution is recorded in :attr:`fallbacks` with
    its reason — surfaced via ``explain()['execution']['fallbacks']``
    and raised under ``verify="strict"`` when a costed placement on a
    *real* mesh is abandoned.  One plan, hybrid execution."""
    plan: ExecPlan
    pallas: str = "never"
    cache: PlanCache = field(default_factory=lambda: PLAN_CACHE)
    #: FusionLayout the plan was selected under (None: local-only)
    layout: Optional[object] = None
    #: whole-plan staged execution (False: per-operator debug dispatch)
    staged: bool = True
    #: raise when a costed distributed placement is abandoned at
    #: execution time on a real mesh (FusionContext(verify="strict"))
    strict: bool = False
    #: per-(spec index, mesh) compiled shard_map callables for the per-op
    #: path (False: not realizable) — keyed by the mesh so a plan
    #: re-targeted at a different real mesh can't reuse a stale executable
    _dist_fns: dict = field(default_factory=dict, repr=False)
    #: literal (1, 1) arrays, built once per plan (per-op path)
    _lit_cache: Optional[dict] = field(default=None, repr=False)
    #: jitted whole-plan function + its un-jitted trace (introspection)
    _staged_fn: Optional[Callable] = field(default=None, repr=False)
    _staged_raw: Optional[Callable] = field(default=None, repr=False)
    #: structural whole-plan cache key of the staged lowering
    _staged_key: Optional[tuple] = field(default=None, repr=False)
    #: mesh-validated SegmentPlans of the staged lowering (real mesh)
    _seg_plans: list = field(default_factory=list, repr=False)
    #: recorded execution downgrades, deduped by (site, reason, specs)
    _fallbacks: dict = field(default_factory=dict, repr=False)
    #: BCSR partition memo: (nid, nparts, id(data)) -> (data, ShardedBCSR)
    _part_cache: dict = field(default_factory=dict, repr=False)

    # -- fallback observability --------------------------------------------

    def record_fallback(self, site: str, reason: str,
                        specs: Optional[tuple] = None,
                        hard: bool = False) -> None:
        """Log one execution downgrade (idempotent per site/reason/specs).
        ``hard`` marks a placement a *real* mesh could have executed —
        under ``strict`` that abandonment raises instead of downgrading."""
        key = (site, reason, specs)
        if key not in self._fallbacks:
            entry = {"site": site, "reason": reason}
            if specs is not None:
                entry["specs"] = list(specs)
            self._fallbacks[key] = entry
        if hard and self.strict:
            raise PlanInvariantError(
                f"verify=strict: costed distributed placement abandoned "
                f"at execution time ({site}): {reason}")

    @property
    def fallbacks(self) -> list:
        """Recorded execution downgrades (see ``explain()``)."""
        return list(self._fallbacks.values())

    # -- staged whole-plan path --------------------------------------------

    def staged_callable(self) -> tuple[Callable, Callable]:
        """(jitted whole-plan function, its un-jitted trace function),
        building them on first use.  Both take the graph's input arrays
        positionally (``graph.inputs()`` order) and return the tuple of
        graph outputs; the raw function is exposed so tests can inspect
        the plan's jaxpr (e.g. count ``shard_map`` regions)."""
        if self._staged_fn is None:
            self._staged_fn, self._staged_raw = self._build_staged()
        return self._staged_fn, self._staged_raw

    def _build_staged(self) -> tuple[Callable, Callable]:
        import jax
        from repro.kernels.distributed import (
            SegmentFallback, SegmentItem, lower_segment, plan_segment,
            run_segment_local)

        t0 = time.perf_counter()
        graph, plan = self.plan.graph, self.plan
        specs = plan.specs
        in_nids = tuple(n.nid for n in graph.inputs())
        lits = tuple((n.nid, float(n.attrs["value"]))
                     for n in graph.nodes if n.op == "lit")
        output_ids = tuple(o.nid for o in graph.outputs)
        mesh = _mesh_of(self.layout)
        real_mesh = _is_real_mesh(mesh)

        # canonical env tokens: whole-plan keys must capture the wiring,
        # not the node ids (structurally-equal plans from other traces
        # must hit)
        canon: dict[int, tuple] = {nid: ("in", p)
                                   for p, nid in enumerate(in_nids)}
        for nid, v in lits:
            canon[nid] = ("lit", v)

        steps: list[tuple] = []          # executable steps
        key_parts: list[tuple] = []      # structural key, one per step
        spec_step: dict[int, int] = {}   # spec idx -> step idx
        self._seg_plans = []

        def _token(roots: tuple[int, ...], step_idx: int,
                   item_idx: int = 0) -> None:
            # the item index distinguishes the members of one segment
            # step — without it two outputs of the same step would be
            # indistinguishable in the whole-plan key and a structurally
            # different consumer wiring could hit the wrong function
            for k, r in enumerate(roots):
                canon[r] = ("s", step_idx, item_idx, k)

        def _seg_key(items, sp):
            return ("seg", mesh,
                    tuple((it.cplan.cache_key(), it.placement.epilogue,
                           tuple(b.nid in it.placement.sharded
                                 for b in it.cplan.binds), it.export)
                          for it in items),
                    tuple(canon[nid] for nid in sp.ext))

        seg_start = {seg.indices[0]: seg for seg in plan.segments}
        idx = 0
        while idx < len(specs):
            seg = seg_start.get(idx)
            if seg is not None and mesh is not None:
                items = _segment_items(graph, plan, seg, self.cache)
                sp = plan_segment(items, mesh)
                if isinstance(sp, SegmentFallback):
                    # mesh can't realize the costed placement: record
                    # and let the members run as local fused steps
                    self.record_fallback("segment", sp.reason,
                                         specs=tuple(seg.indices),
                                         hard=real_mesh)
                else:
                    step_idx = len(steps)
                    steps.append(("seg", sp,
                                  tuple(it.roots for it in items
                                        if it.export)))
                    key_parts.append(_seg_key(items, sp))
                    self._seg_plans.append(sp)
                    for j in seg.indices:
                        spec_step[j] = step_idx
                    for item_idx, it in enumerate(items):
                        _token(it.roots, step_idx, item_idx)
                    idx = seg.indices[-1] + 1
                    continue
            spec = specs[idx]
            step_idx = len(steps)
            if isinstance(spec, MultiAggSpec) or (
                    isinstance(spec, FusedOpSpec) and spec.fused):
                _op, cplan = self.cache.get_or_build(graph, spec)
                roots = _spec_roots(spec)
                pl = getattr(spec, "placement", None)
                sp = None
                if pl is not None and pl.arm == "distributed" \
                        and mesh is not None:
                    items = [SegmentItem(cplan, pl, roots, True)]
                    sp = plan_segment(items, mesh)
                    if isinstance(sp, SegmentFallback):
                        self.record_fallback("operator", sp.reason,
                                             specs=(idx,), hard=real_mesh)
                        sp = None
                bind_nids = tuple(b.nid for b in cplan.binds)
                if sp is not None:
                    steps.append(("seg", sp, (roots,)))
                    key_parts.append(_seg_key(items, sp))
                    self._seg_plans.append(sp)
                else:
                    steps.append(("fused", cplan, bind_nids, roots))
                    key_parts.append((
                        "fused", cplan.cache_key(),
                        tuple(canon[nid] for nid in bind_nids)))
                _token(roots, step_idx)
            else:
                node = graph.by_id[spec.root]
                steps.append(("basic", node))
                key_parts.append((
                    "basic", node.op,
                    tuple(sorted(node.attrs.items())), node.shape,
                    tuple(canon[i.nid] if i.op != "lit"
                          else ("lit", float(i.attrs["value"]))
                          for i in node.inputs)))
                canon[spec.root] = ("s", step_idx, 0, 0)
            spec_step[idx] = step_idx
            idx += 1

        # dead intermediates, re-indexed from spec positions to steps
        free: dict[int, list[int]] = {}
        for sidx, dead in _last_uses(plan).items():
            step_idx = spec_step[sidx]
            keep = set(output_ids)
            free.setdefault(step_idx, []).extend(
                d for d in dead if d not in keep)

        pallas = self.pallas

        def _mat(v):
            # a value partitioned for a segment, consumed whole elsewhere
            return v.unshard() if isinstance(v, ShardedBCSR) else v

        def plan_fn(*arrays):
            env: dict[int, object] = dict(zip(in_nids, arrays))
            for nid, v in lits:         # trace-time constants
                env[nid] = jnp.full((1, 1), v, jnp.float32)
            for step_idx, step in enumerate(steps):
                kind = step[0]
                if kind == "seg":
                    _, sp, out_roots = step
                    vals = [env[nid] for nid in sp.ext]
                    # trace-time lowering: in_specs chosen from the
                    # actual value formats (jit retraces per pytree
                    # structure, so each format gets its own lowering)
                    lowered = lower_segment(sp, mesh, vals, pallas=pallas)
                    if isinstance(lowered, SegmentFallback):
                        # recorded by __call__'s preflight; numerically
                        # identical local execution (collectives exact)
                        outs = run_segment_local(sp, vals, pallas=pallas)
                    else:
                        outs = lowered(*vals)
                    for out, roots in zip(outs, out_roots):
                        if len(roots) > 1:
                            for k, r in enumerate(roots):
                                env[r] = out[k].reshape(1, 1)
                        else:
                            env[roots[0]] = out
                elif kind == "fused":
                    _, cplan, bind_nids, roots = step
                    out = kops.execute(
                        cplan, {nid: _mat(env[nid]) for nid in bind_nids},
                        pallas=pallas)
                    if len(roots) > 1:
                        for k, r in enumerate(roots):
                            env[r] = out[k].reshape(1, 1)
                    else:
                        env[roots[0]] = out
                else:
                    node = step[1]
                    env[node.nid] = _eval_basic(graph, node, env)
                for dead in free.get(step_idx, ()):
                    env.pop(dead, None)      # release: XLA reuses buffers
            return tuple(_mat(env[o]) for o in output_ids)

        key = (tuple(key_parts), tuple(canon[o] for o in output_ids),
               self.pallas, tuple(getattr(self.plan, "rewrite", ()) or ()))
        self._staged_key = key
        # build-once under concurrency: racing threads compiling
        # structurally-equal plans share one jitted function (and with
        # it one XLA executable per shape signature)
        def _build():
            faults.fault_point("plan.jit_build")
            return jax.jit(plan_fn)

        jitted = WHOLE_PLAN_CACHE.get_or_create(
            key, _build, extra_build_s=time.perf_counter() - t0)
        return jitted, plan_fn

    def batched_callable(self) -> Callable:
        """Jitted ``vmap`` of the staged whole-plan function over a new
        leading request axis — the executable the fused-plan server
        (:mod:`repro.serve.fusion`) dispatches one *batch* of
        same-structure requests through.  Takes each graph input stacked
        to ``(B, *shape)`` (``graph.inputs()`` order) and returns every
        output stacked the same way; batch elements are computed
        independently (vmap semantics), so the result equals B separate
        calls.  Mesh-free plans only: ``vmap`` over a ``shard_map``
        segment is not supported.  Shared across structurally-equal
        plans via the whole-plan cache (key ``("vmap", staged key)``)."""
        if _mesh_of(self.layout) is not None:
            raise PlanInvariantError(
                "batched_callable: batched (vmapped) execution requires "
                "a mesh-free plan; this plan was compiled under a layout")
        import jax
        _fn, raw = self.staged_callable()
        key = ("vmap", self._staged_key)

        def _build():
            faults.fault_point("plan.jit_build")
            return jax.jit(jax.vmap(raw))

        return WHOLE_PLAN_CACHE.get_or_create(key, _build)

    # -- per-operator fallback path ----------------------------------------

    def _dist_call(self, idx: int, spec, cplan, env: dict[int, object]):
        """Run one distributed-placed operator, or None to fall back —
        recording the downgrade reason (and raising under strict when a
        real mesh abandons its costed placement)."""
        pl = getattr(spec, "placement", None)
        if pl is None or pl.arm != "distributed" or self.layout is None:
            return None
        mesh = _mesh_of(self.layout)
        from repro.kernels.distributed import build_dist_fn
        vals = [env[b.nid] for b in cplan.binds]
        built, fb = build_dist_fn(cplan, mesh, pl, pallas=self.pallas,
                                  values=vals)
        if built is None:
            self.record_fallback("operator", fb.reason, specs=(idx,),
                                 hard=_is_real_mesh(mesh))
            return None
        fn, prepared = built
        return fn(*prepared)

    def _literals(self, graph: Graph) -> dict[int, object]:
        if self._lit_cache is None:
            self._lit_cache = {
                node.nid: jnp.full((1, 1), float(node.attrs["value"]),
                                   jnp.float32)
                for node in graph.nodes if node.op == "lit"}
        return self._lit_cache

    def _call_per_op(self, bindings: dict[str, object]):
        graph = self.plan.graph
        env: dict[int, object] = {}
        for node in graph.inputs():
            env[node.nid] = bindings[node.name]
        env.update(self._literals(graph))

        last_use = _last_uses(self.plan)
        for idx, spec in enumerate(self.plan.specs):
            if isinstance(spec, MultiAggSpec) or (
                    isinstance(spec, FusedOpSpec) and spec.fused):
                op, my_cplan = self.cache.get_or_build(graph, spec)
                out = self._dist_call(idx, spec, my_cplan, env)
                if out is None:
                    # positional re-binding: cached operator's nids ≠ ours
                    op_env = {ob.nid: env[mb.nid] for ob, mb in
                              zip(op.cplan.binds, my_cplan.binds)}
                    out = op(op_env, pallas=self.pallas)
                if isinstance(spec, MultiAggSpec):
                    for k, r in enumerate(spec.roots):
                        env[r] = out[k].reshape(1, 1)
                else:
                    env[spec.root] = out
            else:
                env[spec.root] = _eval_basic(graph, graph.by_id[spec.root],
                                             env)
            for dead in last_use.get(idx, ()):    # free intermediates
                if dead not in graph.output_ids:
                    env.pop(dead, None)
        outs = [env[o.nid] for o in graph.outputs]
        return outs[0] if len(outs) == 1 else tuple(outs)

    # -- sharded sparse input preparation -----------------------------------

    def _partition_memo(self, nid: int, v: BCSR, nparts: int):
        """Memoized block-row partition of a concrete BCSR input (O(nnz)
        host work — cached by data-array identity so steady-state calls
        with the same matrix pay it once)."""
        from repro.kernels.blocksparse import partition_block_rows
        key = (nid, nparts, id(v.data))
        hit = self._part_cache.get(key)
        if hit is not None and hit[0] is v.data:
            return hit[1]
        part = partition_block_rows(v, nparts)
        if part is not None:
            if len(self._part_cache) > 16:
                self._part_cache.clear()
            self._part_cache[key] = (v.data, part)
        return part

    def _prepare_inputs(self, vals: dict[int, object]) -> None:
        """Preflight for the staged call: block-row-partition graph-input
        BCSRs that a ``shard_map`` segment consumes row-sharded (must run
        outside ``jit`` — re-bucketing needs concrete indices), recording
        every operand that forces the segment to run locally instead."""
        for sp in self._seg_plans:
            sparse_noagg = {it.cplan.main.nid for it in sp.items
                            if it.export and it.cplan.variant == NO_AGG
                            and it.cplan.main.exploit}
            for nid in sp.ext:
                if not sp.ext_shard[nid] or nid not in vals:
                    continue
                v = vals[nid]
                if isinstance(v, BCSR):
                    if nid in sparse_noagg:
                        self.record_fallback(
                            "segment",
                            f"sparse no_agg output of operand %{nid} "
                            f"cannot cross the shard_map boundary",
                            hard=True)
                        continue
                    part = self._partition_memo(nid, v, sp.n)
                    if part is None:
                        self.record_fallback(
                            "segment",
                            f"sparse operand %{nid}: "
                            f"{v.shape[0] // v.bs} block rows not "
                            f"partitionable across {sp.n} shards",
                            hard=True)
                    else:
                        vals[nid] = part
                elif isinstance(v, DictCompressed):
                    self.record_fallback(
                        "segment",
                        f"row-sharded operand %{nid} is CLA-compressed: "
                        f"no distributed decompression path", hard=True)

    # -- entry point ---------------------------------------------------------

    def __call__(self, bindings: dict[str, object]):
        graph = self.plan.graph
        for node in graph.inputs():
            if node.name not in bindings:
                raise KeyError(f"missing binding for input '{node.name}'")
        if not self.staged:
            return self._call_per_op(bindings)
        fn, _raw = self.staged_callable()
        vals = {n.nid: bindings[n.name] for n in graph.inputs()}
        self._prepare_inputs(vals)
        outs = fn(*[vals[n.nid] for n in graph.inputs()])
        return outs[0] if len(outs) == 1 else tuple(outs)


def _last_uses(plan: ExecPlan) -> dict[int, list[int]]:
    last: dict[int, int] = {}
    for idx, spec in enumerate(plan.specs):
        for i in spec.inputs:
            last[i] = idx
    out: dict[int, list[int]] = {}
    for nid, idx in last.items():
        out.setdefault(idx, []).append(nid)
    return out


def staged_plan_key(plan: ExecPlan, pallas: str = "never",
                    cache: Optional[PlanCache] = None) -> tuple:
    """The structural whole-plan cache key of the local (mesh-free)
    staged lowering, computed without tracing or jitting anything —
    the replay the plan verifier's key-completeness check
    (:func:`repro.core.verify.verify_exec`, EXE004) runs: every value a
    step consumes must resolve to a canonical env token, so a
    ``KeyError`` here means the plan wires a value no step produces.

    Mirrors the mesh-free path of :meth:`CompiledPlan._build_staged`
    (same token scheme, same key layout) — keep the two in sync."""
    cache = cache if cache is not None else PLAN_CACHE
    graph = plan.graph
    in_nids = tuple(n.nid for n in graph.inputs())
    output_ids = tuple(o.nid for o in graph.outputs)
    canon: dict[int, tuple] = {nid: ("in", p)
                               for p, nid in enumerate(in_nids)}
    for n in graph.nodes:
        if n.op == "lit":
            canon[n.nid] = ("lit", float(n.attrs["value"]))

    key_parts: list[tuple] = []
    for spec in plan.specs:
        step_idx = len(key_parts)
        if isinstance(spec, MultiAggSpec) or (
                isinstance(spec, FusedOpSpec) and spec.fused):
            _op, cplan = cache.get_or_build(graph, spec)
            bind_nids = tuple(b.nid for b in cplan.binds)
            key_parts.append(("fused", cplan.cache_key(),
                              tuple(canon[nid] for nid in bind_nids)))
            for k, r in enumerate(_spec_roots(spec)):
                canon[r] = ("s", step_idx, 0, k)
        else:
            node = graph.by_id[spec.root]
            key_parts.append((
                "basic", node.op,
                tuple(sorted(node.attrs.items())), node.shape,
                tuple(canon[i.nid] if i.op != "lit"
                      else ("lit", float(i.attrs["value"]))
                      for i in node.inputs)))
            canon[spec.root] = ("s", step_idx, 0, 0)
    return (tuple(key_parts), tuple(canon[o] for o in output_ids), pallas,
            tuple(getattr(plan, "rewrite", ()) or ()))


def plan_fallbacks(plan: ExecPlan, layout=None, pallas: str = "never",
                   staged: bool = True,
                   cache: Optional[PlanCache] = None) -> list:
    """Statically derivable execution downgrades for this plan — the
    compile-time portion of ``explain()['execution']['fallbacks']``.

    Replays the same :func:`~repro.kernels.distributed.plan_segment`
    validation the staged lowering runs (via the shared
    :func:`_segment_items`), so the report can never drift from what
    execution does.  Value-format downgrades (a sparse operand whose
    block rows don't partition) depend on the bound arrays and are
    recorded at call time on :attr:`CompiledPlan.fallbacks`;
    ``Compiled.explain()`` merges both."""
    cache = cache if cache is not None else PLAN_CACHE
    out: list[dict] = []
    if not staged:
        out.append({"site": "plan",
                    "reason": "staged=False: per-operator debug "
                              "dispatch requested"})
    mesh = _mesh_of(layout)
    if mesh is None:
        return out
    from repro.kernels.distributed import (SegmentFallback, SegmentItem,
                                           plan_segment)
    graph = plan.graph
    seg_member = {j for seg in plan.segments for j in seg.indices}
    for seg in plan.segments:
        items = _segment_items(graph, plan, seg, cache)
        sp = plan_segment(items, mesh)
        if isinstance(sp, SegmentFallback):
            out.append({"site": "segment", "specs": list(seg.indices),
                        "reason": sp.reason})
    for idx, spec in enumerate(plan.specs):
        if idx in seg_member:
            continue
        pl = getattr(spec, "placement", None)
        if pl is None or pl.arm != "distributed":
            continue
        _op, cplan = cache.get_or_build(graph, spec)
        sp = plan_segment(
            [SegmentItem(cplan, pl, _spec_roots(spec), True)], mesh)
        if isinstance(sp, SegmentFallback):
            out.append({"site": "operator", "specs": [idx],
                        "reason": sp.reason})
    return out


def freed_intermediates(plan: ExecPlan) -> int:
    """Number of intermediate values the staged trace releases at their
    last use (graph outputs excepted) — the plan-level buffer-donation
    count ``explain()`` reports."""
    outs = set(plan.graph.output_ids)
    return sum(1 for dead in _last_uses(plan).values()
               for d in dead if d not in outs)


def compile_plan(plan: ExecPlan, pallas: str = "never",
                 layout=None, staged: bool = True,
                 strict: bool = False) -> CompiledPlan:
    """Bind an ExecPlan to its executable form.

    ``staged=True`` (default) compiles the whole plan into a single
    jitted computation (one dispatch per call, whole-plan cached) for
    every operand format and Pallas mode — BCSR mains and
    ``pallas="interpret"`` included; ``staged=False`` selects the
    per-operator interpreter dispatch, an explicit debug path.  Every
    execution downgrade is recorded on :attr:`CompiledPlan.fallbacks`;
    ``strict=True`` (``FusionContext(verify="strict")``) raises when a
    costed distributed placement on a real mesh is abandoned at
    execution time.  The per-template dispatch rules are tabulated in
    ``docs/architecture.md`` (kernel-dispatch decision table)."""
    return CompiledPlan(plan, pallas=pallas, layout=layout, staged=staged,
                        strict=strict)
