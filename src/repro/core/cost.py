"""Analytical cost model and plan resolution (paper §4.3, Eq. 4).

``C(P_i|q) = Σ_p ( T̂w_p + max(T̂r_p, T̂c_p) )`` over the basic/fused
operators p that assignment q induces: write time + overlapped read/compute
time, bandwidth-normalized.  Sparsity-exploiting operators scale compute by
the sparsity of the main (driver) input; sparse inputs are read at
nnz·(value+index) bytes; shared reads and CSEs are deduplicated via cost
vectors; operators reachable over multiple paths with materialized output
cost zero the second time, while *overlapping* fused operators pay their
redundant compute (fuse-all semantics).

The same walker that costs a plan also **extracts** it (`resolve_partition`
returns :class:`FusedOpSpec` lists), so the executed plan is by construction
the costed plan.

Cost constants default to the TPU v5e roofline (819 GB/s HBM, 197 TFLOP/s
bf16); the distributed variant prices reads of sharded side inputs at ICI
all-gather bandwidth — the paper's "different read bandwidths for inputs of
resulting distributed operations" (§4.4) mapped onto the mesh.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro import hw as _hw
from .ir import Graph, Node, sparse_safe_wrt
from .memo import MemoEntry, MemoTable
from .partitions import Partition, Point
from .templates import TType

# -- hardware constants (shared substrate: repro.hw, TPU v5e target) ---------

@dataclass(frozen=True)
class DistParams:
    """Row-partitioned execution geometry for the distributed cost arm.

    Derived from a :class:`~repro.core.layout.FusionLayout` by
    :func:`~repro.core.layout.layout_cost_params`: the mesh's data/FSDP
    axes become the row-shard group, and per graph-input shard factors are
    read off the layout's PartitionSpec trees (``row_factor``: dim-0,
    ``col_factor``: dim-1).  With this set, :func:`spec_cost` prices every
    fused operator as ``min(local arm, distributed arm)`` — the
    local × distributed template dimension of candidate selection.
    """

    axes: tuple[str, ...]          # row-shard mesh axes, mesh order
    n: int                         # total row-shard degree (Π axis sizes)
    ici_bw: float = _hw.TPU_V5E.ici_bw
    row_factor: dict = field(default_factory=dict)   # input nid → dim-0 shards
    col_factor: dict = field(default_factory=dict)   # input nid → dim-1 shards
    #: per-spec memo of :func:`_dist_arm` (one planning call shares one
    #: DistParams, so the cache dies with the plan)
    cache: dict = field(default_factory=dict, repr=False, compare=False)

    def signature(self) -> tuple:
        """Hashable identity (plan-cache / context keys)."""
        return (self.axes, self.n, self.ici_bw,
                tuple(sorted(self.row_factor.items())),
                tuple(sorted(self.col_factor.items())))


@dataclass(frozen=True)
class Placement:
    """The local-vs-distributed decision for one fused operator.

    ``arm`` is the selected execution arm; both arms' modeled costs are
    kept for ``explain()``.  For the distributed arm, ``epilogue`` names
    the collective that completes the template
    (:func:`repro.core.templates.dist_epilogue`), ``collective_bytes`` is
    the total per-device ring volume (epilogue all-reduce + side-input
    all-gathers), and ``sharded`` lists the bound input nids each device
    reads as a row shard."""

    arm: str                       # "local" | "distributed"
    cost: float                    # cost of the selected arm
    local_cost: float
    dist_cost: float               # inf when no distributed variant applies
    epilogue: Optional[str] = None  # none | psum | pmin | pmax
    axes: tuple = ()
    n: int = 1
    collective_bytes: float = 0.0
    gather_bytes: float = 0.0      # side-input all-gather share of the above
    sharded: frozenset = frozenset()


@dataclass
class CostParams:
    read_bw: float = _hw.TPU_V5E.hbm_bw      # HBM read, B/s
    write_bw: float = _hw.TPU_V5E.hbm_bw     # HBM write, B/s
    compute_bw: float = _hw.TPU_V5E.peak_flops   # peak FLOP/s (bf16 MXU)
    dtype_bytes: int = 4
    sparse_idx_bytes: int = 4
    #: per-input read-bandwidth override (nid -> B/s): distributed side
    #: inputs crossing shards are read at collective bandwidth.
    input_read_bw: dict[int, float] = field(default_factory=dict)
    #: hard constraint checker: (spec) -> bool valid; invalid => inf cost.
    max_fused_inputs: int = 12      # VMEM-budget style constraint
    #: row-shard geometry enabling the distributed cost arm (None: local
    #: only — the pre-layout behavior).
    dist: Optional[DistParams] = None

    def in_bw(self, nid: int) -> float:
        return self.input_read_bw.get(nid, self.read_bw)


TPU_V5E = CostParams()

#: flop weight per output cell for cell-wise ops (transcendentals are
#: many-flop on the VPU; same spirit as SystemML's per-op costs).
_EXPENSIVE = {"exp": 16, "log": 16, "sigmoid": 20, "tanh": 20, "gelu": 24,
              "silu": 20, "softplus": 20, "pow": 16, "sqrt": 4, "div": 4,
              "recip": 4, "log1p": 16}


def node_flops(node: Node) -> float:
    if node.is_input or node.op in ("t", "idx"):
        return 0.0
    if node.is_matmul:
        m, k, n = node.mm_dims()
        return 2.0 * m * k * n
    if node.is_agg:
        return float(node.inputs[0].ncells)
    w = _EXPENSIVE.get(node.op, 1)
    return float(node.ncells) * w


def node_bytes(node: Node, params: CostParams) -> float:
    """Storage footprint (sparse-aware)."""
    if node.sparsity < 1.0:
        return node.ncells * node.sparsity * (params.dtype_bytes
                                              + params.sparse_idx_bytes)
    return float(node.ncells) * params.dtype_bytes


# -- plan specs ---------------------------------------------------------------

@dataclass
class FusedOpSpec:
    """One operator of the induced runtime plan: a fused operator (ttype
    set) or a basic operator (ttype None).  ``cover`` maps covered node id →
    chosen memo entry (root first)."""
    root: int
    ttype: Optional[TType]
    cover: dict[int, Optional[MemoEntry]]
    inputs: list[int]                     # distinct, order of discovery
    driver: Optional[int] = None          # sparse-exploitation driver input
    #: local/distributed decision (set by selection when planning under a
    #: mesh layout; None ≡ local).
    placement: Optional["Placement"] = None

    @property
    def fused(self) -> bool:
        return self.ttype is not None and len(self.cover) > 1


def _spec_flops(graph: Graph, spec: FusedOpSpec) -> float:
    """Covered-node FLOPs, sparse-driver scaled (shared by both arms)."""
    flops = 0.0
    for nid in spec.cover:
        n = graph.by_id[nid]
        f = node_flops(n)
        if n.is_matmul and spec.ttype is None:
            # basic matmul exploits sparse left input (SystemML dispatches
            # to sparse kernels)
            f *= max(graph.by_id[n.inputs[0].nid].sparsity, 1e-12)
        flops += f
    if spec.driver is not None:
        flops *= max(graph.by_id[spec.driver].sparsity, 1e-12)
    return flops


def _boundary_gather(graph: Graph, spec: FusedOpSpec, params: CostParams,
                     interior: Optional[dict]) -> float:
    """Ring all-gather volume (bytes) a *segment boundary* costs: every
    input that an upstream operator produces row-partitioned
    (``interior[nid]`` — a distributed operator with a ``"none"``
    epilogue) must be gathered across the row group before a consumer
    that does not read it as a row shard can run.  Intra-segment edges —
    a distributed consumer reading the value sharded — never pay this;
    that asymmetry is what makes selection prefer longer distributed
    chains."""
    if not interior or params.dist is None:
        return 0.0
    n = params.dist.n
    return sum(_hw.all_gather_bytes(node_bytes(graph.by_id[i], params), n)
               for i in spec.inputs if interior.get(i))


def _local_spec_cost(graph: Graph, spec: FusedOpSpec, params: CostParams,
                     interior: Optional[dict] = None) -> float:
    """The paper's Eq. 4 single-device operator cost (the local arm).

    ``interior`` maps node id → "produced row-partitioned by an upstream
    distributed operator"; reading such an intermediate locally first
    re-assembles it (ring all-gather at ICI bandwidth) — the re-scatter
    side of a distributed-segment boundary."""
    if len(spec.inputs) > params.max_fused_inputs and spec.fused:
        return math.inf                    # constraint violation (paper Z)
    root = graph.by_id[spec.root]
    t_r = 0.0
    for i in spec.inputs:
        n = graph.by_id[i]
        t_r += node_bytes(n, params) / params.in_bw(i)
    t_w = node_bytes(root, params) / params.write_bw
    t_c = _spec_flops(graph, spec) / params.compute_bw
    cost = t_w + max(t_r, t_c)
    gather = _boundary_gather(graph, spec, params, interior)
    if gather:
        cost += gather / params.dist.ici_bw
    return cost


def spec_cost(graph: Graph, spec: FusedOpSpec, params: CostParams,
              interior: Optional[dict] = None) -> float:
    """Operator cost under ``params``.

    Without distributed geometry this is the local Eq. 4 cost.  When
    ``params.dist`` is set (planning under a mesh layout), every fused
    operator is priced on *both* execution arms and the cheaper one wins —
    candidate selection thereby enumerates ``local × distributed`` as an
    extra per-partition template dimension, and the induced plan is hybrid
    whenever that is what the cost model prefers.

    ``interior`` (nid → upstream operator produces the value
    row-partitioned) makes the pricing *chain-aware*: a distributed
    consumer reads such intermediates as free-flowing row shards (and is
    anchored by them), while a local consumer pays the boundary
    all-gather — so the model stops charging the epilogue gather +
    re-scatter on intra-segment edges and selection extends distributed
    runs instead of bouncing back to local after every operator."""
    local = _local_spec_cost(graph, spec, params, interior)
    if params.dist is None or not getattr(spec, "fused", False) \
            or not math.isfinite(local):
        return local
    arm = _dist_arm(graph, spec, params, interior)
    return local if arm is None else min(local, arm[0])


def spec_placement(graph: Graph, spec: FusedOpSpec, params: CostParams,
                   interior: Optional[dict] = None) -> Placement:
    """Resolve the local/distributed decision for one fused operator (the
    argmin :func:`spec_cost` takes, with both arms' evidence retained)."""
    local = _local_spec_cost(graph, spec, params, interior)
    arm = _dist_arm(graph, spec, params, interior) \
        if math.isfinite(local) else None
    if arm is None:
        return Placement("local", local, local, math.inf)
    cost, epil, coll, gather, sharded, axes, n = arm
    if cost < local:
        return Placement("distributed", cost, local, cost, epil, axes, n,
                         coll, gather, sharded)
    return Placement("local", local, local, cost, epil, axes, n)


def _iter_rows(graph: Graph, spec: FusedOpSpec, variant: str,
               prog_root: int) -> int:
    """Rows of the template's iteration domain — the dimension the
    distributed variant shards.  Aggregating variants (including the
    closing-matmul ones, whose contraction runs over the chain rows)
    iterate the chain at ``prog_root``; no_agg/right_mm iterate the
    output rows."""
    if variant in ("full_agg", "row_agg", "col_agg", "col_t_agg",
                   "left_mm"):
        return graph.by_id[prog_root].shape[0]
    return graph.by_id[spec.root].shape[0]


def _shardable(graph: Graph, spec: FusedOpSpec, i: int, rows: int) -> bool:
    """May input ``i`` arrive as a row shard of the iteration domain?

    Shape equality with the iteration rows is necessary but *not*
    sufficient — the template must also bind the input per-row.  A
    covered matmul consuming ``i`` as its **right** operand contracts
    (or, transposed, emits) over ``i``'s rows, so the full operand is
    needed regardless of its shape (a square main would otherwise
    misclassify, e.g. ``w`` in ``(X @ w)`` with m == n).  A **left**
    operand is row-bound — except a transposed interior read, which only
    the reduce epilogue of a closing ``t(X) @ chain`` / ``left_mm`` root
    makes exact."""
    node = graph.by_id[i]
    if node.is_scalar or node.shape[0] != rows:
        return False
    for nid in spec.cover:
        c = graph.by_id[nid]
        if not c.is_matmul:
            continue
        a, b = c.inputs
        if b.nid == i:
            return False
        if a.nid == i and c.ta and nid != spec.root:
            return False
    return True


_MISS = object()


def _dist_arm(graph: Graph, spec: FusedOpSpec, params: CostParams,
              interior: Optional[dict] = None):
    """Cost the distributed variant of ``spec``, or None when no such
    variant exists (template/variant not in the registry, rows don't
    divide the shard group, or no operand actually arrives row-sharded).

    Returns (cost, epilogue, collective_bytes, gather_bytes, sharded
    nids, axes, n).  Reads and compute scale 1/n over the row shards;
    broadcast side inputs are read in full, and layout-sharded ones add
    ring all-gather volume; a "reduce" epilogue adds the ring all-reduce
    of the (partial) output — all at ICI bandwidth (``repro.hw``).

    ``interior`` marks inputs an upstream distributed operator already
    produces row-partitioned: they anchor the operator (no layout shard
    factor needed) and flow shard-to-shard for free, while consuming one
    as a *broadcast* side input costs the boundary all-gather.

    Memoized per (spec identity, interior inputs) on ``params.dist``
    (one planning call shares one DistParams): MPSkipEnum re-costs the
    same induced operators exponentially often, and the variant
    derivation walks the cover — pure arithmetic must stay pure
    arithmetic in that loop."""
    dp = params.dist
    if dp is None or dp.n <= 1 or spec.ttype is None:
        return None
    interior = interior or {}
    key = (id(graph), spec.root, spec.ttype, frozenset(spec.cover),
           tuple(spec.inputs), spec.driver,
           tuple(sorted(i for i in spec.inputs if interior.get(i))))
    hit = dp.cache.get(key, _MISS)
    if hit is not _MISS:
        return hit
    dp.cache[key] = out = _dist_arm_uncached(graph, spec, params, dp,
                                             interior)
    return out


def _dist_arm_uncached(graph: Graph, spec: FusedOpSpec, params: CostParams,
                       dp: DistParams, interior: dict):
    from .templates import dist_epilogue
    from .cplan import _variant_of     # runtime import: cplan imports us

    root = graph.by_id[spec.root]
    variant, agg_op, prog_root, _close = _variant_of(
        graph, spec.ttype, root, set(spec.cover))
    epil = dist_epilogue(spec.ttype, variant, agg_op)
    if epil is None:
        return None
    rows = _iter_rows(graph, spec, variant, prog_root)
    n = dp.n
    if rows < n or rows % n:
        return None

    sharded: set[int] = set()
    anchored = False            # ≥1 operand is layout-sharded over rows
    t_r = 0.0
    gather = 0.0
    for i in spec.inputs:
        node = graph.by_id[i]
        b = node_bytes(node, params)
        r = dp.row_factor.get(i, 1)
        c = dp.col_factor.get(i, 1)
        if _shardable(graph, spec, i, rows):
            # row-bound: each device reads only its row slice — either a
            # layout shard or an upstream operator's row-partitioned
            # output flowing shard-to-shard (no collective on that edge)
            sharded.add(i)
            anchored = anchored or r == n or bool(interior.get(i))
            t_r += b / n / params.read_bw
            if c > 1:           # column shards gathered within the row group
                gather += _hw.all_gather_bytes(b / n, c)
        else:
            # broadcast side input: full read, all-gathered if sharded
            t_r += b / params.read_bw
            if r * c > 1:
                gather += _hw.all_gather_bytes(b, r * c)
            elif interior.get(i):
                # upstream row-partitioned intermediate consumed whole:
                # the segment boundary's re-assembly gather
                gather += _hw.all_gather_bytes(b, n)
    if not anchored:
        return None
    t_c = _spec_flops(graph, spec) / n / params.compute_bw
    out_b = node_bytes(root, params)
    coll = gather
    if epil == "none":
        t_w = out_b / n / params.write_bw      # row-partitioned write
    else:
        t_w = out_b / params.write_bw          # replicated reduced output
        coll += _hw.all_reduce_bytes(out_b, n)
    cost = t_w + max(t_r, t_c) + coll / dp.ici_bw
    return cost, epil, coll, gather, frozenset(sharded), dp.axes, n


# -- sparse driver detection ---------------------------------------------------

SPARSE_EXPLOIT_MAX = 0.7   # exploit sparsity in costs below this density


def find_driver(graph: Graph, root: Node, cover: dict[int, object],
                inputs: list[int], ttype: Optional[TType]) -> Optional[int]:
    """Main-input sparse driver of a fused operator, if any: an input matrix
    w.r.t. which the fused chain is sparse-safe (evaluating only at its
    non-zeros is exact)."""
    if ttype is None or ttype == TType.ROW:
        # Row binds whole (possibly sparse) rows; it gets no per-cell
        # asymptotic win — this is exactly why an overlapping Row plan
        # "destroys" a sparse-safe Outer plan (paper §5.4 ALS-CG).
        return None
    # expression whose per-cell values must vanish where the driver is 0
    expr = root
    if root.is_agg:
        if root.op not in ("sum", "sum_sq"):
            return None
        expr = root.inputs[0]
    elif root.is_matmul:
        a, b = root.inputs
        expr = b if root.ta else a

    best: Optional[int] = None
    best_sp = SPARSE_EXPLOIT_MAX if ttype != TType.OUTER else 1.0 + 1e-9
    for i in inputs:
        n = graph.by_id[i]
        if n.is_scalar or n.is_vector:
            continue
        if ttype == TType.OUTER and n.shape != expr.shape:
            continue
        if n.sparsity < best_sp and sparse_safe_wrt(expr, n):
            best, best_sp = i, n.sparsity
    return best


# -- plan resolution (the GETPLANCOST walker, also used for extraction) --------

#: cost-tie preference between template types at a plan root: multi-
#: aggregates enable cross-operator sharing, Outer enables sparsity.
_TIE_PREF = {TType.MAGG: 0, TType.OUTER: 1, TType.CELL: 2, TType.ROW: 3}


def _build_spec(graph: Graph, memo: MemoTable, nid: int,
                entry: Optional[MemoEntry],
                banned: set[Point]) -> FusedOpSpec:
    """Expand a root memo entry into the fused-operator spec it induces
    (interior continuations picked by max fusion references, the paper's
    "best plan regarding template type and fusion references")."""
    node = graph.by_id[nid]
    if entry is None or entry.n_refs == 0:
        return FusedOpSpec(nid, None, {nid: None},
                           [i.nid for i in node.inputs])
    cover: dict[int, Optional[MemoEntry]] = {}
    inputs: list[int] = []
    in_seen: set[int] = set()

    def walk(wid: int, e: MemoEntry) -> None:
        if wid in cover:
            return
        cover[wid] = e
        wnode = graph.by_id[wid]
        for j, inp in enumerate(wnode.inputs):
            fused = e.refs[j] >= 0 and (wid, inp.nid) not in banned
            e_in = None
            if fused:
                e_in = memo.best_compatible(inp.nid, entry.ttype, banned)
                fused = e_in is not None
            if fused:
                walk(inp.nid, e_in)              # type: ignore[arg-type]
            elif inp.nid not in in_seen:
                in_seen.add(inp.nid)
                inputs.append(inp.nid)

    walk(nid, entry)
    drv = find_driver(graph, node, cover, inputs, entry.ttype)
    return FusedOpSpec(nid, entry.ttype, cover, inputs, drv)


def resolve_partition(graph: Graph, memo: MemoTable, part: Partition,
                      banned: set[Point], params: CostParams = TPU_V5E,
                      probe: str = "cost") -> list[FusedOpSpec]:
    """Induce the runtime plan of partition ``part`` under assignment
    ``banned``.

    ``probe="cost"`` (Gen): per materialized node the root plan is chosen
    by a memoized cost DP over candidate memo entries (fused alternatives
    plus the basic operator), including the cost of the materialized
    subgraphs each alternative leaves behind.

    ``probe="greedy"`` (the fuse-all / fuse-no-redundancy heuristics):
    always take the maximal-fusion entry — this is what lets an
    overlapping Row plan destroy a sparse-safe Outer plan (paper §5.4).

    Under distributed geometry the DP is *chain-aware*: materialized
    inputs are resolved bottom-up first, and a child whose chosen plan is
    a distributed operator with a row-partitioned output marks its node
    ``interior`` — the parent's cost then sees the value as a free
    shard-to-shard edge on the distributed arm and as a boundary
    all-gather on the local arm (see :func:`spec_cost`).

    Returns one spec per materialized operator in dependency order."""
    choice: dict[int, FusedOpSpec] = {}
    subcost: dict[int, float] = {}
    interior: dict[int, bool] = {}

    def best(nid: int) -> float:
        """Memoized cost of materializing nid (and everything below it)."""
        if nid in subcost:
            return subcost[nid]
        node = graph.by_id[nid]
        if node.is_input:
            subcost[nid] = 0.0
            return 0.0
        subcost[nid] = 0.0          # cycle guard (DAG: unreachable)
        cands: list[Optional[MemoEntry]]
        if nid not in part.nodes:
            cands = [None]
        elif probe == "greedy":
            cands = [memo.best_compatible(nid, None, banned)]
        else:
            cands = [None] + [
                e for e in memo.entries(nid) if e.can_root
                and not any((nid, r) in banned for r in e.ref_ids())]
        best_c, best_s = math.inf, None
        for e in cands:
            spec = _build_spec(graph, memo, nid, e, banned)
            child = sum(best(i) for i in spec.inputs)
            c = spec_cost(graph, spec, params, interior) + child
            pref = _TIE_PREF.get(spec.ttype, 9) if spec.ttype else 9
            if c < best_c * (1 - 1e-12) or (
                    best_s is not None and abs(c - best_c) <= best_c * 1e-9
                    and pref < (_TIE_PREF.get(best_s.ttype, 9)
                                if best_s.ttype else 9)):
                best_c, best_s = c, spec
        choice[nid] = best_s            # type: ignore[assignment]
        subcost[nid] = best_c
        if params.dist is not None and best_s is not None \
                and getattr(best_s, "fused", False):
            interior[nid] = row_partitioned(
                spec_placement(graph, best_s, params, interior))
        return best_c

    # commit: walk the chosen DAG from roots/exits, emit specs once each
    specs: list[FusedOpSpec] = []
    emitted: set[int] = set()

    def emit(nid: int) -> None:
        node = graph.by_id[nid]
        if nid in emitted or node.is_input:
            return
        emitted.add(nid)
        if nid not in part.nodes:
            return                       # planned elsewhere (other partition
                                         # or basic fill-in by select())
        best(nid)
        spec = choice[nid]
        for i in spec.inputs:
            emit(i)
        specs.append(spec)

    for r in sorted(set(part.roots) | part.exits):
        emit(r)
    return specs


def row_partitioned(pl: Optional[Placement]) -> bool:
    """Does this placement produce its output as row shards (the value an
    intra-segment consumer may read shard-to-shard)?  The single source
    of the rule for the selection DP, :func:`update_interior`, and the
    post-selection placement walk."""
    return pl is not None and pl.arm == "distributed" \
        and pl.epilogue == "none"


def update_interior(graph: Graph, spec, params: CostParams,
                    interior: dict) -> None:
    """Record whether ``spec``'s output is produced row-partitioned
    (distributed arm, ``"none"`` epilogue) — the walker state both the
    selection DP and the post-selection placement pass thread through
    :func:`spec_cost` in dependency order."""
    if params.dist is None or not getattr(spec, "fused", False):
        return
    pl = spec_placement(graph, spec, params, interior)
    interior[spec.root] = row_partitioned(pl)


def partition_cost(graph: Graph, memo: MemoTable, part: Partition,
                   banned: set[Point], params: CostParams,
                   ub: float = math.inf) -> float:
    """GETPLANCOST with early abort once the partial cost exceeds ub.
    Walks the induced specs in dependency order so chain-aware
    distributed pricing sees the same interior-producer state the DP in
    :func:`resolve_partition` used."""
    total = 0.0
    interior: dict[int, bool] = {}
    for spec in resolve_partition(graph, memo, part, banned, params):
        total += spec_cost(graph, spec, params, interior)
        update_interior(graph, spec, params, interior)
        if total >= ub:
            return math.inf
    return total


# -- lower bounds for cost-based pruning (paper §4.4) ---------------------------

def static_lower_bound(graph: Graph, memo: MemoTable, part: Partition,
                       params: CostParams) -> float:
    """C̲_{P_i}: read partition inputs once + minimal (sparsity-exploited)
    compute + write partition roots/exits — a true lower bound of any plan.

    Under distributed geometry every operator may run row-partitioned —
    reads, compute, and writes all scale 1/n — so the bound divides by
    the shard degree to stay a *valid* lower bound of the distributed
    arm (otherwise cost-based pruning would discard exactly the
    materialization assignments that enable long distributed chains)."""
    t_r = sum(node_bytes(graph.by_id[i], params) / params.in_bw(i)
              for i in part.inputs)
    sp_min = min((graph.by_id[i].sparsity for i in part.inputs
                  if not graph.by_id[i].is_scalar), default=1.0)
    t_c = sum(node_flops(graph.by_id[n]) for n in part.nodes) \
        * max(sp_min, 1e-12) / params.compute_bw
    t_w = sum(node_bytes(graph.by_id[r], params) / params.write_bw
              for r in set(part.roots) | part.exits)
    bound = max(t_r, t_c) + t_w
    if params.dist is not None and params.dist.n > 1:
        bound /= params.dist.n
    return bound


def mp_cost(graph: Graph, banned: set[Point], params: CostParams,
            written_anyway: frozenset[int] = frozenset()) -> float:
    """GETMPCOST: each distinct materialization target forced by q costs at
    least one write plus one read.  Targets in ``written_anyway`` (partition
    roots/exits, whose write is already in the static bound) only add the
    read — otherwise the bound would overestimate and mis-prune.  Like
    :func:`static_lower_bound`, the distributed arm may write and re-read
    a materialization target row-partitioned (1/n per device), so the
    bound scales by the shard degree."""
    targets = {t for (_, t) in banned}
    total = 0.0
    for t in targets:
        b = node_bytes(graph.by_id[t], params)
        total += b / params.read_bw
        if t not in written_anyway:
            total += b / params.write_bw
    if params.dist is not None and params.dist.n > 1:
        total /= params.dist.n
    return total
