"""Public fusion API.

``@fused`` traces a python function of :class:`Expr` arguments into a LinOp
graph at first call (per shape/sparsity/mode signature), runs the
three-phase optimizer (explore → select → codegen) and executes the
generated plan.  Works under ``jax.jit`` — planning happens at trace time
with static shapes (the analogue of SystemML's dynamic recompilation with
known sizes), and compiled operators are memoized in the plan cache.

    @fused
    def hinge(X, w, y):
        return ir.relu(1 - y * (X @ w)).unary("pow2").sum()

    loss = hinge(Xarr, warr, yarr)                 # planned + fused
    with fusion_mode("fnr"): loss = hinge(...)     # heuristic arm
"""

from __future__ import annotations

import contextlib
import inspect
import threading
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.blocksparse import BCSR, DictCompressed
from . import ir
from .codegen import CompiledPlan, PLAN_CACHE, compile_plan
from .cost import CostParams, TPU_V5E
from .select import ExecPlan, plan as plan_graph


@dataclass
class FusionConfig:
    mode: str = "gen"            # gen | fa | fnr | none
    pallas: str = "never"        # never | interpret | tpu
    params: CostParams = field(default_factory=lambda: TPU_V5E)


_STATE = threading.local()


def current_config() -> FusionConfig:
    cfg = getattr(_STATE, "cfg", None)
    if cfg is None:
        cfg = FusionConfig()
        _STATE.cfg = cfg
    return cfg


@contextlib.contextmanager
def fusion_mode(mode: Optional[str] = None, pallas: Optional[str] = None,
                params: Optional[CostParams] = None):
    old = current_config()
    new = replace(old)
    if mode is not None:
        new.mode = mode
    if pallas is not None:
        new.pallas = pallas
    if params is not None:
        new.params = params
    _STATE.cfg = new
    try:
        yield new
    finally:
        _STATE.cfg = old


# --------------------------------------------------------------------------

def _signature(args: dict[str, object], cfg: FusionConfig):
    sig = [cfg.mode, cfg.pallas]
    for name, v in args.items():
        if isinstance(v, BCSR):
            sig.append((name, "bcsr", v.shape, v.bs, round(v.block_sparsity, 4)))
        elif isinstance(v, DictCompressed):
            sig.append((name, "dict", v.shape))
        else:
            sig.append((name, "dense", tuple(v.shape)))
    return tuple(sig)


def _as_expr_inputs(args: dict[str, object],
                    sparsity: dict[str, float]) -> dict[str, ir.Expr]:
    out = {}
    for name, v in args.items():
        if isinstance(v, BCSR):
            sp = sparsity.get(name, v.block_sparsity)
            out[name] = ir.matrix(name, v.shape, sparsity=sp)
        elif isinstance(v, DictCompressed):
            out[name] = ir.matrix(name, v.shape,
                                  sparsity=sparsity.get(name, 1.0))
        else:
            shape = tuple(v.shape)
            assert len(shape) == 2, f"{name}: expected 2-D, got {shape}"
            out[name] = ir.matrix(name, shape,
                                  sparsity=sparsity.get(name, 1.0))
    return out


class Fused:
    """Callable wrapper planning+executing a traced expression function."""

    def __init__(self, fn: Callable, sparsity: Optional[dict] = None):
        self.fn = fn
        self.sparsity = dict(sparsity or {})
        self.names = list(inspect.signature(fn).parameters)
        self._plans: dict[tuple, tuple[ExecPlan, CompiledPlan]] = {}

    def plan_for(self, **shaped_args) -> ExecPlan:
        cfg = current_config()
        exprs = _as_expr_inputs(shaped_args, self.sparsity)
        outs = self.fn(**exprs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        graph = ir.Graph.build(list(outs))
        return plan_graph(graph, cfg.mode, cfg.params)

    def __call__(self, *args, **kwargs):
        cfg = current_config()
        bound = dict(zip(self.names, args))
        bound.update(kwargs)
        key = _signature(bound, cfg)
        entry = self._plans.get(key)
        if entry is None:
            eplan = self.plan_for(**bound)
            compiled = compile_plan(eplan, pallas=cfg.pallas)
            self._plans[key] = (eplan, compiled)
        else:
            eplan, compiled = entry
        return compiled(bound)


def fused(fn: Optional[Callable] = None, *, sparsity: Optional[dict] = None):
    if fn is None:
        return lambda f: Fused(f, sparsity=sparsity)
    return Fused(fn, sparsity=sparsity)


def fuse_exprs(outputs, bindings: dict[str, object],
               mode: Optional[str] = None):
    """One-shot: plan + execute a hand-built expression DAG."""
    cfg = current_config()
    graph = ir.Graph.build(outputs if isinstance(outputs, (list, tuple))
                           else [outputs])
    eplan = plan_graph(graph, mode or cfg.mode, cfg.params)
    return compile_plan(eplan, pallas=cfg.pallas)(bindings)
