"""Public fusion API: the staged ``trace → plan → compile`` pipeline.

The paper's three optimizer phases (candidate exploration, cost-based
selection, code generation) are exposed as explicit, inspectable stages —
the JAX-AOT-style analogue of SystemML separating compilation from
execution:

    hinge = fused(lambda X, w, y: ir.relu(1 - y * (X @ w)))

    traced   = hinge.trace(Xarr, warr, yarr)      # IR graph, static shapes
    planned  = traced.plan(mode="gen")            # explore → select
    print(planned.explain())                      # per-candidate cost report
    op       = planned.compile(pallas="never")    # generated fused operators
    out      = op(Xarr, warr, yarr)

``@fused`` call syntax stays as sugar over the staged path: the wrapper
traces/plans/compiles on first call per (shape, format, context) signature
and memoizes the Compiled stage.

Compiled fused operators are first-class JAX citizens:

* **autodiff** — each dense call runs through a ``jax.custom_vjp`` whose
  backward pass is *itself* planned through explore → select
  (:mod:`repro.core.grad`), so ``jax.grad`` of a ``@fused`` region executes
  generated fused operators in both directions.
* **layouts** — ``plan(layout=mesh_or_FusionLayout)`` threads the PR-2
  distributed layout rules onto operator inputs/outputs: reads of
  model-sharded side inputs are costed at ICI bandwidth during selection,
  and dense operands are sharding-constrained at execution
  (:mod:`repro.core.layout`), so local and distributed execution share one
  entry point.

Operands may be 2-D matrices, 1-D vectors, or 0-D scalars; non-2-D inputs
are canonicalized to column / 1×1 matrices for planning.  **Round-trip
rule:** when a call passes any 1-D/0-D operand, outputs of shape ``(n, 1)``
are returned as 1-D ``(n,)`` and ``(1, 1)`` outputs as 0-D scalars; calls
made entirely with 2-D operands always return 2-D results.

Contexts are immutable and explicitly scoped (:class:`FusionContext`);
``fusion_mode(...)`` remains as derive-and-scope sugar.
"""

from __future__ import annotations

import inspect
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax.numpy as jnp

from repro.kernels.blocksparse import BCSR, DictCompressed
from . import ir
from .codegen import (CompiledPlan, compile_plan, freed_intermediates,
                      plan_fallbacks)
from .context import FusionContext, current_context
from .cost import CostParams
from .grad import vjp_graph
from .layout import FusionLayout, ensure_layout, layout_cost_params
from .select import ExecPlan, MODES, MultiAggSpec, plan as plan_graph
from .verify import VerifyReport, verify_exec, verify_plan


class FusionInputError(TypeError):
    """An operand cannot be lifted into the 2-D LinOp IR."""


# --------------------------------------------------------------------------
# operand canonicalization (1-D vectors / 0-D scalars → column / 1×1)
# --------------------------------------------------------------------------

def _canon_shape(name: str, v) -> tuple[tuple[int, int], int]:
    """(canonical 2-D shape, original ndim) of one operand.

    This is where the 1-D/0-D canonicalization is *enforced*: the LinOp
    IR is strictly 2-D, so a 1-D vector of length n plans as an (n, 1)
    column matrix and a 0-D / python scalar as (1, 1).  The original
    ndim is kept so :func:`_uncanon_output` can round-trip results
    (column → 1-D, 1×1 → 0-D) for calls that passed any non-2-D operand;
    ranks above 2 raise :class:`FusionInputError`."""
    if isinstance(v, (BCSR, DictCompressed)):
        return tuple(v.shape), 2
    if isinstance(v, (int, float)):
        return (1, 1), 0
    shape = tuple(getattr(v, "shape", None) or ())
    if not hasattr(v, "shape"):
        raise FusionInputError(
            f"argument '{name}': expected an array, matrix, or scalar, "
            f"got {type(v).__name__}")
    if len(shape) == 2:
        return shape, 2
    if len(shape) == 1:
        return (shape[0], 1), 1           # column-vector convention
    if len(shape) == 0:
        return (1, 1), 0
    raise FusionInputError(
        f"argument '{name}': expected 0-D, 1-D or 2-D, got shape {shape}")


def _canon_value(name: str, v):
    shape, nd = _canon_shape(name, v)
    if nd == 2:
        return v
    if isinstance(v, (int, float)):
        return jnp.full((1, 1), float(v), jnp.float32)
    return jnp.reshape(v, shape)


def _uncanon_output(out):
    """The output half of the canonicalization round-trip, applied by
    :meth:`Compiled.__call__` iff the call passed any 1-D/0-D operand
    ("vector-world"): (n, 1) columns → 1-D ``(n,)``, (1, 1) → 0-D.
    All-2-D calls skip this and always get 2-D results back."""
    shape = getattr(out, "shape", ())
    if shape == (1, 1):
        return jnp.reshape(out, ())
    if len(shape) == 2 and shape[1] == 1:
        return jnp.reshape(out, (shape[0],))
    return out


def _as_expr_inputs(args: dict[str, object],
                    sparsity: dict[str, float]) -> dict[str, ir.Expr]:
    out = {}
    for name, v in args.items():
        shape, _ = _canon_shape(name, v)
        if isinstance(v, BCSR):
            sp = sparsity.get(name, v.block_sparsity)
        else:
            sp = sparsity.get(name, 1.0)
        out[name] = ir.matrix(name, shape, sparsity=sp)
    return out


def _signature(args: dict[str, object], ctx: FusionContext):
    sig: list = [ctx.key()]
    for name, v in args.items():
        if isinstance(v, BCSR):
            sig.append((name, "bcsr", v.shape, v.bs,
                        round(v.block_sparsity, 4)))
        elif isinstance(v, DictCompressed):
            sig.append((name, "dict", v.shape))
        else:
            shape, nd = _canon_shape(name, v)
            sig.append((name, "dense", shape, nd))
    return tuple(sig)


# --------------------------------------------------------------------------
# stage 1: Traced — the IR graph of the expression at static shapes
# --------------------------------------------------------------------------

@dataclass
class Traced:
    """Abstract trace of an expression function: the HOP DAG plus operand
    metadata.  Planning-only — carries no array data."""

    name: str
    graph: ir.Graph
    in_names: list[str]                    # fn-signature order
    in_meta: dict[str, dict]               # name → {shape, format, sparsity}

    def plan(self, mode: Optional[str] = None,
             params: Optional[CostParams] = None,
             layout=None,
             context: Optional[FusionContext] = None) -> "Planned":
        """Stage 2: run explore → select, returning a :class:`Planned`.

        Arguments (each optional, overriding the scoped
        :class:`FusionContext`):

        mode
            Selection arm: ``"gen"`` (cost-based MPSkipEnum — the paper's
            contribution), ``"fa"`` (fuse-all), ``"fnr"``
            (fuse-no-redundancy), or ``"none"`` (no fusion).
        params
            :class:`CostParams` cost-model constants (roofline
            bandwidths, byte widths, the fused-input constraint).
        layout
            A :class:`FusionLayout`, or any mesh exposing
            ``.shape``/``.axis_names`` — including the abstract
            ``repro.dist.LogicalMesh``, so no devices are required —
            which is auto-fitted to this trace's operand shapes via the
            PR-1/2 sharding rules.  With a layout, selection prices
            every fused operator on both the local and the distributed
            arm (``shard_map`` body + collective epilogue) and the
            induced plan is *hybrid*: per-operator placement is reported
            by :meth:`Planned.explain`.
        context
            Explicit base context (defaults to :func:`current_context`).
        """
        ctx = context if context is not None else current_context()
        if mode is not None:
            ctx = ctx.with_(mode=mode)
        if params is not None:
            ctx = ctx.with_(params=params)
        if layout is not None:
            ctx = ctx.with_(layout=layout)
        if ctx.layout is not None and not isinstance(ctx.layout,
                                                     FusionLayout):
            # bare mesh (incl. via the scoped context): fit the sharding
            # rules to this trace's operand and output shapes
            shapes = {name: m["shape"] for name, m in self.in_meta.items()}
            ctx = ctx.with_(layout=ensure_layout(ctx.layout, self.graph,
                                                 extra_shapes=shapes))
        eff = layout_cost_params(ctx.layout, self.graph, ctx.params)
        eplan = plan_graph(self.graph, ctx.mode, eff)
        rw_report = None
        if ctx.rewrite:
            eplan, rw_report = _rewrite_sweep(self.graph, ctx, eplan)
        planned = _verified_planned(self, ctx, eplan)
        planned._rewrite = rw_report
        return planned


# --------------------------------------------------------------------------
# stage 2: Planned — a selected ExecPlan with costs and an explain() report
# --------------------------------------------------------------------------

def _rewrite_sweep(graph: ir.Graph, ctx: FusionContext,
                   base: ExecPlan) -> tuple[ExecPlan, dict]:
    """The SPORES-style variant sweep between trace and plan: generate
    algebraically-equal DAG variants (:mod:`repro.core.rewrite`), gate
    each through the rewrite verifier (RW001–RW004 — always at least
    ``"cheap"``, even under ``verify="off"``: rejecting an illegal
    variant is a correctness property, not a diagnostic), plan the clean
    ones through the same explore → select pipeline, and return the
    global cost argmin plus the ``explain()["rewrite"]`` report.

    Deterministic: variants come out of the bounded BFS in a fixed
    order, plans tie-break toward the earlier variant (and the original
    DAG before any variant), and rule labels use topological indices —
    so re-tracing the same expression reproduces the report verbatim."""
    from .rewrite import rewrite_variants
    from .verify import verify_variant

    level = "strict" if ctx.verify == "strict" else "cheap"
    variants = rewrite_variants(graph)
    entries = [{"rules": [], "cost": base.cost, "selected": False}]
    rejected: list[dict] = []
    best, best_idx, best_rules = base, 0, ()
    for v in variants:
        vrep = verify_variant(graph, v.graph, level=level)
        if not vrep.ok:
            rejected.append({"rules": list(v.rules),
                             "errors": sorted({d.code
                                               for d in vrep.errors})})
            continue
        eff_v = layout_cost_params(ctx.layout, v.graph, ctx.params)
        ep = plan_graph(v.graph, ctx.mode, eff_v)
        entries.append({"rules": list(v.rules), "cost": ep.cost,
                        "selected": False})
        if ep.cost < best.cost:
            best, best_idx, best_rules = ep, len(entries) - 1, v.rules
    entries[best_idx]["selected"] = True
    best.rewrite = tuple(best_rules)
    report = {
        "enabled": True,
        "n_variants": len(variants),
        "n_planned": len(entries) - 1,
        "n_rejected": len(rejected),
        "rejected": rejected,
        "variants": entries,
        "winner": {
            "rules": list(best_rules),
            "cost": best.cost,
            "baseline_cost": base.cost,
            "improvement": base.cost - best.cost,
        },
    }
    return best, report


def _verified_planned(traced: Traced, ctx: FusionContext,
                      eplan: ExecPlan) -> "Planned":
    """The plan() stage boundary: every ExecPlan entering stage 2 passes
    the plan verifier at the context's level (``"cheap"`` by default,
    ``"strict"`` for the full pass, ``"off"`` to skip).  Error-severity
    diagnostics raise :class:`~repro.core.verify.VerificationError`
    here — before any code generation can execute the broken plan."""
    planned = Planned(traced, ctx, eplan)
    if ctx.verify != "off":
        report = verify_plan(eplan, level=ctx.verify, pallas=ctx.pallas,
                             layout=ctx.layout)
        report.raise_if_errors()
        planned._verify = report
    return planned


def _spec_signature(graph: ir.Graph, spec) -> dict:
    def label(nid: int) -> str:
        n = graph.by_id[nid]
        return n.name if n.name else n.op

    if isinstance(spec, MultiAggSpec):
        return {"template": "MAGG(multi)",
                "root": [graph.by_id[r].op for r in spec.roots],
                "inputs": sorted(label(i) for i in spec.inputs),
                "driver": None,
                "n_covered": sum(len(p.cover) for p in spec.parts)}
    return {"template": spec.ttype.name if spec.ttype is not None else "basic",
            "root": graph.by_id[spec.root].op,
            "inputs": sorted(label(i) for i in spec.inputs),
            "driver": label(spec.driver) if spec.driver is not None else None,
            "n_covered": len(spec.cover)}


@dataclass
class Planned:
    """One selected execution plan for a Traced expression."""

    traced: Traced
    context: FusionContext
    eplan: ExecPlan
    _bwd: Optional["Planned"] = field(default=None, repr=False)
    #: VerifyReport from the plan() stage boundary (None: verify="off")
    _verify: Optional[VerifyReport] = field(default=None, repr=False)
    #: rewrite-sweep report from Traced.plan() (None: ctx.rewrite=False or
    #: a path that never swept, e.g. the planned backward)
    _rewrite: Optional[dict] = field(default=None, repr=False)

    @property
    def cost(self) -> float:
        return self.eplan.cost

    def fused_signatures(self) -> list[dict]:
        """Structural signature of every selected fused operator.  Under a
        mesh layout each signature also carries the local/distributed
        decision: ``placement``, the collective ``epilogue``, and the
        modeled per-device ``collective_bytes`` (ring all-reduce of the
        epilogue plus side-input all-gathers)."""
        out = []
        for s in self.eplan.fused_specs():
            sig = _spec_signature(self.eplan.graph, s)
            pl = getattr(s, "placement", None)
            if pl is not None:
                sig["placement"] = pl.arm
                sig["epilogue"] = pl.epilogue
                sig["collective_bytes"] = int(round(pl.collective_bytes))
            out.append(sig)
        return out

    def candidates(self) -> list[dict]:
        """Cost every selection arm for this plan's graph (the
        per-candidate report, analogous to the layout planner's candidate
        sweep).  Uses ``eplan.graph`` — when the rewrite sweep won, the
        arms are costed on the *winning variant*, so the table compares
        like with like."""
        eff = layout_cost_params(self.context.layout, self.eplan.graph,
                                 self.context.params)
        out = []
        for m in MODES:
            p = self.eplan if m == self.context.mode \
                else plan_graph(self.eplan.graph, m, eff)
            out.append({"mode": m, "cost": p.cost,
                        "n_fused": len(p.fused_specs()),
                        "n_operators": len(p.specs),
                        "selected": m == self.context.mode})
        return out

    def backward(self) -> "Planned":
        """Plan the gradient DAG through the same explore → select pipeline
        (fused backward operators).  Raises NonDifferentiableError when the
        forward graph has an op with no VJP rule."""
        if self._bwd is None:
            ct_names, grads = vjp_graph(self.eplan.graph)
            fwd_inputs = [n.name for n in self.eplan.graph.inputs()]
            bgraph = ir.Graph.build([grads[n] for n in fwd_inputs])
            in_meta = dict(self.traced.in_meta)
            for name, o in zip(ct_names, self.eplan.graph.outputs):
                in_meta[name] = {"shape": o.shape, "format": "dense",
                                 "sparsity": 1.0}
            btr = Traced(self.traced.name + ":vjp", bgraph,
                         list(self.traced.in_names) + ct_names, in_meta)
            self._bwd = _verified_planned(
                btr, self.context,
                plan_graph(bgraph, self.context.mode,
                           layout_cost_params(self.context.layout, bgraph,
                                              self.context.params)))
            self._bwd.grad_names = fwd_inputs   # type: ignore[attr-defined]
        return self._bwd

    def explain(self, include_backward: bool = False) -> dict:
        """Structured plan report (same shape as the layout planner's
        ``experiments/layouts`` JSON: winner + candidates + stats).

        Keys: ``expression``, ``mode``, ``inputs`` (shape/format/
        sparsity per operand), ``winner`` (cost, operator count, and one
        signature per fused operator — see :meth:`fused_signatures`),
        ``candidates`` (every selection arm costed on this trace),
        ``rewrite`` (the trace→plan algebraic-variant sweep: rules
        applied, per-variant cost, rejected variants with their RW
        codes, and the winning rule chain — ``{"enabled": False}`` when
        the context disabled it), ``stats`` (exploration/enumeration
        counters), ``execution``
        (staged whole-plan compilation: the per-call dispatch count, the
        dead intermediates the staged trace frees for buffer reuse, and
        the guarantee that inputs are never donated), and ``layout``
        (mesh + PartitionSpecs, or None).  Under a mesh layout a
        ``distributed`` summary is added: row-shard axes and degree, the
        local/distributed operator split, total modeled collective
        volume, and the plan ``segments`` — runs of adjacent distributed
        operators that execute inside a single ``shard_map`` region,
        each with the intra-segment boundary volume the fused region
        removes (``removed_collective_bytes``).  ``verify`` carries the
        plan verifier's report (:mod:`repro.core.verify`): the level it
        ran at, error/warning counts, and every diagnostic.
        ``include_backward=True`` appends the planned gradient DAG's
        report (see :meth:`backward`)."""
        ex, en = self.eplan.explore_stats, self.eplan.enum_stats
        report = {
            "expression": self.traced.name,
            "mode": self.context.mode,
            "inputs": {n: {"shape": list(m["shape"]),
                           "format": m["format"],
                           "sparsity": round(float(m["sparsity"]), 4)}
                       for n, m in self.traced.in_meta.items()},
            "winner": {
                "cost": self.eplan.cost,
                "n_operators": len(self.eplan.specs),
                "operators": self.fused_signatures(),
            },
            "candidates": self.candidates(),
            # the trace→plan rewrite sweep (rules applied, per-variant
            # cost, winner); {"enabled": False} when the context disabled
            # it or this Planned came from a path that never sweeps
            "rewrite": (self._rewrite if self._rewrite is not None
                        else {"enabled": False}),
            "stats": {
                "explored_operators": ex.operators if ex else 0,
                "memo_entries": ex.entries_kept if ex else 0,
                "partitions": en.partitions if en else 0,
                "enum_points": en.points_total if en else 0,
                "plans_costed": en.plans_costed if en else 0,
            },
            "execution": {
                "staged": self.context.staged,
                "dispatches_per_call": 1 if self.context.staged
                else len(self.eplan.specs),
                "donated_inputs": [],       # inputs are never donated
                "freed_intermediates": freed_intermediates(self.eplan),
                # every statically-known execution downgrade, with its
                # reason; Compiled.explain() merges the runtime-recorded
                # entries (value-format downgrades seen at call time)
                "fallbacks": plan_fallbacks(
                    self.eplan, layout=self.context.layout,
                    pallas=self.context.pallas,
                    staged=self.context.staged),
            },
            "layout": None,
        }
        if self._verify is None and self.context.verify != "off":
            self._verify = verify_plan(self.eplan,
                                       level=self.context.verify,
                                       pallas=self.context.pallas,
                                       layout=self.context.layout)
        report["verify"] = (self._verify.summary()
                           if self._verify is not None else None)
        if self.context.layout is not None:
            lay = self.context.layout
            report["layout"] = {
                "mesh": {a: int(lay.mesh.shape[a])
                         for a in lay.mesh.axis_names},
                "specs": {n: [list(e) if isinstance(e, tuple) else e
                              for e in tuple(s)]
                          for n, s in sorted(lay.specs.items())},
            }
            ops = report["winner"]["operators"]
            n_dist = sum(1 for o in ops
                         if o.get("placement") == "distributed")
            segments = [{
                "specs": list(seg.indices),
                "n_operators": len(seg.indices),
                "row_axes": list(seg.axes),
                "devices": seg.n,
                "n_sharded_edges": len(seg.sharded_edges),
                "removed_collective_bytes":
                    int(round(seg.removed_gather_bytes)),
            } for seg in self.eplan.segments]
            report["distributed"] = {
                "row_axes": list(lay.row_axes()),
                "devices": lay.row_devices(),
                "n_fused_local": len(ops) - n_dist,
                "n_fused_distributed": n_dist,
                "collective_bytes": sum(o.get("collective_bytes", 0)
                                        for o in ops),
                "segments": segments,
                "removed_collective_bytes": sum(
                    s["removed_collective_bytes"] for s in segments),
            }
        if include_backward:
            bwd = self.backward()
            report["backward"] = {
                "cost": bwd.cost,
                "n_operators": len(bwd.eplan.specs),
                "operators": bwd.fused_signatures(),
            }
        return report

    def compile(self, pallas: Optional[str] = None,
                staged: Optional[bool] = None) -> "Compiled":
        """Stage 3: bind the plan to generated operators.

        ``pallas`` overrides the context's kernel-lowering policy:
        ``"never"`` (XLA-fused trace, the default), ``"interpret"``
        (Pallas template kernels in interpreter mode — CPU-safe
        validation), or ``"tpu"``.  With ``staged=True`` (default) the
        *whole plan* is compiled into a single jitted computation — one
        dispatch per call, literals folded as constants, dead
        intermediates freed for buffer reuse, distributed segments
        lowered into single ``shard_map`` regions — memoized in the
        structural whole-plan cache (:func:`whole_plan_cache_stats`);
        ``staged=False`` keeps per-operator dispatch as a debug path.
        Generated operators come from the global structural plan cache
        (:func:`plan_cache_stats`), so structurally-equal plans —
        retraced shapes, other expressions with the same skeleton —
        reuse compiled operators.  The returned :class:`Compiled` is
        callable on arrays and differentiable (``jax.custom_vjp`` whose
        backward is the *planned* gradient DAG)."""
        ctx = self.context
        if pallas is not None:
            ctx = ctx.with_(pallas=pallas)
        if staged is not None:
            ctx = ctx.with_(staged=staged)
        if ctx.verify != "off":
            # the compile() stage boundary re-checks the execution-level
            # invariants (liveness, aliasing, whole-plan key): the plan
            # object is mutable between stages
            report = VerifyReport(level=ctx.verify)
            report.diagnostics.extend(verify_exec(
                self.eplan, strict=ctx.verify == "strict",
                pallas=ctx.pallas, layout=ctx.layout))
            report.raise_if_errors()
        return Compiled(replace(self, context=ctx))


# --------------------------------------------------------------------------
# stage 3: Compiled — an executable, differentiable fused operator
# --------------------------------------------------------------------------

class Compiled:
    """Executable fused operator: runs the CompiledPlan, constrains operand
    layouts, and registers a ``jax.custom_vjp`` whose backward pass is the
    planned gradient DAG."""

    def __init__(self, planned: Planned):
        self.planned = planned
        ctx = planned.context
        self.staged = ctx.staged
        self._cplan: CompiledPlan = compile_plan(
            planned.eplan, pallas=ctx.pallas, layout=ctx.layout,
            staged=ctx.staged, strict=ctx.verify == "strict")
        self._n_outs = len(planned.eplan.graph.outputs)
        self._vjp_fn = None
        self._bwd_compiled: Optional[CompiledPlan] = None

    # -- serving hooks ------------------------------------------------------
    @property
    def input_order(self) -> list[str]:
        """Operand names in the staged function's positional order
        (``graph.inputs()`` order — may differ from the expression
        function's signature order)."""
        return [n.name for n in self.planned.eplan.graph.inputs()]

    def plan_key(self) -> tuple:
        """Structural whole-plan signature of this compiled plan (the
        mesh-free staged cache key).  Two Compiled objects with equal
        plan keys share one staged function and one XLA executable — the
        bucketing identity the fused-plan server
        (:mod:`repro.serve.fusion`) batches concurrent requests by."""
        from .codegen import staged_plan_key
        return staged_plan_key(self.planned.eplan,
                               pallas=self.planned.context.pallas)

    def batched(self):
        """Jitted vmapped form of the staged whole-plan function: takes
        each input stacked to ``(B, *shape)`` in :attr:`input_order` and
        returns the output tuple stacked the same way (batch elements
        independent).  Mesh-free dense plans only; shared across
        structurally-equal plans via the whole-plan cache."""
        return self._cplan.batched_callable()

    # -- execution ----------------------------------------------------------
    def _run_plain(self, bound: dict):
        lay = self.planned.context.layout
        if lay is not None:
            bound = {n: lay.apply(n, v) for n, v in bound.items()}
        outs = self._cplan(bound)
        if lay is not None:
            if isinstance(outs, tuple):
                outs = tuple(lay.apply(f"__out{i}", o)
                             for i, o in enumerate(outs))
            else:
                outs = lay.apply("__out0", outs)
        return outs

    def _get_bwd(self) -> tuple[CompiledPlan, list[str], list[str]]:
        bwd = self.planned.backward()
        if self._bwd_compiled is None:
            self._bwd_compiled = compile_plan(
                bwd.eplan, pallas=self.planned.context.pallas,
                layout=self.planned.context.layout, staged=self.staged)
        ct_names = [n for n in bwd.traced.in_names if n.startswith("__ct")]
        return self._bwd_compiled, bwd.grad_names, ct_names  # type: ignore

    def _build_vjp(self):
        import jax
        names = list(self.planned.traced.in_names)

        def run(*arrs):
            return self._run_plain(dict(zip(names, arrs)))

        @jax.custom_vjp
        def call(*arrs):
            return run(*arrs)

        def fwd(*arrs):
            return run(*arrs), arrs          # residuals: primal inputs only

        def bwd(res, ct):
            bwd_plan, grad_names, ct_names = self._get_bwd()
            cts = ct if isinstance(ct, (tuple, list)) else (ct,)
            binds = dict(zip(names, res))
            binds.update({n: jnp.asarray(c, jnp.float32)
                          for n, c in zip(ct_names, cts)})
            grads = bwd_plan(binds)
            if not isinstance(grads, tuple):
                grads = (grads,)
            by_name = dict(zip(grad_names, grads))
            return tuple(by_name.get(n) if n in by_name
                         else jnp.zeros_like(res[i])
                         for i, n in enumerate(names))

        call.defvjp(fwd, bwd)
        return call

    # -- calling ------------------------------------------------------------
    def explain(self, include_backward: bool = False) -> dict:
        report = self.planned.explain(include_backward=include_backward)
        # merge runtime-recorded downgrades (value-format decisions made
        # at call time) with the static ones, deduped by site+reason
        static = report["execution"]["fallbacks"]
        seen = {(f["site"], f["reason"]) for f in static}
        for f in self._cplan.fallbacks:
            if (f["site"], f["reason"]) not in seen:
                static.append(dict(f))
        bwd = self._bwd_compiled
        if bwd is not None:
            seen = {(f["site"], f["reason"]) for f in static}
            for f in bwd.fallbacks:
                if (f["site"], f["reason"]) not in seen:
                    static.append(dict(f))
        return report

    def _bind(self, args, kwargs) -> dict:
        bound = dict(zip(self.planned.traced.in_names, args))
        bound.update(kwargs)
        return bound

    def __call__(self, *args, **kwargs):
        """Execute on concrete operands (positional or by name).

        Dense calls run through the ``custom_vjp`` wrapper, so the result
        is ``jax.grad``-able; calls with sparse/compressed operands take
        the direct dispatch path.  Any 1-D/0-D operand puts the call in
        "vector world": outputs round-trip back through
        :func:`_uncanon_output`."""
        bound = self._bind(args, kwargs)
        vector_world = any(
            _canon_shape(n, v)[1] < 2 for n, v in bound.items())
        canon = {n: _canon_value(n, v) for n, v in bound.items()}
        dense = all(not isinstance(v, (BCSR, DictCompressed))
                    for v in canon.values())
        if dense:
            if self._vjp_fn is None:
                self._vjp_fn = self._build_vjp()
            names = self.planned.traced.in_names
            outs = self._vjp_fn(*[canon[n] for n in names])
        else:
            outs = self._run_plain(canon)
        if vector_world:
            if isinstance(outs, tuple):
                return tuple(_uncanon_output(o) for o in outs)
            return _uncanon_output(outs)
        return outs


# --------------------------------------------------------------------------
# the @fused wrapper — sugar over trace → plan → compile
# --------------------------------------------------------------------------

class Fused:
    """Callable wrapper staging an expression function on demand.

    Each distinct (shape, format, context) signature is traced, planned,
    and compiled once; subsequent calls reuse the Compiled stage (and,
    transitively, the structural plan cache)."""

    def __init__(self, fn: Callable, sparsity: Optional[dict] = None):
        self.fn = fn
        self.sparsity = dict(sparsity or {})
        self.names = list(inspect.signature(fn).parameters)
        self._staged: dict[tuple, Compiled] = {}

    # -- staged entry points ------------------------------------------------
    def trace(self, *args, **kwargs) -> Traced:
        """Stage 1: trace with abstract or concrete operands (anything with
        ``.shape`` — arrays, ShapeDtypeStructs, BCSR — or python scalars)."""
        bound = dict(zip(self.names, args))
        bound.update(kwargs)
        exprs = _as_expr_inputs(bound, self.sparsity)
        outs = self.fn(**exprs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        graph = ir.Graph.build(list(outs))
        meta = {}
        for name, v in bound.items():
            shape, _ = _canon_shape(name, v)
            fmt = ("bcsr" if isinstance(v, BCSR) else
                   "dict" if isinstance(v, DictCompressed) else "dense")
            meta[name] = {"shape": shape, "format": fmt,
                          "sparsity": exprs[name].node.sparsity}
        return Traced(getattr(self.fn, "__name__", "<expr>"), graph,
                      list(bound), meta)

    def plan_for(self, **shaped_args) -> ExecPlan:
        """Trace + plan under the current context (inspection helper)."""
        return self.trace(**shaped_args).plan().eplan

    # -- call sugar ---------------------------------------------------------
    def __call__(self, *args, **kwargs):
        ctx = current_context()
        bound = dict(zip(self.names, args))
        bound.update(kwargs)
        key = _signature(bound, ctx)
        compiled = self._staged.get(key)
        if compiled is None:
            compiled = self.trace(**bound).plan(context=ctx).compile()
            self._staged[key] = compiled
        return compiled(**bound)


def fused(fn: Optional[Callable] = None, *, sparsity: Optional[dict] = None):
    """Wrap an expression function as a stageable fused region.

    ``fn`` is a python function over :mod:`repro.core.ir` expressions
    (operands arrive as IR matrices; ``+ * @ .sum() ir.relu …`` build the
    HOP DAG).  The returned :class:`Fused` wrapper offers two spellings
    of the same pipeline:

    * **staged** — ``f.trace(*operands)`` → :class:`Traced`, then
      ``.plan(mode=, params=, layout=)`` → :class:`Planned`, then
      ``.compile(pallas=)`` → :class:`Compiled`, each stage inspectable
      (``Planned.explain()`` is the cost report);
    * **call sugar** — ``f(*arrays)`` traces/plans/compiles on first use
      per (shape, format, context) signature and memoizes the Compiled
      stage.

    Operands may be 2-D matrices (dense, ``BCSR``, ``DictCompressed``),
    1-D vectors, or 0-D scalars — see :func:`_canon_shape` for the
    canonicalization and round-trip rule.  ``sparsity`` optionally maps
    operand names to assumed densities for planning.

    Usable bare (``@fused``) or with arguments
    (``@fused(sparsity={"X": 0.05})``).
    """
    if fn is None:
        return lambda f: Fused(f, sparsity=sparsity)
    return Fused(fn, sparsity=sparsity)


def fuse_exprs(outputs, bindings: dict[str, object],
               mode: Optional[str] = None):
    """One-shot: plan + execute a hand-built expression DAG (honors the
    scoped context's layout the same way the staged path does)."""
    ctx = current_context()
    if mode is not None:
        ctx = ctx.with_(mode=mode)
    graph = ir.Graph.build(outputs if isinstance(outputs, (list, tuple))
                           else [outputs])
    if ctx.layout is not None and not isinstance(ctx.layout, FusionLayout):
        ctx = ctx.with_(layout=ensure_layout(ctx.layout, graph))
    eff = layout_cost_params(ctx.layout, graph, ctx.params)
    eplan = plan_graph(graph, ctx.mode, eff)
    if ctx.layout is not None:
        bindings = {n: ctx.layout.apply(n, v) for n, v in bindings.items()}
    outs = compile_plan(eplan, pallas=ctx.pallas, layout=ctx.layout,
                        staged=ctx.staged)(bindings)
    if ctx.layout is not None:
        if isinstance(outs, tuple):
            outs = tuple(ctx.layout.apply(f"__out{i}", o)
                         for i, o in enumerate(outs))
        else:
            outs = ctx.layout.apply("__out0", outs)
    return outs
