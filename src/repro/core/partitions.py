"""Plan partitions and interesting points (paper §4.2).

Plan partitions are the connected components of the *maximal DAG of fusion
references* — nodes unreachable via fusion are independent, so each
partition is optimized and costed separately.  Per partition we determine
root nodes, input nodes, materialization points (multiple consumers), and
the **interesting points** M'_i that span the 2^|M'_i| search space:

  - *materialization-point consumers* ``(g → m)``: one boolean per consuming
    data dependency of a multi-consumer node (fine-grained, so overlapping
    fused operators are not forced to re-read materialized intermediates);
  - *template switches* ``(g_i → g_j)`` where W[g_j] contains template types
    absent from W[g_i] (e.g. a Cell consumer that would destroy a
    sparsity-exploiting Outer below — paper's Y + X ⊙ UVᵀ example).

A point assigned **true** bans fusion along that dependency (all plans with
that reference become invalid); false leaves the choice to plan probing.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Graph
from .memo import MemoTable

#: an interesting point is a data dependency (consumer_nid, input_nid)
Point = tuple[int, int]


class PlanInvariantError(Exception):
    """A fusion plan violated a structural invariant the pipeline relies
    on — an inconsistent placement/segment assignment, a binding that
    cannot be wired, or (via :class:`repro.core.verify.VerificationError`)
    any error-severity verifier diagnostic.  Raised instead of silently
    producing a plan that would compute a wrong result."""


@dataclass
class Partition:
    nodes: set[int]                       # group ids with fusion plans
    roots: list[int]                      # never referenced within partition
    inputs: set[int]                      # read by partition, not in it
    mat_points: list[int]                 # multi-consumer nodes (no roots)
    points: list[Point]                   # interesting points M'_i
    #: extra nodes whose output leaves the partition (consumed by ops
    #: outside it or graph outputs) — they must be materialized too.
    exits: set[int] = field(default_factory=set)


def build_partitions(graph: Graph, memo: MemoTable) -> list[Partition]:
    plan_nodes = {nid for nid in memo.groups() if memo.entries(nid)}
    if not plan_nodes:
        return []

    # union-find over fusion references (the maximal reference DAG)
    parent = {nid: nid for nid in plan_nodes}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: int, b: int) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    referenced: set[int] = set()
    for nid in plan_nodes:
        for e in memo.entries(nid):
            for r in e.ref_ids():
                if r in plan_nodes:
                    union(nid, r)
                    referenced.add(r)

    comps: dict[int, set[int]] = {}
    for nid in plan_nodes:
        comps.setdefault(find(nid), set()).add(nid)

    parts: list[Partition] = []
    for members in comps.values():
        parts.append(_analyze(graph, memo, members, referenced))
    # deterministic order (by smallest member id) for reproducible planning
    parts.sort(key=lambda p: min(p.nodes))
    return parts


def _analyze(graph: Graph, memo: MemoTable, members: set[int],
             referenced: set[int]) -> Partition:
    roots = sorted(nid for nid in members if nid not in referenced)

    inputs: set[int] = set()
    for nid in members:
        for inp in graph.by_id[nid].inputs:
            if inp.nid not in members:
                inputs.add(inp.nid)

    # materialization points: multiple consumers (graph-wide), not a root
    mat = sorted(nid for nid in members
                 if graph.n_consumers(nid) > 1 and nid not in roots)

    # nodes whose value escapes the partition (external consumer or output)
    exits: set[int] = set()
    for nid in members:
        if nid in graph.output_ids:
            exits.add(nid)
        for c in graph.consumers[nid]:
            if c not in members:
                exits.add(nid)

    points: list[Point] = []
    seen: set[Point] = set()
    # (a) materialization-point consumers, individually per dependency
    for m in mat:
        for c in graph.consumers[m]:
            if c in members and _references(memo, c, m):
                p = (c, m)
                if p not in seen:
                    seen.add(p)
                    points.append(p)
    # (b) template switches
    for nid in members:
        t_out = set(memo.distinct_types(nid))
        for inp in graph.by_id[nid].inputs:
            if inp.nid not in members or (nid, inp.nid) in seen:
                continue
            if not _references(memo, nid, inp.nid):
                continue
            t_in = set(memo.distinct_types(inp.nid))
            if t_in - t_out:
                p = (nid, inp.nid)
                seen.add(p)
                points.append(p)

    return Partition(nodes=members, roots=roots, inputs=inputs,
                     mat_points=mat, points=points, exits=exits)


def _references(memo: MemoTable, consumer: int, inp: int) -> bool:
    return any(inp in e.ref_ids() for e in memo.entries(consumer))
