"""Fusion templates and their OFMC (open-fuse-merge-close) predicates.

Paper Table 1 / §3.2: four template types — **Cell**, **Row**, **MAgg**,
**Outer** — each a generic fused-operator skeleton with a data binding.  The
OFMC abstraction separates template-specific conditions from DAG traversal:

  - ``open(h)``   may a new fused operator of this template start at hop h?
  - ``fuse(h,in)``may an open fused op at input ``in`` expand to consumer h?
  - ``merge(h,in)``may an open fused op at h merge fused ops at input ``in``?
  - ``close(h)``  status after h: OPEN / CLOSED_VALID / CLOSED_INVALID
                  (+ OPEN_INVALID: extendable but not a valid plan root).

TPU adaptation constants: ``NARROW_MAX`` (a Row-template matmul side operand
must fit a VMEM row panel and feed the VPU/MXU without a grid over columns —
128-lane aligned) and ``OUTER_RANK_MAX`` (Outer-template rank bound so a
U-row/V-row panel pair fits VMEM), replacing the paper's CPU blocksize B_c.
"""

from __future__ import annotations

import enum
from typing import Optional

from .ir import Graph, Node, sparse_safe_wrt

# thresholds (TPU-motivated; see module docstring)
NARROW_MAX = 256          # max cols of a Row-template matmul side operand
OUTER_RANK_MAX = 512      # max common dim k of an outer-product matmul
OUTER_MIN_DIM = 128       # outer product ≥ one MXU block per side


class TType(enum.IntEnum):
    CELL = 0
    ROW = 1
    MAGG = 2
    OUTER = 3

    @property
    def letter(self) -> str:
        return "CRMO"[int(self)]


class Status(enum.IntEnum):
    OPEN_VALID = 0       # extendable, may root a plan
    OPEN_INVALID = 1     # extendable, may NOT root a plan (paper §3.1)
    CLOSED_VALID = 2     # complete fused operator
    CLOSED_INVALID = 3   # removed from the memo table


#: interior-reference compatibility: following a ref from an entry of type t
#: into a group, which entry types may continue the fused operator (paper:
#: "merge of Cell templates into Row templates", Outer merges Cell, …).
COMPAT: dict[TType, tuple[TType, ...]] = {
    TType.CELL: (TType.CELL,),
    TType.ROW: (TType.ROW, TType.CELL),
    TType.MAGG: (TType.CELL, TType.MAGG),
    TType.OUTER: (TType.OUTER, TType.CELL),
}


def _is_full_agg(h: Node) -> bool:
    return h.is_agg and h.agg_axis == "full"


def _row_compatible_shapes(h: Node) -> bool:
    """Cell-wise op whose operands broadcast row-wise: full matrices of equal
    rows, (m,1) per-row scalars, (1,n) shared row vectors, or scalars."""
    mats = [i for i in h.inputs if not i.is_scalar]
    if not mats:
        return False
    rows = {i.shape[0] for i in mats if i.shape[0] != 1}
    return len(rows) <= 1


def _narrow_mm(h: Node) -> bool:
    """Matrix multiplication with a narrow output (matrix-vector or
    matrix–narrow-matrix chain — the Row template's bread and butter).

    A double-transposed product t(A) @ t(B) is excluded: neither Row
    skeleton closes it (col_t_agg contracts t(X) @ chain, no_agg runs the
    chain's rows through (chain) @ B), so it executes as a basic operator
    instead of silently dropping one transpose inside a fused cover."""
    if not h.is_matmul:
        return False
    if h.ta and h.tb:
        return False
    m, k, n = h.mm_dims()
    return n <= NARROW_MAX and k > 1 and m > 1


def _outer_mm(h: Node) -> bool:
    """Outer-product-like matmul U @ t(V): large m×n output, small k."""
    if not h.is_matmul:
        return False
    m, k, n = h.mm_dims()
    return (k <= OUTER_RANK_MAX and m >= OUTER_MIN_DIM and n >= OUTER_MIN_DIM
            and m > k and n > k)


class Template:
    ttype: TType

    def open(self, h: Node) -> bool:
        raise NotImplementedError

    def fuse(self, h: Node, inp: Node) -> bool:
        raise NotImplementedError

    def merge(self, h: Node, inp: Node) -> bool:
        raise NotImplementedError

    def close(self, h: Node, graph: Graph) -> Status:
        raise NotImplementedError


# --------------------------------------------------------------------------
class CellTpl(Template):
    """Cell-wise template: binds cells X_ij, side inputs, scalars.
    Variants no_agg / row_agg / col_agg / full_agg (paper Table 1)."""

    ttype = TType.CELL

    def open(self, h: Node) -> bool:
        # idx (column-range read) is a valid entry: fusing it lets consumers
        # read the base matrix with an offset instead of materializing the
        # slice (SystemML fuses right-indexing into all templates).
        return (h.is_cellwise or h.op == "idx") and not h.is_scalar

    def fuse(self, h: Node, inp: Node) -> bool:
        if h.is_cellwise or h.op == "idx":
            return True
        if h.is_agg:            # any aggregation fuses (and then closes)
            return True
        return False

    def merge(self, h: Node, inp: Node) -> bool:
        # cell ops merge cell plans at any (broadcast-compatible) input
        return h.is_cellwise or h.is_agg or h.op == "idx"

    def close(self, h: Node, graph: Graph) -> Status:
        if h.is_agg:            # paper: "any aggregation closes a Cell"
            return Status.CLOSED_VALID
        return Status.OPEN_VALID


# --------------------------------------------------------------------------
class RowTpl(Template):
    """Row-wise template: binds rows X_i with side inputs/scalars.  Covers
    matvec chains (Xv, Xᵀy, XV narrow), row aggregations, and per-row cell
    math; closes on column/full aggregation or an Xᵀ(chain) product."""

    ttype = TType.ROW

    def open(self, h: Node) -> bool:
        if _narrow_mm(h):
            return True
        if h.is_agg and h.inputs[0].shape[1] > 1:      # agg over a matrix
            return True
        return False

    def fuse(self, h: Node, inp: Node) -> bool:
        if h.is_cellwise:
            return _row_compatible_shapes(h)
        if h.is_agg:
            return True
        if h.is_matmul:
            a, b = h.inputs
            if not _narrow_mm(h):
                return False
            # (chain) @ B  — chain rows stay rows (vectMatMult per row)
            if inp.nid == a.nid and not h.ta:
                return True
            # t(X) @ (chain) — column-transposed aggregation (col_t_agg):
            # accumulates x_rowᵀ ⊗ chain_row into a (k,n) output.
            if inp.nid == b.nid and h.ta and not h.tb:
                return True
            return False
        if h.op == "idx":
            return True
        return False

    def merge(self, h: Node, inp: Node) -> bool:
        if h.is_matmul:
            # a Row op opened at a matmul may merge plans at either operand
            return _narrow_mm(h)
        return self.fuse(h, inp)

    def close(self, h: Node, graph: Graph) -> Status:
        if h.is_agg and h.agg_axis in ("col", "full"):
            return Status.CLOSED_VALID
        if h.is_matmul and h.ta and not h.tb:
            return Status.CLOSED_VALID      # col_t_agg
        return Status.OPEN_VALID


# --------------------------------------------------------------------------
class MAggTpl(Template):
    """Multi-aggregate template: a single full aggregation over a cell chain;
    selection/codegen later combines MAgg roots sharing inputs into one fused
    operator with k outputs (paper Fig. 1(c), §5.2)."""

    ttype = TType.MAGG

    def open(self, h: Node) -> bool:
        if not _is_full_agg(h):
            return False
        src = h.inputs[0]
        return src.is_cellwise or src.is_input

    def fuse(self, h: Node, inp: Node) -> bool:
        return False                        # nothing extends beyond the agg

    def merge(self, h: Node, inp: Node) -> bool:
        return _is_full_agg(h)              # merge the cell chain below

    def close(self, h: Node, graph: Graph) -> Status:
        return Status.CLOSED_VALID          # closed at its own root


# --------------------------------------------------------------------------
class OuterTpl(Template):
    """Sparsity-exploiting outer-product template: binds non-zero (blocks of)
    X, rows of U and V from an outer-like product U @ t(V), plus dense side
    inputs.  Valid only if a sparse driver makes the chain sparse-safe
    (paper: "Outer templates are also validated for the existence of
    sparsity exploiting operators")."""

    ttype = TType.OUTER

    def open(self, h: Node) -> bool:
        return _outer_mm(h)

    def fuse(self, h: Node, inp: Node) -> bool:
        if h.is_cellwise:
            return _row_compatible_shapes(h)
        if _is_full_agg(h):
            return True                     # sum(...) -> full_agg variant
        if h.is_matmul:
            if _outer_mm(h):
                return False                # that would be a nested outer
            a, b = h.inputs
            m, k, n = h.mm_dims()
            # right_mm: (chain) @ V ; left_mm: t(chain) @ U
            if inp.nid == a.nid and not h.ta and n <= OUTER_RANK_MAX:
                return True
            if inp.nid == b.nid and h.ta and n <= OUTER_RANK_MAX:
                return True
            return False
        return False

    def merge(self, h: Node, inp: Node) -> bool:
        return self.fuse(h, inp) or self.open(h)

    def close(self, h: Node, graph: Graph) -> Status:
        if _outer_mm(h):
            # the outer product itself: extendable, but rooting here would
            # materialize the dense m×n product — exactly what we must avoid.
            return Status.OPEN_INVALID
        closing = _is_full_agg(h) or (h.is_matmul and not _outer_mm(h))
        if not closing:
            if h.is_cellwise and _has_sparse_driver(h):
                return Status.OPEN_VALID    # no_agg variant may root here
            return Status.OPEN_INVALID
        return (Status.CLOSED_VALID if _reaches_sparse_driver(h)
                else Status.CLOSED_INVALID)


def _has_sparse_driver(h: Node) -> bool:
    """Structural sparse-safety: ∃ leaf matrix L (not a factor of the outer
    matmul) with sparse-safe path to the cell chain at h."""
    leaves, factors = _collect_outer_leaves(h)
    return any(sparse_safe_wrt(h, lf) for lf in leaves
               if lf.nid not in factors and not lf.is_scalar
               and not lf.is_vector)


def _reaches_sparse_driver(h: Node) -> bool:
    """For closing hops (mm/agg over the chain), validate the chain input."""
    if h.is_agg:
        return _has_sparse_driver(h.inputs[0])
    if h.is_matmul:
        a, b = h.inputs
        chain = b if h.ta else a
        return _has_sparse_driver(chain)
    return _has_sparse_driver(h)


def _collect_outer_leaves(h: Node) -> tuple[list[Node], set[int]]:
    leaves: list[Node] = []
    factors: set[int] = set()
    seen: set[int] = set()
    stack = [h]
    while stack:
        n = stack.pop()
        if n.nid in seen:
            continue
        seen.add(n.nid)
        if n.is_input:
            leaves.append(n)
        elif _outer_mm(n):
            factors.update(i.nid for i in n.inputs)
            stack.extend(n.inputs)
        else:
            stack.extend(n.inputs)
    return leaves, factors


TEMPLATES: dict[TType, Template] = {
    TType.CELL: CellTpl(),
    TType.ROW: RowTpl(),
    TType.MAGG: MAggTpl(),
    TType.OUTER: OuterTpl(),
}


# --------------------------------------------------------------------------
# distributed template variants (hybrid local/distributed plans)
# --------------------------------------------------------------------------
#
# Every template above also has a *distributed* variant: the generated
# operator body runs unchanged on a row shard of its iteration domain
# (``shard_map`` over the mesh's data/FSDP axes), and a per-variant
# collective epilogue restores the global result.  This table is the
# registry of which (template, skeleton-variant) pairs distribute and how:
#
# * ``"none"``   — the output is row-partitioned exactly like the inputs
#                  (Cell/Row no_agg, row_agg, Outer right_mm): each shard
#                  writes its own slice, no communication.
# * ``"reduce"`` — each shard produces a *partial* of the full output that
#                  an all-reduce over the row axes completes (full/col
#                  aggregates, Row col_t_agg, Outer left_mm — everything
#                  whose reduction axis is the sharded one).  The concrete
#                  collective is picked per aggregation op by
#                  :func:`dist_epilogue` (``psum`` / ``pmin`` / ``pmax``);
#                  ``mean`` partials do not compose associatively per
#                  shard, so mean-rooted operators stay local.
#
# Variant names are the CPlan skeleton variants (``core/cplan.py``); kept
# as string literals here because cplan imports this module.
DIST_VARIANTS: dict[tuple[TType, str], str] = {
    (TType.CELL, "no_agg"):    "none",
    (TType.CELL, "row_agg"):   "none",
    (TType.CELL, "col_agg"):   "reduce",
    (TType.CELL, "full_agg"):  "reduce",
    (TType.ROW, "no_agg"):     "none",
    (TType.ROW, "row_agg"):    "none",
    (TType.ROW, "col_agg"):    "reduce",
    (TType.ROW, "full_agg"):   "reduce",
    (TType.ROW, "col_t_agg"):  "reduce",
    (TType.MAGG, "full_agg"):  "reduce",
    # Outer distributes only where the reduction axis is the sharded row
    # axis of the sparse driver: left_mm (t(chain) @ U) and the full/col
    # aggregates.  right_mm's reduction runs over columns, which stay
    # local to each row shard — but its *output* is the dense m×n-shaped
    # product row block, which the template exists to avoid materializing
    # globally; it distributes as a row-partitioned write.
    (TType.OUTER, "right_mm"): "none",
    (TType.OUTER, "left_mm"):  "reduce",
    (TType.OUTER, "full_agg"): "reduce",
    (TType.OUTER, "col_agg"):  "reduce",
}

#: aggregation op → collective completing a "reduce" epilogue.
_REDUCE_COLLECTIVE = {"sum": "psum", "sum_sq": "psum",
                      "min": "pmin", "max": "pmax"}


def dist_epilogue(ttype: TType, variant: str, agg_op: str) -> Optional[str]:
    """Collective epilogue of the distributed variant of (template,
    variant), or None when no distributed variant exists: ``"none"``
    (row-partitioned output), or the all-reduce flavour (``"psum"`` /
    ``"pmin"`` / ``"pmax"``) matching the aggregation op."""
    kind = DIST_VARIANTS.get((ttype, variant))
    if kind is None:
        return None
    if kind == "none":
        return "none"
    return _REDUCE_COLLECTIVE.get(agg_op)
