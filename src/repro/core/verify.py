"""Plan verifier: static analysis over HOP DAGs, CPlans, and ExecPlans.

The paper's pitch is that candidate exploration only emits *valid* fusion
plans and that cost-based selection preserves semantics — this module is
where those claims become machine-checked invariants instead of implicit
properties of the construction code.  Three checkers share one diagnostic
framework:

* :func:`verify_graph` — the **IR verifier** over the traced HOP DAG:
  acyclicity / topological order, single-producer SSA form, shape
  inference re-derived bottom-up (:func:`repro.core.ir.infer_shape`) and
  cross-checked against stored metadata, dtype consistency, and the
  operand-canonicalization invariants (strict 2-D shapes, (1,1) literals,
  named inputs, valid aggregation axes).
* :func:`verify_selection` — the **CPlan/selection verifier**: cover
  connectivity and input-boundary consistency, template applicability
  (Cell/Row/MAgg/Outer root qualification and interior compatibility),
  sparsity-exploitation safety (the driver chain must be zero-preserving
  over the exploited input), production/dependency order, placement
  epilogues against :data:`repro.core.templates.DIST_VARIANTS`, shard
  divisibility, and every :class:`~repro.core.select.Segment`'s
  row-partitioned data flow.
* :func:`verify_exec` — the **ExecPlan/codegen verifier**:
  ``_last_uses`` liveness soundness (no operator reads a freed
  intermediate), donation-aliasing safety, and — in strict mode —
  whole-plan-cache key completeness (every consumed value resolves to a
  structural token of the staged lowering).
* :func:`verify_rewrite` — the **rewrite-variant verifier** (RW001–RW004)
  over pairs of graphs produced by :mod:`repro.core.rewrite`: output
  arity, output shape/dtype re-derived bottom-up via
  :func:`repro.core.ir.infer_shape`, named-input set preservation, and
  sparse-zero-preservation (static zero-propagation: any output the
  original forces to zero when an input is all-zeros, the variant must
  force too).  :func:`verify_variant` bundles it with
  :func:`verify_graph` — the gate every rewrite variant passes before
  ``Traced.plan()`` will price it.

Two effort levels: ``"cheap"`` (O(plan) structural checks; the default at
the ``Traced.plan()`` / ``Planned.compile()`` stage boundaries) and
``"strict"`` (additionally builds every CPlan, replays the placement and
segment derivations, and exercises the whole-plan key — the
``FusionContext(verify="strict")`` / ``tools/fusionlint.py`` mode).

Severity policy: ``error`` means executing the plan could produce a wrong
result or crash; ``warning`` flags suspicious-but-executable structure.
:meth:`VerifyReport.raise_if_errors` turns error diagnostics into a
:class:`VerificationError` (a :class:`~repro.core.partitions.
PlanInvariantError`), which is what the stage boundaries raise.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import ir
from .ir import Graph, sparse_safe_wrt
from .partitions import PlanInvariantError
from .templates import COMPAT, TType, _outer_mm, dist_epilogue

_EPILOGUES = ("none", "psum", "pmin", "pmax")


class VerificationError(PlanInvariantError):
    """A verifier error-severity diagnostic, raised at a stage boundary."""

    def __init__(self, report: "VerifyReport"):
        self.report = report
        lines = [f"plan verification failed "
                 f"({len(report.errors)} error(s)):"]
        lines += [f"  {d}" for d in report.errors]
        super().__init__("\n".join(lines))


@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding.

    ``code`` identifies the invariant (IRxxx / SELxxx / SEGxxx / CPLxxx /
    EXExxx — the catalog lives in ``docs/architecture.md``), ``node`` the
    offending graph node id (or spec/segment index where noted),
    ``fix_hint`` a one-line remediation."""

    code: str
    severity: str                       # "error" | "warning"
    node: Optional[int]
    message: str
    fix_hint: Optional[str] = None

    def __str__(self) -> str:
        loc = f" @node {self.node}" if self.node is not None else ""
        hint = f"  [{self.fix_hint}]" if self.fix_hint else ""
        return f"{self.code} {self.severity}{loc}: {self.message}{hint}"

    def as_dict(self) -> dict:
        return {"code": self.code, "severity": self.severity,
                "node": self.node, "message": self.message,
                "fix_hint": self.fix_hint}


@dataclass
class VerifyReport:
    """All diagnostics of one verification pass."""

    diagnostics: list[Diagnostic] = field(default_factory=list)
    level: str = "cheap"

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def raise_if_errors(self) -> None:
        if self.errors:
            raise VerificationError(self)

    def summary(self) -> dict:
        """The ``explain()`` verify section (JSON-stable)."""
        return {"level": self.level,
                "errors": len(self.errors),
                "warnings": len(self.warnings),
                "diagnostics": [d.as_dict() for d in self.diagnostics]}

    def pretty(self) -> str:
        """Human-readable rendering (the ``fusionlint`` output)."""
        if not self.diagnostics:
            return f"ok ({self.level}): no diagnostics"
        lines = [f"{len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s) [{self.level}]"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)


def _diag(out: list, code: str, sev: str, node, msg: str,
          hint: Optional[str] = None) -> None:
    out.append(Diagnostic(code, sev, node, msg, hint))


# --------------------------------------------------------------------------
# checker 1: the IR verifier (HOP DAG)
# --------------------------------------------------------------------------

def verify_graph(graph: Graph) -> list[Diagnostic]:
    """Structural + metadata invariants of a traced HOP DAG."""
    out: list[Diagnostic] = []
    pos = {n.nid: i for i, n in enumerate(graph.nodes)}

    if len(graph.by_id) != len(graph.nodes):
        _diag(out, "IR002", "error", None,
              "duplicate node id: single-producer SSA form violated",
              "every value must be produced by exactly one node")

    seen_names: dict[str, int] = {}
    cons: dict[int, list[int]] = {n.nid: [] for n in graph.nodes}
    for n in graph.nodes:
        # -- acyclicity / topological order / producer identity ------------
        for i in n.inputs:
            if graph.by_id.get(i.nid) is not i:
                _diag(out, "IR002", "error", n.nid,
                      f"input %{i.nid} of {n.op} is not the graph's "
                      f"producer for that id (stale or foreign node)",
                      "rebuild the graph via Graph.build")
                continue
            if pos[i.nid] >= pos[n.nid]:
                _diag(out, "IR001", "error", n.nid,
                      f"{n.op} reads %{i.nid} which is not ordered before "
                      f"it (cycle or broken topological order)")
            cons[i.nid].append(n.nid)

        # -- operator taxonomy ---------------------------------------------
        if n.op not in ir.ALL_OPS:
            _diag(out, "IR005", "error", n.nid, f"unknown op '{n.op}'")
            continue
        if n.op in ir.AGG_OPS and "axis" in n.attrs \
                and n.attrs["axis"] not in ("full", "row", "col"):
            _diag(out, "IR006", "error", n.nid,
                  f"aggregation {n.op} has invalid axis "
                  f"{n.attrs['axis']!r}", "axis must be full|row|col")

        # -- operand canonicalization ---------------------------------------
        if (not isinstance(n.shape, tuple) or len(n.shape) != 2
                or any((not isinstance(d, int)) or d < 1 for d in n.shape)):
            _diag(out, "IR009", "error", n.nid,
                  f"{n.op} shape {n.shape!r} is not a strictly-2-D "
                  f"positive (rows, cols) tuple",
                  "operands canonicalize to 2-D before planning")
            continue
        if n.op == "lit" and (n.shape != (1, 1) or "value" not in n.attrs
                              or n.inputs):
            _diag(out, "IR009", "error", n.nid,
                  "literal must be a leaf (1, 1) node carrying a "
                  "'value' attr")
        if n.op == "input":
            if not n.name:
                _diag(out, "IR009", "error", n.nid,
                      "input leaf has no bind-time name")
            elif n.name in seen_names:
                _diag(out, "IR011", "warning", n.nid,
                      f"duplicate input name '{n.name}' (also node "
                      f"%{seen_names[n.name]}): bindings are by name")
            else:
                seen_names[n.name] = n.nid

        # -- shape re-derivation (bottom-up) vs stored metadata --------------
        try:
            want = ir.infer_shape(n.op, [i.shape for i in n.inputs],
                                  n.attrs)
        except (ValueError, KeyError) as e:
            _diag(out, "IR003", "error", n.nid,
                  f"{n.op} has inconsistent operand shapes: {e}")
            want = None
        if want is not None and want != n.shape:
            _diag(out, "IR003", "error", n.nid,
                  f"stored shape {n.shape} != re-derived {want} for "
                  f"{n.op}({', '.join(str(i.shape) for i in n.inputs)})",
                  "shape metadata and semantics drifted")

        # -- dtype / sparsity metadata ---------------------------------------
        for i in n.inputs:
            if i.op != "lit" and i.dtype != n.dtype:
                _diag(out, "IR004", "warning", n.nid,
                      f"{n.op} dtype {n.dtype} != input %{i.nid} dtype "
                      f"{i.dtype}")
                break
        if not (0.0 <= n.sparsity <= 1.0 + 1e-9):
            _diag(out, "IR008", "warning", n.nid,
                  f"sparsity estimate {n.sparsity} outside [0, 1]")

    # -- outputs + consumer map ---------------------------------------------
    for o in graph.outputs:
        if graph.by_id.get(o.nid) is not o:
            _diag(out, "IR010", "error", o.nid,
                  "graph output is not a node of the graph")
    for nid, expect in cons.items():
        if sorted(graph.consumers.get(nid, [])) != sorted(expect):
            _diag(out, "IR007", "error", nid,
                  "consumers map inconsistent with the edge set",
                  "rebuild the graph via Graph.build")
    return out


# --------------------------------------------------------------------------
# checker 2: the CPlan / selection verifier
# --------------------------------------------------------------------------

def _spec_roots(spec) -> tuple[int, ...]:
    from .select import MultiAggSpec
    return tuple(spec.roots) if isinstance(spec, MultiAggSpec) \
        else (spec.root,)


def _is_fused(spec) -> bool:
    return bool(getattr(spec, "fused", False))


def _exploit_expr(graph: Graph, ttype, root):
    """The sub-expression whose cells must vanish where the sparse driver
    is zero (mirrors :func:`repro.core.cost.find_driver`), or None when
    the root aggregation cannot skip zero cells at all."""
    if root.is_agg:
        if root.op not in ("sum", "sum_sq"):
            return None                 # min/max/mean see the zeros
        return root.inputs[0]
    if root.is_matmul:
        a, b = root.inputs
        return b if root.ta else a
    return root


def _check_cover(graph: Graph, out: list, spec, cover: dict,
                 root_nid: int, inputs: set) -> None:
    """SEL001/SEL002 for one (sub-)cover rooted at root_nid."""
    if root_nid not in cover:
        _diag(out, "SEL001", "error", root_nid,
              "fused operator root is not in its own cover")
        return
    reach = {root_nid}
    stack = [root_nid]
    while stack:
        for i in graph.by_id[stack.pop()].inputs:
            if i.nid in cover and i.nid not in reach:
                reach.add(i.nid)
                stack.append(i.nid)
    for nid in cover:
        if nid not in reach:
            _diag(out, "SEL001", "error", nid,
                  f"covered node %{nid} is unreachable from the root "
                  f"through the cover (disconnected fusion region)")
    boundary = {i.nid for nid in cover
                for i in graph.by_id[nid].inputs if i.nid not in cover}
    for nid in boundary - inputs:
        _diag(out, "SEL002", "error", nid,
              f"cover boundary value %{nid} is missing from the "
              f"operator's input list", "codegen could not bind it")
    for nid in inputs - boundary - set(cover):
        _diag(out, "SEL002", "warning", nid,
              f"listed input %{nid} is never consumed by the cover")


def _check_template(graph: Graph, out: list, spec) -> None:
    """SEL003: template applicability at the root + interior compat."""
    root = graph.by_id[spec.root]
    tt = spec.ttype
    ok = True
    if tt == TType.CELL:
        ok = root.is_cellwise or root.is_agg or root.op == "idx"
    elif tt == TType.ROW:
        ok = (root.is_cellwise or root.is_agg or root.is_matmul
              or root.op == "idx")
    elif tt == TType.MAGG:
        ok = root.is_agg and root.agg_axis == "full"
    elif tt == TType.OUTER:
        has_outer = any(_outer_mm(graph.by_id[nid]) for nid in spec.cover)
        if not has_outer:
            _diag(out, "SEL003", "error", spec.root,
                  "Outer template without an outer-product matmul in "
                  "its cover")
        if _outer_mm(root):
            _diag(out, "SEL003", "error", spec.root,
                  "Outer template rooted at the outer matmul itself "
                  "would materialize the dense m×n product",
                  "root at the consuming agg/matmul/cell chain instead")
    if not ok:
        _diag(out, "SEL003", "error", spec.root,
              f"{tt.name} template cannot root at op '{root.op}'")
    compat = COMPAT[tt]
    for nid, e in spec.cover.items():
        if nid != spec.root and e is not None and e.ttype not in compat:
            _diag(out, "SEL003", "error", nid,
                  f"interior entry of type {e.ttype.name} is not "
                  f"compatible with a {tt.name} fused operator")


def _check_sparse_safety(graph: Graph, out: list, spec) -> None:
    """SEL004: a sparsity-exploiting operator must be zero-preserving
    over the exploited (driver) input."""
    if spec.driver is None:
        return
    root = graph.by_id[spec.root]
    if spec.driver not in set(spec.inputs):
        _diag(out, "SEL004", "error", spec.driver,
              "sparse driver is not an input of the fused operator")
        return
    expr = _exploit_expr(graph, spec.ttype, root)
    if expr is None:
        _diag(out, "SEL004", "error", spec.root,
              f"aggregation '{root.op}' cannot skip the zero cells of a "
              f"sparse driver (non-linear over the skipped region)",
              "only sum/sum_sq aggregate sparse-exploited chains")
        return
    if not sparse_safe_wrt(expr, graph.by_id[spec.driver]):
        _diag(out, "SEL004", "error", spec.driver,
              f"fused chain is not zero-preserving w.r.t. driver "
              f"%{spec.driver}: evaluating only at its non-zeros would "
              f"be wrong", "clear spec.driver or re-run find_driver")


def _check_placement(graph: Graph, out: list, idx: int, spec,
                     params) -> None:
    """SEL011/SEL012/SEL013 for one distributed-placed operator."""
    from .cplan import variant_of
    from .select import MultiAggSpec

    pl = spec.placement
    if pl.epilogue not in _EPILOGUES:
        _diag(out, "SEL011", "error", spec.root,
              f"spec[{idx}] has unknown collective epilogue "
              f"{pl.epilogue!r}")
        return
    if isinstance(spec, MultiAggSpec):
        if pl.epilogue != "psum":
            _diag(out, "SEL011", "error", spec.root,
                  f"multi-aggregate epilogue must be psum, got "
                  f"{pl.epilogue!r}")
        for p in spec.parts:
            r = graph.by_id[p.root]
            if r.op not in ("sum", "sum_sq"):
                _diag(out, "SEL011", "error", p.root,
                      f"multi-aggregate member '{r.op}' has no psum-"
                      f"composable partial")
        rows = {graph.by_id[p.root].inputs[0].shape[0]
                for p in spec.parts}
    else:
        variant, agg_op, prog_root, _close = variant_of(
            graph, spec.ttype, graph.by_id[spec.root], set(spec.cover))
        want = dist_epilogue(spec.ttype, variant, agg_op)
        if want is None:
            _diag(out, "SEL011", "error", spec.root,
                  f"({spec.ttype.name}, {variant}) has no distributed "
                  f"variant but spec[{idx}] is placed distributed")
        elif pl.epilogue != want:
            _diag(out, "SEL011", "error", spec.root,
                  f"epilogue {pl.epilogue!r} does not match the "
                  f"template registry entry {want!r} for "
                  f"({spec.ttype.name}, {variant}, {agg_op or '-'})",
                  "see templates.DIST_VARIANTS")
        from .cost import _iter_rows
        rows = {_iter_rows(graph, spec, variant, prog_root)}
    if pl.n > 1:
        for r in rows:
            if r % pl.n:
                _diag(out, "SEL012", "error", spec.root,
                      f"iteration rows {r} not divisible by the "
                      f"row-shard degree {pl.n}")
    extra = set(pl.sharded) - set(spec.inputs)
    for nid in sorted(extra):
        _diag(out, "SEL013", "error", nid,
              f"placement marks %{nid} row-sharded but it is not an "
              f"input of spec[{idx}] (placement/binding drift)")


def _check_segments(graph: Graph, out: list, eplan) -> None:
    """SEG001–SEG006: each Segment's shard_map region must be
    representable — consistent row-shard group and data flow."""
    specs = eplan.specs
    for sidx, seg in enumerate(eplan.segments):
        idxs = seg.indices
        if list(idxs) != list(range(idxs[0], idxs[0] + len(idxs))):
            _diag(out, "SEG001", "error", sidx,
                  f"segment {sidx} indices {idxs} are not a contiguous "
                  f"run of the plan")
        pls = []
        for i in idxs:
            if i < 0 or i >= len(specs) or \
                    getattr(specs[i], "placement", None) is None or \
                    specs[i].placement.arm != "distributed":
                _diag(out, "SEG001", "error", sidx,
                      f"segment {sidx} member spec[{i}] is not a "
                      f"distributed-placed operator")
                return
            pls.append(specs[i].placement)
        groups = {(p.axes, p.n) for p in pls}
        if len(groups) > 1:
            _diag(out, "SEG002", "error", sidx,
                  f"segment {sidx} members disagree on the row-shard "
                  f"group: {sorted(groups)}")
        if (seg.axes, seg.n) not in groups:
            _diag(out, "SEG002", "error", sidx,
                  f"segment {sidx} header ({seg.axes}, {seg.n}) does "
                  f"not match its members")
        produced: dict[int, str] = {}
        ext_shard: dict[int, bool] = {}
        for i in idxs:
            pl = specs[i].placement
            for nid in specs[i].inputs:
                epil = produced.get(nid)
                if epil == "none" and nid not in pl.sharded:
                    _diag(out, "SEG003", "error", nid,
                          f"spec[{i}] reads the row-partitioned "
                          f"intra-segment value %{nid} unsharded "
                          f"(needs an in-region gather)")
                elif epil is not None and epil != "none" \
                        and nid in pl.sharded:
                    _diag(out, "SEG004", "error", nid,
                          f"spec[{i}] reads the reduced (replicated) "
                          f"value %{nid} as a row shard")
                elif epil is None:
                    sh = nid in pl.sharded
                    if nid in ext_shard and ext_shard[nid] != sh:
                        _diag(out, "SEG005", "error", nid,
                              f"external operand %{nid} is both "
                              f"sharded and broadcast inside segment "
                              f"{sidx}")
                    ext_shard[nid] = sh
            for r in _spec_roots(specs[i]):
                produced[r] = specs[i].placement.epilogue
        members = set(idxs)
        for (p, c, nid) in seg.sharded_edges:
            bad = (p not in members or c not in members or p >= c
                   or produced.get(nid) is None
                   or specs[p].placement.epilogue != "none"
                   or nid not in specs[c].placement.sharded)
            if bad:
                _diag(out, "SEG006", "error", nid,
                      f"segment {sidx} sharded edge ({p}->{c}, %{nid}) "
                      f"is inconsistent with member placements",
                      "producer must have a 'none' epilogue and the "
                      "consumer must read the value sharded")


def verify_selection(eplan, params=None,
                     strict: bool = False) -> list[Diagnostic]:
    """Checker 2: selection/CPlan invariants of an ExecPlan.

    ``params`` (a :class:`~repro.core.cost.CostParams`) enables the
    constraint and placement-replay checks; defaults to the params the
    plan was selected under (``eplan.params``)."""
    from .select import MultiAggSpec

    graph = eplan.graph
    params = params if params is not None else eplan.params
    out: list[Diagnostic] = []

    produced: dict[int, int] = {}
    available = {n.nid for n in graph.nodes if n.is_input}
    consumed: set[int] = set()
    for idx, spec in enumerate(eplan.specs):
        roots = _spec_roots(spec)
        # -- dependency order / single production --------------------------
        for i in spec.inputs:
            consumed.add(i)
            if i not in available and i not in produced:
                _diag(out, "SEL007", "error", i,
                      f"spec[{idx}] reads %{i} before any operator "
                      f"produces it")
        for r in roots:
            if r in produced:
                _diag(out, "SEL006", "error", r,
                      f"%{r} is produced twice (spec[{produced[r]}] "
                      f"and spec[{idx}])")
            produced[r] = idx

        if not _is_fused(spec):
            continue
        # -- fused-operator structure --------------------------------------
        if isinstance(spec, MultiAggSpec):
            if len(spec.roots) != len(spec.parts) or not spec.parts:
                _diag(out, "SEL010", "error", spec.root,
                      f"multi-aggregate spec[{idx}] roots/parts "
                      f"mismatch")
                continue
            union_inputs: set[int] = set()
            for part in spec.parts:
                r = graph.by_id[part.root]
                if not (r.is_agg and r.agg_axis == "full"):
                    _diag(out, "SEL010", "error", part.root,
                          f"multi-aggregate member root '{r.op}' is "
                          f"not a full aggregation")
                _check_cover(graph, out, part, part.cover, part.root,
                             set(part.inputs))
                _check_template(graph, out, part)
                _check_sparse_safety(graph, out, part)
                union_inputs.update(part.inputs)
            if union_inputs != set(spec.inputs):
                _diag(out, "SEL002", "error", spec.root,
                      f"multi-aggregate spec[{idx}] inputs differ from "
                      f"the union of its members' inputs")
        else:
            _check_cover(graph, out, spec, spec.cover, spec.root,
                         set(spec.inputs))
            _check_template(graph, out, spec)
            _check_sparse_safety(graph, out, spec)
        if params is not None and \
                len(spec.inputs) > params.max_fused_inputs:
            _diag(out, "SEL005", "error", spec.root,
                  f"spec[{idx}] binds {len(spec.inputs)} inputs, over "
                  f"the fused-input constraint "
                  f"{params.max_fused_inputs}")
        pl = getattr(spec, "placement", None)
        if pl is not None and pl.arm == "distributed":
            _check_placement(graph, out, idx, spec, params)

    # -- outputs / dead operators -------------------------------------------
    for o in graph.output_ids:
        if o not in produced and o not in available:
            _diag(out, "SEL008", "error", o,
                  f"graph output %{o} is produced by no operator")
    for r, idx in produced.items():
        if r not in consumed and r not in graph.output_ids:
            _diag(out, "SEL009", "warning", r,
                  f"spec[{idx}] materializes %{r} but nothing "
                  f"consumes it (dead operator)")

    _check_segments(graph, out, eplan)
    if strict:
        out.extend(_verify_selection_strict(eplan, params))
    return out


def _verify_selection_strict(eplan, params) -> list[Diagnostic]:
    """SEL014 / SEG007 / CPL001–CPL004: CPlan construction and the
    placement/segment replay (the expensive, full-pass checks)."""
    from .cplan import build_cplan
    from .select import annotate_segments, resolved_placements

    graph = eplan.graph
    out: list[Diagnostic] = []

    # -- placement replay: pinned placements must equal a fresh walk -------
    if params is not None and params.dist is not None \
            and params.dist.n > 1:
        try:
            pls, _total = resolved_placements(graph, eplan.specs, params)
        except PlanInvariantError as e:
            _diag(out, "SEL014", "error", None,
                  f"placement replay raised: {e}")
            pls = None
        if pls is not None:
            for idx, (spec, pl) in enumerate(zip(eplan.specs, pls)):
                have = getattr(spec, "placement", None)
                if pl is None and have is None:
                    continue
                same = (pl is not None and have is not None
                        and pl.arm == have.arm
                        and pl.epilogue == have.epilogue
                        and pl.axes == have.axes and pl.n == have.n
                        and pl.sharded == have.sharded)
                if not same:
                    _diag(out, "SEL014", "error", spec.root,
                          f"spec[{idx}] pinned placement "
                          f"{have and have.arm}/{have and have.epilogue} "
                          f"disagrees with the replayed walk "
                          f"{pl and pl.arm}/{pl and pl.epilogue}",
                          "placements were mutated after selection")
            segs = annotate_segments(graph, eplan.specs, params)
            if segs != tuple(eplan.segments):
                _diag(out, "SEG007", "error", None,
                      "plan segments differ from a fresh "
                      "annotate_segments derivation",
                      "segments were mutated after selection")

    # -- CPlan construction + well-formedness -------------------------------
    for idx, spec in enumerate(eplan.specs):
        if not _is_fused(spec):
            continue
        try:
            cp = build_cplan(graph, spec)
        except Exception as e:            # noqa: BLE001 - report, not crash
            _diag(out, "CPL001", "error", spec.root,
                  f"spec[{idx}] CPlan construction failed: {e}")
            continue
        out.extend(_verify_cplan(graph, spec, cp, idx))
    return out


def _verify_cplan(graph, spec, cp, idx: int) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    mains = [b for b in cp.binds if b.kind == "main"]
    if not cp.binds or len(mains) != 1 or cp.binds[0].kind != "main":
        _diag(out, "CPL001", "error", spec.root,
              f"spec[{idx}] CPlan binding malformed: expected exactly "
              f"one main bind, first")
    bind_nids = {b.nid for b in cp.binds}
    prog_nids: set[int] = set()
    for (nid, op, ins, _shape, _attrs) in cp.prog:
        for ref in ins:
            kind, r = ref
            if kind == "n" and r not in prog_nids:
                _diag(out, "CPL002", "error", nid,
                      f"CPlan program op '{op}' references %{r} before "
                      f"it is computed")
            elif kind == "b" and r not in bind_nids:
                _diag(out, "CPL002", "error", nid,
                      f"CPlan program op '{op}' references unbound "
                      f"input %{r}")
        prog_nids.add(nid)
    known = prog_nids | bind_nids
    roots = [cp.prog_root] + [pr for pr, _ in cp.extra]
    if cp.close_nid is not None:
        roots.append(cp.close_nid)
    for r in roots:
        if r not in known:
            _diag(out, "CPL003", "error", spec.root,
                  f"spec[{idx}] CPlan root %{r} is neither computed by "
                  f"the program nor bound")
    root = graph.by_id[spec.root]
    expr = _exploit_expr(graph, cp.ttype, root)
    for b in cp.binds:
        if not b.exploit:
            continue
        if expr is None or not sparse_safe_wrt(expr, graph.by_id[b.nid]):
            sev = "error" if spec.driver == b.nid else "warning"
            _diag(out, "CPL004", sev, b.nid,
                  f"spec[{idx}] bind %{b.nid} is flagged "
                  f"sparsity-exploiting but the program is not "
                  f"zero-preserving w.r.t. it")
    return out


# --------------------------------------------------------------------------
# checker 3: the ExecPlan / codegen verifier
# --------------------------------------------------------------------------

def verify_exec(eplan, strict: bool = False, pallas: str = "never",
                last_uses: Optional[dict] = None,
                layout=None) -> list[Diagnostic]:
    """Checker 3: liveness soundness of ``_last_uses``, donation-aliasing
    safety, no-silent-fallback on real meshes (EXE005), and (strict)
    whole-plan-cache key completeness.

    ``last_uses`` injects a liveness map for testing; by default the one
    codegen executes (:func:`repro.core.codegen._last_uses`) is
    simulated — with the same output-protection the runtime applies, so
    a diagnostic here means the *executed* plan would read a freed
    buffer.  ``layout`` enables EXE005: on a *real* mesh, every costed
    distributed placement must be realizable by the runtime — a
    placement the execution layer would quietly abandon is a costing
    bug, not an estimate (the plan priced a path it never takes)."""
    from .codegen import _last_uses as derive_last_uses

    graph = eplan.graph
    out: list[Diagnostic] = []
    lu = last_uses if last_uses is not None else derive_last_uses(eplan)

    outputs = set(graph.output_ids)
    live = {n.nid for n in graph.nodes if n.is_input}
    freed: dict[int, int] = {}            # nid -> spec idx that freed it
    ever = set(live)
    for idx, spec in enumerate(eplan.specs):
        for i in spec.inputs:
            if i in freed:
                _diag(out, "EXE001", "error", i,
                      f"spec[{idx}] reads %{i} which spec[{freed[i]}] "
                      f"already freed (liveness map is unsound)",
                      "a later consumer must extend the last use")
        live.update(_spec_roots(spec))
        ever.update(_spec_roots(spec))
        for dead in lu.get(idx, ()):
            if dead in outputs:
                continue                  # runtime never frees outputs
            if dead not in ever:
                _diag(out, "EXE002", "error", dead,
                      f"liveness map frees %{dead} at spec[{idx}] but "
                      f"it is never live")
            elif dead in live:
                live.discard(dead)
                freed[dead] = idx

    in_nids = {n.nid for n in graph.inputs()}
    for o in graph.output_ids:
        if o in in_nids:
            _diag(out, "EXE003", "warning", o,
                  f"graph input %{o} is returned as a plan output "
                  f"(aliasing hazard if the caller mutates results)",
                  "inputs are never donated, so this stays safe "
                  "read-only")

    if layout is not None:
        out.extend(_verify_exec_fallbacks(eplan, layout))
    if strict:
        out.extend(_verify_exec_strict(eplan, pallas))
    return out


def _verify_exec_fallbacks(eplan, layout) -> list[Diagnostic]:
    """EXE005 (no-silent-fallback): replay the distributed lowering's
    plan-time validation (:func:`repro.core.codegen.plan_fallbacks`) and
    report every placement a *real* mesh cannot realize as an error —
    the runtime would downgrade those segments to local execution, so
    the plan's distributed cost priced a path execution never takes.
    On an abstract ``LogicalMesh`` the same downgrades are by design
    (cost-only planning) and reported as warnings."""
    from .codegen import _is_real_mesh, _mesh_of, plan_fallbacks

    out: list[Diagnostic] = []
    mesh = _mesh_of(layout)
    if mesh is None or not _is_real_mesh(mesh):
        # abstract LogicalMesh: local execution is cost-only planning by
        # design, and explain() reports it — nothing silent to flag
        return out
    for fb in plan_fallbacks(eplan, layout=layout):
        if fb.get("site") == "plan":
            continue                      # staged=False: user's choice
        specs = fb.get("specs")
        _diag(out, "EXE005", "error", None,
              f"distributed placement of spec(s) {specs} falls back to "
              f"local execution: {fb['reason']}",
              "the cost model priced the distributed arm; on a real "
              "mesh this is a silent-downgrade bug (strict raises at "
              "execution time)")
    return out


def _verify_exec_strict(eplan, pallas: str) -> list[Diagnostic]:
    """EXE004: every value the staged lowering wires must resolve to a
    structural token of the whole-plan cache key — a plan whose key
    computation cannot even name all consumed values would alias
    structurally different plans (or crash at lowering)."""
    from .codegen import staged_plan_key

    out: list[Diagnostic] = []
    try:
        staged_plan_key(eplan, pallas=pallas)
    except KeyError as e:
        _diag(out, "EXE004", "error", None,
              f"whole-plan cache key incomplete: value {e} has no "
              f"structural token (producer missing from the plan)")
    except Exception as e:                # noqa: BLE001 - report, not crash
        _diag(out, "EXE004", "error", None,
              f"whole-plan key computation failed: {e}")
    return out


# --------------------------------------------------------------------------
# checker 4: the rewrite-variant verifier (RW001–RW004)
# --------------------------------------------------------------------------

def _derived_shapes(graph: Graph) -> dict[int, tuple[int, int]]:
    """Output shapes re-derived bottom-up via :func:`ir.infer_shape`
    (stored metadata only where the op carries no derivable shape)."""
    d: dict[int, tuple[int, int]] = {}
    for n in graph.nodes:
        got = ir.infer_shape(n.op, [d[i.nid] for i in n.inputs], n.attrs)
        d[n.nid] = got if got is not None else n.shape
    return d


def _zero_forced(graph: Graph, input_name: str) -> tuple[bool, ...]:
    """Static zero-propagation: for each graph output, is it *forced* to
    all-zeros when the input named ``input_name`` is all-zeros?  The
    conservative lattice behind RW004: mul/matmul are zero if either
    operand is, div if the numerator is, add/sub if both are, full/row/col
    aggregates and zero-preserving unaries pass zero through, literals are
    zero iff their value is; everything else is assumed non-zero."""
    z: dict[int, bool] = {}
    for n in graph.nodes:
        if n.op == "input":
            r = n.name == input_name
        elif n.op == "lit":
            r = float(n.sparsity) == 0.0
        elif n.op in ("t", "idx", "diagv"):
            r = z[n.inputs[0].nid]
        elif n.op in ("matmul", "mul") and len(n.inputs) == 2:
            r = z[n.inputs[0].nid] or z[n.inputs[1].nid]
        elif n.op == "div":
            r = z[n.inputs[0].nid]
        elif n.op in ("add", "sub") and len(n.inputs) == 2:
            r = all(z[i.nid] for i in n.inputs)
        elif n.is_agg:
            r = z[n.inputs[0].nid]       # agg of all-zeros is zero (min/max incl.)
        elif n.op in ir.SPARSE_SAFE_UNARY:
            r = z[n.inputs[0].nid]
        else:
            r = False
        z[n.nid] = r
    return tuple(z[o.nid] for o in graph.outputs)


def verify_rewrite(original: Graph, variant: Graph) -> list[Diagnostic]:
    """RW001–RW004: is ``variant`` a legal rewrite of ``original``?

    * **RW001** — output arity preserved.
    * **RW002** — per-output shape and dtype preserved, shapes re-derived
      bottom-up via :func:`ir.infer_shape` (a rule that miscomputes a
      replacement shape is caught here even if its stored metadata
      self-consistently lies).
    * **RW003** — named-input set preserved, with per-name shape/dtype
      agreement (the planned backward keys gradients by input name; a
      variant that drops or retypes an input breaks it).
    * **RW004** — sparse-zero-preservation: every output the original
      statically forces to zero when some input is all-zeros, the variant
      must force to zero too — otherwise sparsity exploitation over the
      rewritten form could read cells the original never produced.
    """
    out: list[Diagnostic] = []
    if len(variant.outputs) != len(original.outputs):
        _diag(out, "RW001", "error", None,
              f"rewrite changed output arity: "
              f"{len(original.outputs)} -> {len(variant.outputs)}",
              "a rule must replace a node with exactly one root")
        return out                       # positional checks are meaningless

    do = _derived_shapes(original)
    dv = _derived_shapes(variant)
    for i, (a, b) in enumerate(zip(original.outputs, variant.outputs)):
        if do[a.nid] != dv[b.nid]:
            _diag(out, "RW002", "error", b.nid,
                  f"rewrite changed output[{i}] shape: "
                  f"{do[a.nid]} -> {dv[b.nid]} (re-derived)",
                  "every rule must be shape-preserving on its match")
        if a.dtype != b.dtype:
            _diag(out, "RW002", "error", b.nid,
                  f"rewrite changed output[{i}] dtype: "
                  f"{a.dtype} -> {b.dtype}")

    ins_o = {n.name: n for n in original.inputs()}
    ins_v = {n.name: n for n in variant.inputs()}
    if set(ins_o) != set(ins_v):
        _diag(out, "RW003", "error", None,
              f"rewrite changed the named-input set: "
              f"{sorted(ins_o)} -> {sorted(ins_v)}",
              "planned backward keys gradients by input name")
    else:
        for name in sorted(ins_o):
            a, b = ins_o[name], ins_v[name]
            if a.shape != b.shape or a.dtype != b.dtype:
                _diag(out, "RW003", "error", b.nid,
                      f"rewrite retyped input '{name}': "
                      f"{a.shape}/{a.dtype} -> {b.shape}/{b.dtype}")
        for name in sorted(ins_o):
            zo = _zero_forced(original, name)
            zv = _zero_forced(variant, name)
            for i, (fo, fv) in enumerate(zip(zo, zv)):
                if fo and not fv:
                    _diag(out, "RW004", "error", None,
                          f"rewrite loses sparse-zero-preservation: "
                          f"output[{i}] is zero-forced by input "
                          f"'{name}' in the original but not in the "
                          f"variant",
                          "the rewritten expression must stay "
                          "zero-preserving over every input the "
                          "original is")
    return out


def verify_variant(original: Graph, variant: Graph,
                   level: str = "cheap") -> VerifyReport:
    """The rewrite-variant gate: IR-verify the variant graph, then check
    the RW001–RW004 pair invariants against the original.  Variants with
    a non-``ok`` report are rejected before planning (and recorded in
    ``explain()["rewrite"]["rejected"]``)."""
    assert level in ("off", "cheap", "strict"), level
    report = VerifyReport(level=level)
    if level == "off":
        return report
    report.diagnostics.extend(verify_graph(variant))
    report.diagnostics.extend(verify_rewrite(original, variant))
    return report


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def verify_plan(eplan, level: str = "cheap", params=None,
                pallas: str = "never", layout=None) -> VerifyReport:
    """Run every checker over an ExecPlan at the given effort level.

    ``"cheap"`` — O(plan) structural checks (the stage-boundary default);
    ``"strict"`` — additionally builds every CPlan, replays placements
    and segments, and exercises the whole-plan cache key; ``"off"`` —
    empty report.  ``layout`` enables the EXE005 no-silent-fallback
    check against a real mesh."""
    assert level in ("off", "cheap", "strict"), level
    report = VerifyReport(level=level)
    if level == "off":
        return report
    strict = level == "strict"
    report.diagnostics.extend(verify_graph(eplan.graph))
    report.diagnostics.extend(
        verify_selection(eplan, params=params, strict=strict))
    report.diagnostics.extend(
        verify_exec(eplan, strict=strict, pallas=pallas, layout=layout))
    return report
