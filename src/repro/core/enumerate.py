"""MPSkipEnum — materialization-point skip enumeration (paper §4.4, Alg. 2).

Linearizes the 2^|M'| assignment space of a partition's interesting points
(MSB-first, negative→positive so plan 0 = maximal fusion = the fuse-all
opening heuristic, giving a good initial upper bound) and scans it with:

  * **cost-based pruning**: C̲(q) = static partition bound + minimum
    materialization cost of q; whenever C̲ ≥ C̄ (best so far), skip the
    2^(|M'|−x−1) plans that share the prefix up to the last true bit x —
    they only add materialization cost;
  * **structural pruning**: a cut set of interesting points that, when
    materialized, splits the remaining points into independent sub-problems
    S1/S2 solved recursively (2^|S1|+2^|S2| ≪ 2^(|S1|+|S2|)); cut sets are
    scored by Eq. (5) and the best one is laid out first in the search
    space;
  * **partial costing**: GETPLANCOST aborts once the running cost exceeds C̄.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

from .cost import (CostParams, mp_cost, partition_cost, static_lower_bound)
from .ir import Graph
from .memo import MemoTable
from .partitions import Partition, Point


@dataclass
class EnumStats:
    partitions: int = 0
    points_total: int = 0
    space_size: float = 0.0        # Σ 2^|M'_i| (unpruned space)
    plans_costed: int = 0
    plans_skipped_cost: float = 0.0
    plans_skipped_struct: float = 0.0
    cut_sets_used: int = 0


# -- reachability graph & cut sets -------------------------------------------

@dataclass
class CutSet:
    points_ix: list[int]           # indices into the point list
    s1_ix: list[int]
    s2_ix: list[int]
    score: float = 0.0


def _walk_points(graph: Graph, part: Partition, starts: Sequence[int],
                 blocked: set[int], points: Sequence[Point]) -> set[int]:
    """Indices of points whose dependency edge is traversed walking
    consumer→input from ``starts``, not descending below ``blocked`` nodes."""
    pidx: dict[Point, int] = {p: i for i, p in enumerate(points)}
    hit: set[int] = set()
    seen: set[int] = set()
    stack = [s for s in starts if s in part.nodes]
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen.add(c)
        for inp in graph.by_id[c].inputs:
            t = inp.nid
            if (c, t) in pidx:
                hit.add(pidx[(c, t)])
            if t in part.nodes and t not in blocked:
                stack.append(t)
    return hit


def find_cut_sets(graph: Graph, part: Partition,
                  points: Sequence[Point]) -> list[CutSet]:
    """Candidate cut sets: per-target composites, single points, and
    non-overlapping pairs of composites; valid iff they split the remaining
    points into two non-empty disjoint halves (paper §4.4)."""
    n = len(points)
    by_target: dict[int, list[int]] = {}
    for i, (_, t) in enumerate(points):
        by_target.setdefault(t, []).append(i)

    composites = [tuple(ix) for ix in by_target.values()]
    candidates: list[tuple[tuple[int, ...], set[int]]] = []
    for ix in composites:
        candidates.append((ix, {points[i][1] for i in ix}))
    for a in range(len(composites)):
        for b in range(a + 1, len(composites)):
            ix = tuple(composites[a]) + tuple(composites[b])
            if len(ix) < n:
                candidates.append(
                    (ix, {points[i][1] for i in ix}))

    roots = list(set(part.roots) | part.exits)
    out: list[CutSet] = []
    for ix, targets in candidates:
        rest = [i for i in range(n) if i not in ix]
        if not rest:
            continue
        s1 = _walk_points(graph, part, roots, targets, points) - set(ix)
        s2 = _walk_points(graph, part, list(targets), set(), points) - set(ix)
        if not s1 or not s2 or (s1 & s2):
            continue
        # points in neither side (disconnected siblings) join S1
        s1 |= set(rest) - s1 - s2
        score = ((2 ** len(ix) - 1) / 2 ** len(ix) * 2 ** n
                 + (2 ** len(s1) + 2 ** len(s2)) / 2 ** len(ix))   # Eq. (5)
        out.append(CutSet(list(ix), sorted(s1), sorted(s2), score))
    out.sort(key=lambda c: c.score)
    return out


# -- the enumeration algorithm -------------------------------------------------

#: partitions above this many interesting points skip exact enumeration and
#: use greedy local search instead.  The paper's forward DAGs stay well
#: under this; planned *gradient* DAGs (repro.core.grad) can exceed it —
#: 2^|M'| scanning is intractable there and any assignment is numerically
#: exact, so bounded search only trades plan cost, never correctness.
EXACT_ENUM_MAX_POINTS = 16


def _greedy_enum(graph: Graph, memo: MemoTable, part: Partition,
                 params: CostParams, pts: list[Point],
                 st: EnumStats) -> tuple[tuple[bool, ...], float]:
    """First-improvement local search over materialization assignments:
    start from maximal fusion (the opening heuristic) and flip single
    points while it pays, a bounded number of passes."""
    n = len(pts)
    q = [False] * n
    best = partition_cost(graph, memo, part, set(), params)
    st.plans_costed += 1
    for _ in range(3):                       # bounded improvement passes
        improved = False
        for i in range(n):
            q[i] = not q[i]
            banned = {pts[k] for k in range(n) if q[k]}
            c = partition_cost(graph, memo, part, banned, params, ub=best)
            st.plans_costed += 1
            if c < best:
                best, improved = c, True
            else:
                q[i] = not q[i]
        if not improved:
            break
    return tuple(q), best


def mp_skip_enum(graph: Graph, memo: MemoTable, part: Partition,
                 params: CostParams, points: Optional[list[Point]] = None,
                 use_structural: bool = True,
                 use_cost_pruning: bool = True,
                 stats: Optional[EnumStats] = None) -> tuple[tuple[bool, ...], float]:
    """Return (q*, cost) for the partition's interesting points."""
    st = stats if stats is not None else EnumStats()
    pts = list(part.points if points is None else points)
    n = len(pts)
    if n > EXACT_ENUM_MAX_POINTS:
        # pts is in caller order here (no cut-set reordering happened yet)
        return _greedy_enum(graph, memo, part, params, pts, st)
    if n == 0:
        c = partition_cost(graph, memo, part, set(), params)
        st.plans_costed += 1
        return (), c

    # structural layout: best cut set first (paper sorts by Eq. 5 and lays
    # out the search space accordingly)
    cut: Optional[CutSet] = None
    if use_structural and n >= 3:
        cuts = find_cut_sets(graph, part, pts)
        if cuts:
            cut = cuts[0]
            order = (list(cut.points_ix)
                     + [i for i in range(n) if i not in cut.points_ix])
            pts = [pts[i] for i in order]
            remap = {old: new for new, old in enumerate(order)}
            cut = CutSet([remap[i] for i in cut.points_ix],
                         [remap[i] for i in cut.s1_ix],
                         [remap[i] for i in cut.s2_ix], cut.score)

    static_lb = static_lower_bound(graph, memo, part, params)
    written_anyway = frozenset(set(part.roots) | part.exits)

    best_q: Optional[tuple[bool, ...]] = None
    best_c = math.inf
    total = 1 << n
    j = 0
    while j < total:
        q = tuple(bool(j >> (n - 1 - i) & 1) for i in range(n))
        pskip = 0
        # -- structural pruning via skip-ahead (lines 6-10) -------------------
        if cut is not None and _is_cut_entry(q, cut, n):
            q = list(q)
            for sub_ix in (cut.s1_ix, cut.s2_ix):
                if not sub_ix:
                    continue
                sub_pts = [pts[i] for i in sub_ix]
                sub_q, _ = mp_skip_enum(graph, memo, part, params,
                                        points=sub_pts,
                                        use_structural=False,
                                        use_cost_pruning=use_cost_pruning,
                                        stats=st)
                for i, v in zip(sub_ix, sub_q):
                    q[i] = v
            q = tuple(q)
            pskip = (1 << (n - len(cut.points_ix))) - 1
            st.plans_skipped_struct += pskip
            st.cut_sets_used += 1
        banned = {pts[i] for i in range(n) if q[i]}
        # -- cost-based pruning (lines 11-15) ----------------------------------
        if use_cost_pruning and pskip == 0:
            lb = static_lb + mp_cost(graph, banned, params, written_anyway)
            if lb >= best_c:
                x = _last_true(q)
                skip = (1 << (n - 1 - x)) if x >= 0 else total - j
                st.plans_skipped_cost += skip - 1
                j += skip
                continue
        # -- plan costing and comparison (lines 16-19) ---------------------------
        c = partition_cost(graph, memo, part, banned, params, ub=best_c)
        st.plans_costed += 1
        if best_q is None or c < best_c:
            best_q, best_c = q, c
        j += 1 + pskip

    # translate back to the caller's point order
    if points is None and best_q is not None:
        order_map = {p: v for p, v in zip(pts, best_q)}
        best_q = tuple(order_map[p] for p in part.points)
    return best_q if best_q is not None else tuple([False] * n), best_c


def _is_cut_entry(q: tuple[bool, ...], cut: CutSet, n: int) -> bool:
    """True at the single assignment where the cut set is all-true and every
    remaining point is false — the entry of the decomposable subspace."""
    cs = set(cut.points_ix)
    return all(q[i] for i in cs) and not any(q[i] for i in range(n)
                                             if i not in cs)


def _last_true(q: tuple[bool, ...]) -> int:
    for i in range(len(q) - 1, -1, -1):
        if q[i]:
            return i
    return -1
