"""The memoization table of partial fusion plans (paper §3.1).

Groups (one per operator / logical subexpression, keyed by node id) hold
memo entries ``(template-type, input-refs, status)``.  ``refs`` aligns with
the hop's inputs by position; each element is the input's node id (a *group
reference* — fuse) or ``-1`` (materialized intermediate).  A reference from
an entry to a group implies the group contains at least one compatible plan
(enforced by exploration).

Mirrors Cascades groups/group-expressions in spirit, but — like the paper —
is used purely as a compact fusion-plan representation.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, Optional

from .templates import COMPAT, Status, TType


@dataclass(frozen=True)
class MemoEntry:
    ttype: TType
    refs: tuple[int, ...]
    status: Status = Status.OPEN_VALID

    @property
    def closed(self) -> bool:
        return self.status in (Status.CLOSED_VALID, Status.CLOSED_INVALID)

    @property
    def can_root(self) -> bool:
        return self.status in (Status.OPEN_VALID, Status.CLOSED_VALID)

    def ref_ids(self) -> tuple[int, ...]:
        return tuple(r for r in self.refs if r >= 0)

    @property
    def n_refs(self) -> int:
        return sum(1 for r in self.refs if r >= 0)

    def with_status(self, status: Status) -> "MemoEntry":
        return MemoEntry(self.ttype, self.refs, status)

    def __repr__(self) -> str:  # matches the paper's R(10,9) notation
        body = ",".join(str(r) for r in self.refs)
        suffix = {Status.OPEN_VALID: "", Status.OPEN_INVALID: "!",
                  Status.CLOSED_VALID: "*", Status.CLOSED_INVALID: "x"}
        return f"{self.ttype.letter}({body}){suffix[self.status]}"


class MemoTable:
    def __init__(self) -> None:
        self._groups: dict[int, list[MemoEntry]] = {}
        self._processed: set[int] = set()        # the paper's W[*]

    # -- population ----------------------------------------------------------
    def add_all(self, nid: int, entries: Iterable[MemoEntry]) -> None:
        self._groups.setdefault(nid, []).extend(entries)

    def set_entries(self, nid: int, entries: list[MemoEntry]) -> None:
        if entries:
            self._groups[nid] = entries
        else:
            self._groups.pop(nid, None)

    def mark_processed(self, nid: int) -> None:
        self._processed.add(nid)

    # -- queries --------------------------------------------------------------
    def processed(self, nid: int) -> bool:
        return nid in self._processed

    def contains(self, nid: int) -> bool:
        return nid in self._groups and bool(self._groups[nid])

    def entries(self, nid: int) -> list[MemoEntry]:
        return self._groups.get(nid, [])

    def groups(self) -> Iterator[int]:
        return iter(self._groups)

    def distinct_types(self, nid: int) -> list[TType]:
        seen: list[TType] = []
        for e in self.entries(nid):
            if e.ttype not in seen:
                seen.append(e.ttype)
        return seen

    def has_open(self, nid: int, ttype: TType) -> bool:
        """Open (extendable) entry of exactly this type in group nid?"""
        return any(e.ttype == ttype and not e.closed
                   for e in self.entries(nid))

    def has_compatible_open(self, nid: int, ttype: TType) -> bool:
        """Open entry that may continue a fused operator of type ``ttype``
        when reached through a reference (same type or mergeable, Cell→Row)."""
        compat = COMPAT[ttype]
        return any(e.ttype in compat and not e.closed
                   for e in self.entries(nid))

    def best_compatible(self, nid: int, ttype: Optional[TType],
                        banned_refs: Optional[set[tuple[int, int]]] = None
                        ) -> Optional[MemoEntry]:
        """Pick the continuation entry with the most fusion references (the
        paper probes "the best fusion plan regarding template type and
        fusion references" during top-down costing).

        ``ttype is None`` → selecting a plan *root* (must be can_root);
        otherwise → interior continuation (must be open & compatible).
        ``banned_refs`` = interesting-point assignments: (src, dst) data
        dependencies forced to materialize; entries using them are invalid.
        """
        if ttype is None:
            cands = [e for e in self.entries(nid) if e.can_root]
        else:
            compat = COMPAT[ttype]
            cands = [e for e in self.entries(nid)
                     if e.ttype in compat and not e.closed]
        if banned_refs:
            cands = [e for e in cands
                     if not any((nid, r) in banned_refs for r in e.ref_ids())]
        if not cands:
            return None
        return max(cands, key=lambda e: ((e.ttype == ttype) if ttype else 0,
                                         e.n_refs, -int(e.ttype)))

    # -- pruning (paper §3.2) --------------------------------------------------
    def prune_redundant(self, nid: int, n_op_inputs: int) -> None:
        """Drop duplicates and closed-valid single-operator entries (a fused
        operator covering one op gains nothing — e.g. no C(-1) at rowSums)."""
        out: list[MemoEntry] = []
        seen: set[tuple] = set()
        for e in self.entries(nid):
            if e.status == Status.CLOSED_INVALID:
                continue
            if e.status == Status.CLOSED_VALID and e.n_refs == 0:
                continue
            k = (e.ttype, e.refs, e.status)
            if k in seen:
                continue
            seen.add(k)
            out.append(e)
        self.set_entries(nid, out)

    def prune_dominated(self, nid: int, single_consumer: set[int]) -> None:
        """Heuristic-only dominance pruning: an entry is dominated if all its
        refs point to once-consumed operators and another same-type entry's
        ref set is a strict superset (paper §3.2 example: R(10,9) dominates
        R(10,-1))."""
        entries = self.entries(nid)
        keep: list[MemoEntry] = []
        for e in entries:
            refs_e = set(e.ref_ids())
            dominated = False
            if all(r in single_consumer for r in refs_e):
                for o in entries:
                    if o is e or o.ttype != e.ttype:
                        continue
                    refs_o = set(o.ref_ids())
                    if refs_e < refs_o:
                        dominated = True
                        break
            if not dominated:
                keep.append(e)
        self.set_entries(nid, keep)

    # -- stats / debug -----------------------------------------------------------
    def n_entries(self) -> int:
        return sum(len(v) for v in self._groups.values())

    def __repr__(self) -> str:  # pragma: no cover
        lines = []
        for nid in sorted(self._groups):
            lines.append(f"{nid}: " + " ".join(map(repr, self._groups[nid])))
        return "\n".join(lines)
