"""Source-to-source reverse-mode autodiff on the LinOp IR.

``vjp_graph`` takes a built forward :class:`~repro.core.ir.Graph` and
constructs the *gradient DAG*: fresh cotangent input matrices (one per
forward output, named ``__ct{i}``) plus an expression per forward input
computing ``d(Σ_i ct_i · out_i) / d(input)``.

The gradient DAG is an ordinary HOP DAG — it goes through the same
explore → select → codegen pipeline as any forward expression, so the
backward pass of a ``@fused`` region executes through *generated fused
operators* (Cell / Row / MAgg templates), exactly like the forward.
Forward intermediates referenced by gradient rules are re-materialized
inside the gradient DAG (rematerialization), which is what makes the
combined chains fusable in the first place.

Unsupported ops raise :class:`NonDifferentiableError`; callers degrade to
the non-differentiable execution path.
"""

from __future__ import annotations

import math
from typing import Optional

from . import ir
from .ir import Expr, Graph, Node


class NonDifferentiableError(ValueError):
    """The forward graph contains an op with no registered VJP rule."""


#: ops whose gradient w.r.t. every input is identically zero (piecewise-
#: constant outputs): propagating nothing through them is exact a.e.
_ZERO_GRAD = frozenset({
    "sign", "round", "floor", "ceil", "neq0",
    "eq", "neq", "lt", "le", "gt", "ge",
})

_TWO_OVER_SQRT_PI = 2.0 / math.sqrt(math.pi)


def _unbroadcast(e: Expr, shape: tuple[int, int]) -> Expr:
    """Sum a cotangent over the dims the forward op broadcast."""
    if e.shape == shape:
        return e
    if shape[0] == 1 and e.shape[0] != 1:
        e = e.colsums()
    if shape[1] == 1 and e.shape[1] != 1:
        e = e.rowsums()
    return e


def _expand(e: Expr, like: Expr) -> Expr:
    """Broadcast a cotangent up to ``like``'s shape (for reductions).

    Value-safe w.r.t. ``like``: where(c, e, e) == e for any predicate, so
    ±inf/NaN cells in the forward input (e.g. -inf logit masks) cannot
    contaminate the gradient the way ``e + like*0.0`` would (0·inf = NaN).
    """
    if e.shape == like.shape:
        return e
    return ir.where(like == like, e, e)


def _agg_vjp(node: Node, ct: Expr) -> Expr:
    x = Expr(node.inputs[0])
    axis = node.attrs["axis"]
    if node.op == "sum":
        return _expand(ct, x)
    if node.op == "mean":
        n = {"full": x.node.ncells, "row": x.shape[1],
             "col": x.shape[0]}[axis]
        return _expand(ct / float(n), x)
    if node.op == "sum_sq":
        return _expand(ct, x) * x * 2.0
    if node.op in ("min", "max"):
        # subgradient: split the cotangent evenly over the extremal cells
        mask = (x == Expr(node))            # broadcasts the (1|m,1|n) value
        denom = {"full": mask.sum(), "row": mask.rowsums(),
                 "col": mask.colsums()}[axis]
        return (mask / denom) * ct
    raise NonDifferentiableError(f"no VJP for aggregation '{node.op}'")


def _matmul_vjp(node: Node, ct: Expr) -> list[tuple[Node, Expr]]:
    a, b = node.inputs
    A, B = Expr(a), Expr(b)
    ta, tb = node.ta, node.tb
    if not ta and not tb:            # C = A B
        da, db = ct @ B.T, A.T @ ct
    elif ta and not tb:              # C = Aᵀ B
        da, db = B @ ct.T, A @ ct
    elif not ta and tb:              # C = A Bᵀ
        da, db = ct @ B, ct.T @ A
    else:                            # C = Aᵀ Bᵀ
        da, db = B.T @ ct.T, ct.T @ A.T
    return [(a, da), (b, db)]


def _node_vjp(node: Node, ct: Expr) -> list[tuple[Node, Expr]]:
    """Per-op rule: contributions of ``ct`` to each input's adjoint."""
    op = node.op
    if op in _ZERO_GRAD:
        return []
    ins = node.inputs
    out = Expr(node)                     # forward value, rematerialized

    if op == "matmul":
        return _matmul_vjp(node, ct)
    if op == "t":
        return [(ins[0], ct.T)]
    if node.is_agg:
        return [(ins[0], _agg_vjp(node, ct))]

    x = Expr(ins[0]) if ins else None
    if op in ir.UNARY_OPS:
        if op == "neg":
            g = -ct
        elif op in ("pow2", "square"):
            g = ct * x * 2.0
        elif op == "relu":
            g = ct * (x > 0.0)
        elif op == "abs":
            g = ct * ir.sign(x)
        elif op == "exp":
            g = ct * out
        elif op == "log":
            g = ct / x
        elif op == "log1p":
            g = ct / (x + 1.0)
        elif op == "sqrt":
            g = ct * 0.5 / out
        elif op == "recip":
            g = -ct * out * out
        elif op == "sigmoid":
            g = ct * out.unary("sprop")          # s(1-s)
        elif op == "tanh":
            g = ct * (1.0 - out * out)
        elif op == "erf":
            g = ct * _TWO_OVER_SQRT_PI * ir.exp(-(x * x))
        elif op == "softplus":
            g = ct * ir.sigmoid(x)
        elif op == "silu":
            s = ir.sigmoid(x)
            g = ct * (s + x * s.unary("sprop"))
        elif op == "sprop":                      # x(1-x)
            g = ct * (1.0 - 2.0 * x)
        else:
            raise NonDifferentiableError(f"no VJP for unary '{op}'")
        return [(ins[0], g)]

    if op in ir.BINARY_OPS:
        a, b = ins
        A, B = Expr(a), Expr(b)
        if op == "add":
            contrib = [(a, ct), (b, ct)]
        elif op == "sub":
            contrib = [(a, ct), (b, -ct)]
        elif op == "mul":
            contrib = [(a, ct * B), (b, ct * A)]
        elif op == "div":
            contrib = [(a, ct / B), (b, -ct * A / (B * B))]
        elif op in ("min", "max"):
            take_a = (A >= B) if op == "max" else (A <= B)
            contrib = [(a, ct * take_a), (b, ct * (1.0 - take_a))]
        elif op == "pow":
            if b.op != "lit":
                raise NonDifferentiableError(
                    "pow VJP requires a literal exponent")
            p = float(b.attrs["value"])
            contrib = [(a, ct * p * A ** (p - 1.0))]
        else:
            raise NonDifferentiableError(f"no VJP for binary '{op}'")
        return [(n, g) for n, g in contrib if n.op != "lit"]

    if op == "where":
        c, a, b = ins
        mask = ir.neq0(Expr(c))
        return [(n, g) for n, g in
                ((a, ct * mask), (b, ct * (1.0 - mask)))
                if n.op != "lit"]
    if op == "plus_mult":      # a + b*c
        a, b, c = ins
        return [(n, g) for n, g in
                ((a, ct), (b, ct * Expr(c)), (c, ct * Expr(b)))
                if n.op != "lit"]
    if op == "minus_mult":     # a - b*c
        a, b, c = ins
        return [(n, g) for n, g in
                ((a, ct), (b, -ct * Expr(c)), (c, -ct * Expr(b)))
                if n.op != "lit"]
    raise NonDifferentiableError(f"no VJP for op '{op}'")


def vjp_graph(graph: Graph) -> tuple[list[str], dict[str, Expr]]:
    """Gradient DAG of ``graph``.

    Returns ``(ct_names, grads)``: the cotangent input names (``__ct{i}``,
    one per forward output, shaped like it) and an Expr per forward input
    name computing its gradient.  Inputs with no differentiable path get an
    explicit zero of the right shape.
    """
    adjoint: dict[int, Expr] = {}
    cts: list[str] = []
    for i, o in enumerate(graph.outputs):
        name = f"__ct{i}"
        cts.append(name)
        ct = ir.matrix(name, o.shape, dtype=o.dtype)
        adjoint[o.nid] = adjoint[o.nid] + ct if o.nid in adjoint else ct

    for node in reversed(graph.nodes):
        if node.nid not in adjoint or node.is_input:
            continue
        ct = adjoint.pop(node.nid)
        for inp, contrib in _node_vjp(node, ct):
            contrib = _unbroadcast(contrib, inp.shape)
            if inp.nid in adjoint:
                adjoint[inp.nid] = adjoint[inp.nid] + contrib
            else:
                adjoint[inp.nid] = contrib

    grads: dict[str, Expr] = {}
    for inp in graph.inputs():
        g: Optional[Expr] = adjoint.get(inp.nid)
        if g is None:
            g = Expr(inp) * 0.0                       # no path: exact zero
        grads[inp.name] = _unbroadcast(g, inp.shape)  # type: ignore[index]
    return cts, grads
