"""Immutable fusion contexts (replaces the old thread-local FusionConfig).

A :class:`FusionContext` bundles every knob the staged pipeline consumes —
selection mode, Pallas lowering policy, cost-model parameters, and an
optional distributed :class:`~repro.core.layout.FusionLayout`.  Contexts are
frozen: "changing" one produces a new object via :meth:`FusionContext.with_`.

Scoping is explicit.  A context is itself a context manager that pushes
onto a thread-local *stack of immutable objects* (the only mutable state),
so library code can read :func:`current_context` without threading an
argument through every call:

    ctx = FusionContext(mode="fa", pallas="interpret")
    with ctx:
        loss = hinge(X, w, y)          # planned under ctx

``fusion_mode(...)`` remains as sugar deriving a child context from the
current one — existing call sites keep working unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Optional

from .cost import CostParams, TPU_V5E

_STACK = threading.local()


@dataclass(frozen=True)
class FusionContext:
    """Immutable bundle of every knob the staged pipeline consumes.

    Fields
    ------
    mode : str
        Candidate-selection arm — ``"gen"`` (cost-based MPSkipEnum, the
        paper's contribution), ``"fa"`` (fuse-all heuristic), ``"fnr"``
        (fuse-no-redundancy), or ``"none"`` (every operator basic).
    pallas : str
        Kernel lowering policy — ``"never"`` (XLA only), ``"interpret"``
        (Pallas kernels in interpreter mode, CPU-safe), or ``"tpu"``.
    staged : bool
        Whole-plan staged execution (default True): the entire ExecPlan
        is compiled into a single jitted computation — one dispatch per
        call.  False keeps per-operator dispatch (the debug/fallback
        interpreter, also used automatically for sparse operands and
        ``pallas="interpret"``).
    params : CostParams
        Analytical cost-model constants (roofline bandwidths, byte
        widths, the fused-input constraint).
    layout : FusionLayout | mesh | None
        Distributed layout for fused-operator inputs/outputs.  A bare
        mesh (anything exposing ``.shape``/``.axis_names``, including the
        abstract ``repro.dist.LogicalMesh``) is auto-fitted per trace.
        With a layout set, planning enumerates local × distributed
        placement per fused operator (hybrid plans) and execution on a
        real mesh runs distributed operators under ``shard_map``.
    verify : str
        Plan-verifier level at the stage boundaries
        (:mod:`repro.core.verify`) — ``"cheap"`` (default: O(plan)
        structural checks after ``Traced.plan()`` and before
        ``Planned.compile()``), ``"strict"`` (additionally builds every
        CPlan, replays placement/segment derivations, and checks the
        whole-plan cache key — the ``fusionlint`` mode), or ``"off"``.
        Error-severity diagnostics raise
        :class:`~repro.core.verify.VerificationError`.
    rewrite : bool
        Algebraic rewrite pass between trace and plan (default True):
        ``Traced.plan()`` generates semantically-equal DAG variants
        (:mod:`repro.core.rewrite`), verifies each (RW001–RW004), plans
        the clean ones, and selects the global cost argmin;
        ``explain()["rewrite"]`` reports the sweep.  False plans the DAG
        exactly as written.

    A context is itself a context manager: ``with FusionContext(...):``
    scopes it onto a thread-local stack that :func:`current_context`
    reads; :meth:`with_` derives a modified copy (contexts are frozen).
    """

    mode: str = "gen"
    pallas: str = "never"
    staged: bool = True
    params: CostParams = field(default_factory=lambda: TPU_V5E)
    layout: Optional[Any] = None        # FusionLayout (kept Any: no jax dep)
    verify: str = "cheap"               # "off" | "cheap" | "strict"
    rewrite: bool = True                # SPORES-style variant sweep in plan()

    def with_(self, **kw) -> "FusionContext":
        """Derived context with the given fields replaced."""
        return replace(self, **kw)

    def key(self) -> tuple:
        """Hashable identity used in plan-cache signatures — includes the
        cost-model constants (and any distributed geometry) so custom
        CostParams re-plan instead of silently reusing a plan selected
        under different bandwidths."""
        from .layout import layout_signature
        p = self.params
        pkey = (p.read_bw, p.write_bw, p.compute_bw, p.dtype_bytes,
                p.sparse_idx_bytes, p.max_fused_inputs,
                tuple(sorted(p.input_read_bw.items())),
                p.dist.signature() if p.dist is not None else None)
        return (self.mode, self.pallas, self.staged, pkey,
                layout_signature(self.layout), self.verify, self.rewrite)

    # -- scoping ------------------------------------------------------------
    def __enter__(self) -> "FusionContext":
        _stack().append(self)
        return self

    def __exit__(self, *exc) -> None:
        top = _stack().pop()
        assert top is self, "unbalanced FusionContext scopes"


def _stack() -> list:
    s = getattr(_STACK, "stack", None)
    if s is None:
        s = []
        _STACK.stack = s
    return s


_DEFAULT = FusionContext()


def current_context() -> FusionContext:
    """Innermost scoped context, or the process-wide default."""
    s = _stack()
    return s[-1] if s else _DEFAULT


# backwards-compatible alias (pre-staged-API name)
current_config = current_context


@contextlib.contextmanager
def fusion_mode(mode: Optional[str] = None, pallas: Optional[str] = None,
                params: Optional[CostParams] = None, layout: Any = None,
                staged: Optional[bool] = None,
                verify: Optional[str] = None,
                rewrite: Optional[bool] = None):
    """Sugar: scope a context derived from the current one."""
    kw = {}
    if mode is not None:
        kw["mode"] = mode
    if pallas is not None:
        kw["pallas"] = pallas
    if params is not None:
        kw["params"] = params
    if layout is not None:
        kw["layout"] = layout
    if staged is not None:
        kw["staged"] = staged
    if verify is not None:
        kw["verify"] = verify
    if rewrite is not None:
        kw["rewrite"] = rewrite
    ctx = current_context().with_(**kw)
    with ctx:
        yield ctx
