"""Layout-aware fused execution: thread the PR-2 distributed layout rules
onto fused-operator inputs/outputs.

A :class:`FusionLayout` maps fused-region input/output names to
rank-matched, divisibility-checked ``PartitionSpec``s built with the same
``repro.dist.sharding`` fitting primitives the layout planner validates
candidates with: matrix rows shard over the data/FSDP axes, columns over
the tensor-parallel axis, vectors and scalars degrade to replication.

Two consumers, one entry point (the paper's hybrid local/distributed
plans):

* **planning** — :func:`layout_cost_params` turns the layout into cost
  geometry for candidate selection:

  - reads of column-sharded (model-parallel) side inputs are re-priced at
    ICI all-gather bandwidth (``core.cost.CostParams.input_read_bw``,
    paper §4.4) for the *local* arm, and
  - a :class:`~repro.core.cost.DistParams` is attached describing the
    row-shard group (the mesh's data/FSDP axes) and the per-input shard
    factors read off the spec trees, which enables the *distributed* cost
    arm — selection then enumerates ``local × distributed`` per fused
    operator and the induced plan is hybrid.

  This accepts any mesh exposing ``.shape``/``.axis_names`` — including
  the planner's abstract ``LogicalMesh`` — so hybrid plans can be costed
  for a 256-chip pod from a CPU container.
* **execution** — :meth:`FusionLayout.apply` places/constrains dense
  operands with ``NamedSharding`` on a *real* ``jax.sharding.Mesh``;
  locally-placed fused operators then run SPMD under ``jit``, while
  operators the plan placed *distributed* run their generated body inside
  ``shard_map`` with the template's collective epilogue
  (:mod:`repro.kernels.distributed`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping, Optional

from repro import hw as _hw
from .cost import CostParams, DistParams
from .ir import Graph


def _mesh_sig(mesh) -> tuple:
    return tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)


def layout_signature(layout) -> Optional[tuple]:
    """Hashable identity of a layout-ish object: a :class:`FusionLayout`,
    a bare mesh (``.shape``/``.axis_names``), or None."""
    if layout is None:
        return None
    if hasattr(layout, "key"):
        return layout.key()
    if hasattr(layout, "axis_names"):
        return _mesh_sig(layout)
    return ("opaque", id(layout))


@dataclass(frozen=True)
class FusionLayout:
    """Mesh + per-name PartitionSpecs for a fused region's inputs/outputs.

    Built either explicitly (``FusionLayout(mesh, {"X": P("data", None)})``)
    or via :meth:`auto`, which fits the PR-1/2 sharding rules to the
    region's operand shapes.  Passing a bare mesh to ``Traced.plan(layout=)``
    or scoping one through ``FusionContext(layout=mesh)`` auto-fits it the
    same way."""

    mesh: Any
    specs: Any            # Mapping[str, PartitionSpec-like]

    @staticmethod
    def auto(mesh, shapes: Mapping[str, tuple[int, int]]) -> "FusionLayout":
        """Fit the PR-1/2 sharding rules to a dict of 2-D operand shapes:
        rows over the FSDP axes, columns over the TP axis, each entry
        divisibility-checked with per-dim degradation to replication."""
        from repro.dist import sharding as sh
        specs = {name: sh.operand_spec(mesh, shape)
                 for name, shape in shapes.items()}
        return FusionLayout(mesh, specs)

    def key(self) -> tuple:
        return (_mesh_sig(self.mesh),
                tuple(sorted((n, tuple(s)) for n, s in self.specs.items())))

    def spec_for(self, name: str):
        return self.specs.get(name)

    def shard_factors(self, name: str) -> tuple[int, int]:
        """(row, col) shard degrees of one named operand (1 ≡ replicated)."""
        from repro.dist import sharding as sh
        spec = self.specs.get(name)
        if spec is None:
            return (1, 1)
        entries = tuple(spec)
        r = sh.axis_size(self.mesh, entries[0]) if len(entries) >= 1 else 1
        c = sh.axis_size(self.mesh, entries[1]) if len(entries) >= 2 else 1
        return (r, c)

    def _shards_cols(self, name: str, shape: tuple[int, int]) -> bool:
        return self.shard_factors(name)[1] > 1

    def row_axes(self) -> tuple[str, ...]:
        """The row-shard group: every non-tensor-parallel mesh axis."""
        from repro.dist import sharding as sh
        return sh.fsdp_axes(self.mesh)

    def row_devices(self) -> int:
        """Total row-shard degree (Π row-axis sizes; 1 on a 1-D TP mesh)."""
        from repro.dist import sharding as sh
        return sh.axis_size(self.mesh, self.row_axes())

    def apply(self, name: str, value):
        """Constrain/place one dense operand on its spec (identity when the
        name has no spec, the value is sparse, or the mesh is abstract)."""
        spec = self.specs.get(name)
        if spec is None or hasattr(value, "todense"):
            return value
        import jax
        from jax.sharding import Mesh, NamedSharding
        if not isinstance(self.mesh, Mesh):
            return value                  # abstract mesh: cost-only layout
        sharding = NamedSharding(self.mesh, spec)
        if isinstance(value, jax.core.Tracer):
            return jax.lax.with_sharding_constraint(value, sharding)
        return jax.device_put(value, sharding)


def ensure_layout(layout, graph: Graph,
                  extra_shapes: Optional[Mapping] = None) -> FusionLayout:
    """Coerce a layout-ish object into a :class:`FusionLayout` for this
    graph: bare meshes are auto-fitted to the graph's input and output
    shapes (``extra_shapes`` may add operand-name → shape entries)."""
    if isinstance(layout, FusionLayout):
        return layout
    shapes = {n.name: n.shape for n in graph.inputs() if n.name}
    shapes.update({f"__out{i}": o.shape
                   for i, o in enumerate(graph.outputs)})
    if extra_shapes:
        shapes.update(extra_shapes)
    return FusionLayout.auto(layout, shapes)


def layout_cost_params(layout: Optional[FusionLayout], graph: Graph,
                       params: CostParams) -> CostParams:
    """Cost parameters carrying the layout's distributed geometry.

    Two effects (both no-ops without a layout):

    * inputs whose layout shards the column (contraction-side) dimension
      must be all-gathered across the model axis before a row-local fused
      operator can consume them — the local arm prices their reads at ICI
      bandwidth instead of HBM bandwidth (the paper's "different read
      bandwidths for inputs of resulting distributed operations");
    * a :class:`~repro.core.cost.DistParams` describing the row-shard
      group and per-input shard factors enables the distributed cost arm,
      so selection can choose mesh-wide execution per fused operator.
    """
    if layout is None:
        return params
    if not isinstance(layout, FusionLayout):
        layout = ensure_layout(layout, graph)
    overrides = dict(params.input_read_bw)
    row_factor: dict[int, int] = {}
    col_factor: dict[int, int] = {}
    for node in graph.inputs():
        if not node.name:
            continue
        r, c = layout.shard_factors(node.name)
        if r > 1:
            row_factor[node.nid] = r
        if c > 1:
            col_factor[node.nid] = c
            overrides[node.nid] = _hw.TPU_V5E.ici_bw
    axes = layout.row_axes()
    n = layout.row_devices()
    dist = DistParams(axes=tuple(axes), n=n, ici_bw=_hw.TPU_V5E.ici_bw,
                      row_factor=row_factor, col_factor=col_factor) \
        if n > 1 else None
    if not overrides and dist is None:
        return params
    return CostParams(read_bw=params.read_bw, write_bw=params.write_bw,
                      compute_bw=params.compute_bw,
                      dtype_bytes=params.dtype_bytes,
                      sparse_idx_bytes=params.sparse_idx_bytes,
                      input_read_bw=overrides,
                      max_fused_inputs=params.max_fused_inputs,
                      dist=dist)
