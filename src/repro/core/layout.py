"""Layout-aware fused execution: thread the PR-2 distributed layout rules
onto fused-operator inputs/outputs.

A :class:`FusionLayout` maps fused-region input/output names to
rank-matched, divisibility-checked ``PartitionSpec``s built with the same
``repro.dist.sharding`` fitting primitives the layout planner validates
candidates with: matrix rows shard over the data/FSDP axes, columns over
the tensor-parallel axis, vectors and scalars degrade to replication.

Two consumers, one entry point (the paper's hybrid local/distributed
plans):

* **planning** — :func:`layout_cost_params` re-prices reads of
  column-sharded (model-parallel) side inputs at ICI all-gather bandwidth
  (``core.cost.CostParams.input_read_bw``, paper §4.4), so candidate
  selection sees distributed read costs.  This accepts any mesh exposing
  ``.shape``/``.axis_names`` — including the planner's abstract
  ``LogicalMesh`` — so plans can be costed for a 256-chip pod from a CPU
  container.
* **execution** — :meth:`FusionLayout.apply` places/constrains dense
  operands with ``NamedSharding`` on a *real* ``jax.sharding.Mesh``; the
  fused computation then runs SPMD under ``jit``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping, Optional

from repro import hw as _hw
from .cost import CostParams
from .ir import Graph


def _mesh_sig(mesh) -> tuple:
    return tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)


@dataclass(frozen=True)
class FusionLayout:
    """Mesh + per-name PartitionSpecs for a fused region's inputs/outputs."""

    mesh: Any
    specs: Any            # Mapping[str, PartitionSpec-like]

    @staticmethod
    def auto(mesh, shapes: Mapping[str, tuple[int, int]]) -> "FusionLayout":
        """Fit the PR-1/2 sharding rules to a dict of 2-D operand shapes."""
        from repro.dist import sharding as sh
        specs = {name: sh._spec(mesh, shape,
                                (sh.fsdp_axes(mesh), sh.tp_axis(mesh)))
                 for name, shape in shapes.items()}
        return FusionLayout(mesh, specs)

    def key(self) -> tuple:
        return (_mesh_sig(self.mesh),
                tuple(sorted((n, tuple(s)) for n, s in self.specs.items())))

    def spec_for(self, name: str):
        return self.specs.get(name)

    def _shards_cols(self, name: str, shape: tuple[int, int]) -> bool:
        spec = self.specs.get(name)
        if spec is None:
            return False
        entries = tuple(spec)
        return len(entries) >= 2 and entries[1] is not None

    def apply(self, name: str, value):
        """Constrain/place one dense operand on its spec (identity when the
        name has no spec, the value is sparse, or the mesh is abstract)."""
        spec = self.specs.get(name)
        if spec is None or hasattr(value, "todense"):
            return value
        import jax
        from jax.sharding import Mesh, NamedSharding
        if not isinstance(self.mesh, Mesh):
            return value                  # abstract mesh: cost-only layout
        sharding = NamedSharding(self.mesh, spec)
        if isinstance(value, jax.core.Tracer):
            return jax.lax.with_sharding_constraint(value, sharding)
        return jax.device_put(value, sharding)


def layout_cost_params(layout: Optional[FusionLayout], graph: Graph,
                       params: CostParams) -> CostParams:
    """Cost parameters with distributed read-bandwidth overrides.

    Inputs whose layout shards the column (contraction-side) dimension must
    be all-gathered across the model axis before a row-local fused operator
    can consume them — their reads are priced at ICI bandwidth instead of
    HBM bandwidth (the paper's "different read bandwidths for inputs of
    resulting distributed operations").
    """
    if layout is None:
        return params
    overrides = dict(params.input_read_bw)
    for node in graph.inputs():
        if node.name and layout._shards_cols(node.name, node.shape):
            overrides[node.nid] = _hw.TPU_V5E.ici_bw
    if not overrides:
        return params
    return CostParams(read_bw=params.read_bw, write_bw=params.write_bw,
                      compute_bw=params.compute_bw,
                      dtype_bytes=params.dtype_bytes,
                      sparse_idx_bytes=params.sparse_idx_bytes,
                      input_read_bw=overrides,
                      max_fused_inputs=params.max_fused_inputs)
