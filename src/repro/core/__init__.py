"""Cost-based operator-fusion-plan optimization (the paper's contribution).

Pipeline: IR (HOP DAG) → OFMC candidate exploration (memo table) →
cost-based candidate selection (plan partitions, interesting points,
MPSkipEnum) → code generation (CPlans → XLA/Pallas fused operators, plan
cache).

Public surface: the staged API (``fused(fn).trace(...).plan(...)
.compile()``), its ``@fused`` call sugar, immutable
:class:`FusionContext` scoping, layout-aware execution
(:class:`FusionLayout`), and plan-cache introspection.  The module
``__all__`` below is pinned by ``tests/test_api_surface.py`` — extending
it is an explicit, reviewed act.
"""

from . import ir
from .api import (Compiled, Fused, FusionInputError, Planned, Traced,
                  fuse_exprs, fused)
from .codegen import plan_cache_stats, whole_plan_cache_stats
from .context import (FusionContext, current_config, current_context,
                      fusion_mode)
from .cost import CostParams, TPU_V5E
from .grad import NonDifferentiableError
from .layout import FusionLayout
from .partitions import PlanInvariantError
from .select import plan
from .verify import (Diagnostic, VerificationError, VerifyReport,
                     verify_plan)

__all__ = [
    # IR + planning entry points
    "ir", "plan",
    # staged pipeline
    "Fused", "fused", "Traced", "Planned", "Compiled", "fuse_exprs",
    # contexts
    "FusionContext", "fusion_mode", "current_context", "current_config",
    # layout-aware execution
    "FusionLayout",
    # cost model
    "CostParams", "TPU_V5E",
    # plan verifier
    "Diagnostic", "VerifyReport", "verify_plan",
    # introspection + errors
    "plan_cache_stats", "whole_plan_cache_stats",
    "NonDifferentiableError", "FusionInputError",
    "PlanInvariantError", "VerificationError",
]
