"""Cost-based operator-fusion-plan optimization (the paper's contribution).

Pipeline: IR (HOP DAG) → OFMC candidate exploration (memo table) →
cost-based candidate selection (plan partitions, interesting points,
MPSkipEnum) → code generation (CPlans → XLA/Pallas fused operators, plan
cache).
"""

from . import ir
from .api import Fused, fuse_exprs, fused, fusion_mode, current_config
from .cost import CostParams, TPU_V5E
from .select import plan

__all__ = ["ir", "Fused", "fused", "fuse_exprs", "fusion_mode",
           "current_config", "CostParams", "TPU_V5E", "plan"]
