"""Code-generation plans (CPlans) — paper §2.2.

A CPlan is the backend-independent representation of one fused operator:
a template type + variant, a *data binding* (main input, side inputs,
scalars), and a DAG of basic operations (the CNode program).  Code
generation expands the template skeleton and splices the program in; here
the "generated code" is a traced function — the program is interpreted at
JAX/Pallas **trace time**, so the emitted kernel/XLA computation is exactly
as fused as SystemML's janino-compiled operator (zero interpretation
overhead at run time).

CPlans hash structurally (ops, shapes, binding, variant) — the key of the
plan cache (paper §2.1 "identifies equivalent CPlans via hashing").
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional

from .cost import FusedOpSpec
from .ir import Graph, Node
from .select import MultiAggSpec
from .templates import TType

# variants (paper Table 1)
NO_AGG, ROW_AGG, COL_AGG, FULL_AGG, COL_T_AGG, RIGHT_MM, LEFT_MM = (
    "no_agg", "row_agg", "col_agg", "full_agg", "col_t_agg",
    "right_mm", "left_mm")


@dataclass
class CBind:
    """One bound input of the fused operator."""
    nid: int
    kind: str                 # "main" | "side" | "scalar" | "factor_u" | "factor_v"
    shape: tuple[int, int]
    sparsity: float = 1.0
    #: True iff the planner certified the chain sparse-safe w.r.t. this
    #: (main) input — gates the block-sparse execution path.
    exploit: bool = False


@dataclass
class CPlan:
    ttype: TType
    variant: str
    agg_op: str                          # sum/min/max/mean ('' if none)
    binds: list[CBind]                   # main first
    #: covered nodes in topo order: (nid, op, input keys, shape, attrs)
    #: input key: ('n', nid) covered node | ('b', bind index) bound input
    prog: list[tuple]
    prog_root: int                       # nid whose value the skeleton closes
    out_shape: tuple[int, int]
    roots: tuple[int, ...] = ()          # >1 for multi-aggregates
    #: per extra root (multi-agg): (prog_root, agg_op)
    extra: tuple[tuple[int, str], ...] = ()
    close_tb: bool = False               # right_mm: chain @ t(V)?
    #: second operand of the closing matmul (col_t_agg: X; right_mm: V;
    #: left_mm: U) — a bind nid or a covered node computed by the program.
    close_nid: Optional[int] = None

    @property
    def main(self) -> CBind:
        return self.binds[0]

    def side_binds(self) -> list[CBind]:
        return [b for b in self.binds[1:]]

    def cache_key(self) -> str:
        """Structural hash: node ids canonicalized to local indices so that
        re-traced but structurally identical CPlans hit the plan cache."""
        local: dict[int, str] = {b.nid: f"b{i}"
                                 for i, b in enumerate(self.binds)}
        for j, (nid, *_rest) in enumerate(self.prog):
            local[nid] = f"n{j}"

        def canon(ref):
            kind, r = ref
            return (kind, local.get(r, r) if kind in ("n", "b") else r)

        h = hashlib.sha256()
        h.update(repr((
            self.ttype, self.variant, self.agg_op,
            [(b.kind, b.shape, round(b.sparsity, 6), b.exploit)
             for b in self.binds],
            [(op, tuple(canon(i) for i in ins), shape, attrs)
             for (_, op, ins, shape, attrs) in self.prog],
            local.get(self.prog_root, self.prog_root),
            self.out_shape, self.close_tb,
            local.get(self.close_nid, self.close_nid),
            tuple((local.get(pr, pr), op) for pr, op in self.extra),
        )).encode())
        return h.hexdigest()[:16]


# --------------------------------------------------------------------------
# CPlan construction from a selected fusion plan
# --------------------------------------------------------------------------

def build_cplan(graph: Graph, spec) -> CPlan:
    if isinstance(spec, MultiAggSpec):
        return _build_multi_agg(graph, spec)
    assert isinstance(spec, FusedOpSpec) and spec.ttype is not None
    root = graph.by_id[spec.root]
    cover = set(spec.cover)

    variant, agg_op, prog_root, close_operand = _variant_of(
        graph, spec.ttype, root, cover)

    inputs = _effective_inputs(graph, spec, cover)
    binds = _bind_inputs(graph, spec, inputs, prog_root, close_operand)
    roots = [prog_root] + ([close_operand] if close_operand is not None
                           else [])
    prog = _linearize(graph, cover, {b.nid for b in binds}, *roots)
    return CPlan(spec.ttype, variant, agg_op, binds, prog, prog_root,
                 root.shape, roots=(spec.root,),
                 close_tb=bool(root.is_matmul and root.tb),
                 close_nid=close_operand)


def _build_multi_agg(graph: Graph, spec: MultiAggSpec) -> CPlan:
    binds: list[CBind] = []
    bound: set[int] = set()
    for part in spec.parts:
        cp = build_cplan(graph, part)
        for b in cp.binds:
            if b.nid not in bound:
                bound.add(b.nid)
                binds.append(b)
    # keep exactly one main (the first); demote other mains to sides
    main_seen = False
    norm: list[CBind] = []
    for b in binds:
        if b.kind == "main":
            if main_seen:
                b = CBind(b.nid, "side", b.shape, b.sparsity)
            main_seen = True
        norm.append(b)
    norm.sort(key=lambda b: b.kind != "main")
    cover: set[int] = set()
    for part in spec.parts:
        cover.update(part.cover)
    roots = [graph.by_id[r] for r in spec.roots]
    prog_roots = [r.inputs[0].nid for r in roots]
    prog = _linearize(graph, cover, {b.nid for b in norm}, *prog_roots)
    return CPlan(TType.MAGG, FULL_AGG, roots[0].op, norm, prog,
                 prog_roots[0], (len(roots), 1),
                 roots=tuple(spec.roots),
                 extra=tuple((pr, r.op) for pr, r in
                             zip(prog_roots[1:], roots[1:])))


def _variant_of(graph: Graph, ttype: TType, root: Node, cover: set[int]):
    """(variant, agg_op, prog_root, close_operand_nid)."""
    if root.is_agg:
        ax = root.agg_axis
        variant = {"full": FULL_AGG, "row": ROW_AGG, "col": COL_AGG}[ax]
        return variant, root.op, root.inputs[0].nid, None
    if root.is_matmul and ttype == TType.ROW:
        if root.ta and not root.tb:
            # t(X) @ chain — column-transposed aggregation
            return COL_T_AGG, "sum", root.inputs[1].nid, root.inputs[0].nid
        # (chain) @ B — stays row-wise; the matmul runs inside the program.
        # (t(A) @ t(B) also lands here defensively: the program evaluates
        # the matmul with both transpose flags — templates refuse to open
        # such roots, see templates._narrow_mm.)
        return NO_AGG, "", root.nid, None
    if root.is_matmul and ttype == TType.OUTER:
        a, b = root.inputs
        if root.ta:      # t(chain) @ U  — left_mm
            return LEFT_MM, "sum", b.nid, a.nid
        return RIGHT_MM, "sum", a.nid, b.nid
    return NO_AGG, "", root.nid, None


#: public accessor for the plan verifier and cost model — the
#: (variant, agg_op, prog_root, close_operand_nid) classification is the
#: single source of a fused operator's execution variant
variant_of = _variant_of


def _effective_inputs(graph: Graph, spec: FusedOpSpec,
                      cover: set[int]) -> list[int]:
    """Spec inputs, with covered idx-nodes over raw inputs folded: the
    wrapper slices the base matrix, so the idx node acts as the leaf."""
    inputs = list(spec.inputs)
    for nid in cover:
        n = graph.by_id[nid]
        if n.op == "idx" and n.inputs[0].nid in inputs:
            pass                       # base stays; idx evaluated in program
    return inputs


def _bind_inputs(graph: Graph, spec: FusedOpSpec, inputs: list[int],
                 prog_root: int, close_operand: Optional[int]) -> list[CBind]:
    inputs = [i for i in inputs if graph.by_id[i].op != "lit"]
    nodes = {i: graph.by_id[i] for i in inputs}
    scalars = [i for i in inputs if nodes[i].is_scalar]
    mats = [i for i in inputs if not nodes[i].is_scalar]

    main: Optional[int] = None
    factor_u: Optional[int] = None
    factor_v: Optional[int] = None

    if spec.ttype == TType.OUTER:
        mm = _find_outer_mm(graph, spec)
        a, b = mm.inputs
        factor_u, factor_v = a.nid, b.nid
        main = spec.driver
        if main is None:   # structurally guaranteed by close(), but be safe
            cands = [i for i in mats if i not in (factor_u, factor_v)]
            main = cands[0] if cands else factor_u
    elif spec.driver is not None:
        main = spec.driver
    if main is None:
        # largest matrix whose rows match the iteration domain
        target_rows = graph.by_id[close_operand].shape[0] if close_operand \
            else graph.by_id[prog_root].shape[0]
        ranked = sorted(
            mats, key=lambda i: (nodes[i].shape[0] == target_rows,
                                 nodes[i].ncells), reverse=True)
        main = ranked[0] if ranked else scalars[0]

    binds = [CBind(main, "main", nodes.get(main, graph.by_id[main]).shape,
                   graph.by_id[main].sparsity,
                   exploit=(spec.driver == main
                            or spec.ttype == TType.OUTER))]
    if close_operand is not None and close_operand not in inputs \
            and spec.ttype == TType.ROW:
        # col_t_agg closes against X, which may equal main — nothing to add
        pass
    for i in inputs:
        if i == main:
            continue
        kind = "scalar" if graph.by_id[i].is_scalar else "side"
        if i == factor_u:
            kind = "factor_u"
        elif i == factor_v:
            kind = "factor_v"
        binds.append(CBind(i, kind, graph.by_id[i].shape,
                           graph.by_id[i].sparsity))
    return binds


def _find_outer_mm(graph: Graph, spec: FusedOpSpec) -> Node:
    from .templates import _outer_mm
    for nid in spec.cover:
        n = graph.by_id[nid]
        if n.is_matmul and _outer_mm(n):
            return n
    raise AssertionError("outer template without outer matmul")


def _linearize(graph: Graph, cover: set[int], bound: set[int],
               *roots: int) -> list[tuple]:
    """Topo-ordered program over covered nodes reachable from the roots."""
    order: list[tuple] = []
    seen: set[int] = set()

    def visit(nid: int) -> None:
        if nid in seen or nid in bound:
            return
        seen.add(nid)
        node = graph.by_id[nid]
        assert nid in cover or node.is_input or node.op == "lit", \
            f"node {node} escapes cover"
        ins = []
        for i in node.inputs:
            if i.nid in bound or (i.nid not in cover and not i.op == "lit"):
                ins.append(("b", i.nid))
            elif i.op == "lit":
                ins.append(("l", float(i.attrs["value"])))
            else:
                visit(i.nid)
                ins.append(("n", i.nid))
        order.append((nid, node.op, tuple(ins), node.shape,
                      tuple(sorted(node.attrs.items()))))

    for r in roots:
        if r not in bound:
            visit(r)
    return order
