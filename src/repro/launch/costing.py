"""Honest HLO cost accounting for scanned programs.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so a
64-layer scanned model under-reports FLOPs by ~the trip count.  Two
complementary corrections:

1. **Probe extrapolation (FLOPs / bytes)** — lower *unrolled* miniature
   variants of the same cell (G ∈ {1,2} layer groups, M ∈ {1,2}
   microbatches, dense attention) on a small mesh and solve the affine
   model  f(G,M) = o₀ + o₁·G + M·(b + c·G)  for the per-group (c),
   per-microbatch (b) and optimizer (o₁,o₀) components, then evaluate at
   the production (G,M).  Costs that live inside *sequence* scans
   (Mamba/mLSTM cells, chunked-attention recompute) are added
   analytically — they are simple closed forms.

2. **Trip-corrected collectives** — parse the *production* compiled HLO,
   build the computation call graph, multiply each while body's
   collective bytes by its trip count (read from the loop condition's
   bound constant).

Everything is derived from compiled artifacts of the real programs; no
wall-clock measurement is involved (CPU container, TPU target).
"""

from __future__ import annotations

import json
import re
from dataclasses import replace
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lm import build_pattern

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "costing"


# ---------------------------------------------------------------------------
# trip-corrected collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->.*{")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=(%?[\w\.\-]+),"
                       r"\s*body=(%?[\w\.\-]+)", re.S)
#: non-while call edges only — while body/condition are handled with trip
#: counts by _WHILE_RE (listing them here would double count).
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                      r"(%?[\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _result_bytes(lhs: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo(hlo: str):
    """computations: name -> {lines}, whiles per computation, trip counts."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and "->" in line and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def trip_count(comps: dict, cond_name: str) -> int:
    lines = comps.get(cond_name, [])
    consts = [int(c) for l in lines for c in _CONST_RE.findall(l)]
    return max(consts) if consts else 1


def corrected_collectives(hlo: str) -> dict:
    """Per-kind collective bytes with while bodies multiplied by trips."""
    comps = parse_hlo(hlo)
    direct: dict[str, dict] = {}
    children: dict[str, list[tuple[str, int]]] = {}
    for name, lines in comps.items():
        d = {k: 0.0 for k in _COLLECTIVES}
        ch: list[tuple[str, int]] = []
        for line in lines:
            for kind in _COLLECTIVES:
                if f" {kind}(" in line or f" {kind}-start(" in line:
                    parts = line.split("=", 1)
                    if len(parts) == 2:
                        d[kind] += _result_bytes(parts[1].split(kind)[0])
                    break
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                ch.append((body, trip_count(comps, cond)))
            else:
                for callee in _CALL_RE.findall(line):
                    if callee in comps:
                        ch.append((callee, 1))
        direct[name] = d
        children[name] = ch

    memo: dict[str, dict] = {}

    def effective(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        if depth > 50:
            return {k: 0.0 for k in _COLLECTIVES}
        acc = dict(direct.get(name, {k: 0.0 for k in _COLLECTIVES}))
        for callee, trips in children.get(name, []):
            sub = effective(callee, depth + 1)
            for k in _COLLECTIVES:
                acc[k] += trips * sub[k]
        memo[name] = acc
        return acc

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    res = effective(entry) if entry else {k: 0.0 for k in _COLLECTIVES}
    res["total"] = sum(res[k] for k in _COLLECTIVES)
    return res


# ---------------------------------------------------------------------------
# probe extrapolation for FLOPs / bytes
# ---------------------------------------------------------------------------

def _probe_cfg(cfg: ModelConfig, n_groups: int) -> ModelConfig:
    P = len(build_pattern(cfg))
    return replace(cfg, n_layers=n_groups * P, scan_layers=False,
                   attn_chunk=0)


def _measure(cfg, shape, mesh, n_mb: int) -> tuple[float, float]:
    """(total flops, total bytes) of one probe variant."""
    from repro.launch import dryrun_lib as dl
    from repro.launch import serve as serve_lib
    from repro.launch import train as train_lib
    from repro.dist import sharding as sh
    from repro.models import LM
    from repro.optim import adamw
    from jax.sharding import NamedSharding, PartitionSpec as P_

    model = LM(cfg)
    params_abs = dl.abstract_params(model)
    pspecs = sh.named(mesh, sh.param_specs(mesh, cfg, params_abs))
    if shape.kind == "train":
        tc = train_lib.TrainConfig(n_microbatches=n_mb, unroll_mb=True)
        step = train_lib.make_train_step(model, cfg, tc)
        opt_abs = jax.eval_shape(lambda p: adamw.init(p, tc.opt), params_abs)
        ospecs = {"m": sh.param_specs(mesh, cfg, params_abs),
                  "v": sh.param_specs(mesh, cfg, params_abs),
                  "count": P_()}
        batch_abs = train_lib.train_batch_specs(cfg, shape)
        bspecs = jax.tree_util.tree_map(
            lambda s: sh.batch_spec(mesh, cfg, s.shape[0],
                                    len(s.shape) - 1), batch_abs)
        comp = jax.jit(step, in_shardings=(
            pspecs, sh.named(mesh, ospecs), sh.named(mesh, bspecs))
        ).lower(params_abs, opt_abs, batch_abs).compile()
    elif shape.kind == "prefill":
        pre = serve_lib.make_prefill_step(model, cfg)
        cache_abs = serve_lib.cache_specs_abstract(model, shape)
        cspecs = sh.cache_specs(mesh, cfg, shape, cache_abs)
        batch_abs = serve_lib.prefill_specs(cfg, shape)
        tspec = sh.batch_spec(mesh, cfg, shape.global_batch,
                              len(batch_abs["tokens"].shape) - 1)
        comp = jax.jit(lambda p, t, c: pre(p, t, c), in_shardings=(
            pspecs, NamedSharding(mesh, tspec), sh.named(mesh, cspecs))
        ).lower(params_abs, batch_abs["tokens"], cache_abs).compile()
    else:
        step = serve_lib.make_serve_step(model, cfg)
        cache_abs = serve_lib.cache_specs_abstract(model, shape)
        cspecs = sh.cache_specs(mesh, cfg, shape, cache_abs)
        dspecs = serve_lib.decode_specs(cfg, shape)
        tspec = sh.batch_spec(mesh, cfg, shape.global_batch,
                              len(dspecs["token"].shape) - 1)
        comp = jax.jit(step, in_shardings=(
            pspecs, sh.named(mesh, cspecs), NamedSharding(mesh, tspec),
            NamedSharding(mesh, P_()))
        ).lower(params_abs, cache_abs, dspecs["token"],
                dspecs["pos"]).compile()
    ca = dl.cost_analysis_dict(comp)
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    return (float(ca.get("flops", 0.0)) * n_dev,
            float(ca.get("bytes accessed", 0.0)) * n_dev)


def _seq_scan_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic FLOPs living inside sequence scans (counted once by the
    probes): Mamba/mLSTM cell steps and chunked-attention recompute."""
    pattern = build_pattern(cfg)
    L = cfg.n_layers
    per = len(pattern)
    if shape.kind == "train":
        B, S = shape.global_batch, shape.seq_len
        bwd_mult = 3.0       # fwd + ~2x bwd (scan body differentiated)
    elif shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        bwd_mult = 1.0
    else:
        return 0.0           # decode: single step, fully counted

    total = 0.0
    n_mamba = sum(s.kind == "mamba" for s in pattern) * (L // per)
    n_mlstm = sum(s.kind == "mlstm" for s in pattern) * (L // per)
    n_attn = sum(s.kind == "attn" for s in pattern) * (L // per)
    if n_mamba:
        di, N = cfg.ssm_expand * cfg.d_model, cfg.ssm_state
        total += n_mamba * B * S * di * N * 26.0 * bwd_mult
    if n_mlstm:
        di = cfg.ssm_expand * cfg.d_model
        hd = di // cfg.n_heads
        total += n_mlstm * B * S * cfg.n_heads * hd * hd * 5.5 * bwd_mult
    if shape.kind == "train" and cfg.attn_chunk and n_attn:
        # chunk-body remat: one extra attention forward in the backward
        # (0.5 ≈ causal-mask effective score density)
        for s in pattern:
            if s.kind != "attn":
                continue
            s_eff = min(s.window or S, S)
            total += (L // per) * 4.0 * B * S * s_eff \
                * cfg.n_heads * cfg.hd * 0.5
    return total


def probe_cell(arch: str, shape_name: str, probe_mesh, *,
               save: bool = True, force: bool = False,
               variant: dict | None = None, variant_tag: str = "") -> dict:
    """Extrapolated total (flops, bytes) for the production cell."""
    tag = f"{arch}__{shape_name}" + (f"__{variant_tag}" if variant_tag
                                     else "")
    out_path = RESULTS_DIR / f"{tag}.json"
    if save and out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if variant:
        from repro.launch.dryrun_lib import apply_variant
        cfg = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    P = len(build_pattern(cfg))
    G_full = cfg.n_layers / P

    if shape.kind == "train":
        from repro.launch import train as train_lib
        f = {}
        b = {}
        for (g, m) in ((1, 1), (2, 1), (1, 2), (2, 2)):
            f[(g, m)], b[(g, m)] = _measure(_probe_cfg(cfg, g), shape,
                                            probe_mesh, m)

        def extrap(v):
            c = v[(2, 2)] - v[(2, 1)] - v[(1, 2)] + v[(1, 1)]
            bb = v[(1, 2)] - v[(1, 1)] - c
            o1 = v[(2, 1)] - v[(1, 1)] - c
            o0 = v[(1, 1)] - o1 - bb - c
            M = train_lib.default_microbatches(cfg, shape, 16)
            return o0 + o1 * G_full + M * (bb + c * G_full)

        flops, bytes_ = extrap(f), extrap(b)
    else:
        f1, b1 = _measure(_probe_cfg(cfg, 1), shape, probe_mesh, 1)
        f2, b2 = _measure(_probe_cfg(cfg, 2), shape, probe_mesh, 1)
        cf, cb = f2 - f1, b2 - b1
        flops = (f1 - cf) + cf * G_full
        bytes_ = (b1 - cb) + cb * G_full

    flops += _seq_scan_flops(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "total_flops": flops, "total_bytes": bytes_}
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    """Probe every live cell (run under a small host-device count)."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    from repro.configs import all_configs, cells
    from repro.dist.compat import auto_axis_types
    probe_mesh = jax.make_mesh(
        (4, 2), ("data", "model"), axis_types=auto_axis_types(2))
    for arch, shape in cells(all_configs()):
        try:
            rec = probe_cell(arch, shape, probe_mesh, force=args.force)
            print(f"OK   {arch:18s} {shape:12s} "
                  f"flops={rec['total_flops']:.3e} "
                  f"bytes={rec['total_bytes']:.3e}", flush=True)
        except Exception as e:
            print(f"FAIL {arch} {shape}: {type(e).__name__}: {e}",
                  flush=True)


if __name__ == "__main__":
    main()
