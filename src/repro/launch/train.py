"""Training step factory + CLI driver.

``make_train_step`` builds the jit-able global-SPMD step: microbatch
gradient accumulation (lax.scan; the scan body is also where XLA's
latency-hiding scheduler overlaps FSDP all-gathers with compute), fused
softmax-CE loss through the paper's planner (Row template) when
``fusion`` is enabled, AdamW update on fully-sharded state.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import LM, lm_loss
from repro.models.lm import N_PATCHES
from repro.optim import adamw


@dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    moe_aux_weight: float = 0.01
    fusion: str = "off"          # off | gen | fa | fnr  (planner arm)
    unroll_mb: bool = False      # python-loop microbatches (cost probes)
    #: mesh or FusionLayout for the fused-loss planner: the LSE Row chain
    #: iterates flattened (B·S) token rows, so under a layout the planner
    #: may place it distributed (row-partitioned, no collective) while the
    #: rest of the step stays under GSPMD.  None keeps local planning.
    fusion_layout: Optional[object] = None
    #: whole-plan staged execution of the fused loss (False: per-operator
    #: dispatch — the debug path; see repro.core.codegen.CompiledPlan)
    fusion_staged: bool = True
    opt: adamw.OptConfig = adamw.OptConfig()


def _fused_lse(logits2d: jnp.ndarray, mode: str,
               layout=None, staged: bool = True) -> jnp.ndarray:
    """log-sum-exp rows through the fusion planner (Row template:
    rowmax → sub → exp → rowsums → log → add), staged explicitly:
    trace → plan → compile once per (shape, mode, layout, staged), then
    reuse the Compiled operator — whole-plan jitted by default
    (``staged=False`` keeps per-operator dispatch for debugging).
    Differentiable: the training backward pass runs the planned gradient
    DAG via the operator's custom_vjp."""
    from repro.core import fused, ir
    from repro.core.layout import layout_signature

    if not hasattr(_fused_lse, "_lse"):
        @fused
        def _lse(L):
            m = L.rowmaxs()
            return ir.log(ir.exp(L - m).rowsums()) + m
        _fused_lse._lse = _lse
        _fused_lse._ops = {}
    key = (tuple(logits2d.shape), mode, layout_signature(layout), staged)
    op = _fused_lse._ops.get(key)
    if op is None:
        op = _fused_lse._lse.trace(logits2d) \
                            .plan(mode=mode, layout=layout) \
                            .compile(staged=staged)
        _fused_lse._ops[key] = op
    return op(logits2d)


def make_loss_fn(model: LM, cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch):
        prefix = batch.get("patches")
        logits, _, aux = model.apply(params, batch["tokens"],
                                     prefix_emb=prefix)
        if prefix is not None:
            logits = logits[:, prefix.shape[1]:]
        targets = batch["targets"]
        if cfg.n_codebooks > 1:
            ce = jnp.mean(jnp.stack(
                [_ce(logits[..., c, :], targets[..., c], tc)
                 for c in range(cfg.n_codebooks)]))
        else:
            ce = _ce(logits, targets, tc)
        return ce + tc.moe_aux_weight * aux, ce
    return loss_fn


def _ce(logits, targets, tc: TrainConfig):
    if tc.fusion == "off":
        return lm_loss(logits, targets)
    V = logits.shape[-1]
    flat = logits.reshape(-1, V).astype(jnp.float32)
    lse = _fused_lse(flat, tc.fusion, layout=tc.fusion_layout,
                     staged=tc.fusion_staged)
    tgt = jnp.take_along_axis(flat, targets.reshape(-1, 1), axis=-1)
    return jnp.mean(lse - tgt)


def make_train_step(model: LM, cfg: ModelConfig, tc: TrainConfig):
    loss_fn = make_loss_fn(model, cfg, tc)

    def train_step(params, opt_state, batch):
        n_mb = tc.n_microbatches

        def split(x):
            return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

        mbs = jax.tree_util.tree_map(split, batch)
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            g_acc, loss_acc = acc
            (_, ce), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, loss_acc + ce), None

        if n_mb > 1 and tc.unroll_mb:
            acc = (zero, 0.0)
            for i in range(n_mb):
                mb = jax.tree_util.tree_map(lambda x: x[i], mbs)
                acc, _ = body(acc, mb)
            grads, loss_sum = acc
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
            loss = loss_sum / n_mb
        elif n_mb > 1:
            (grads, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
            loss = loss_sum / n_mb
        else:
            (_, loss), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, metrics = adamw.update(grads, opt_state,
                                                    params, tc.opt)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — shared with the dry-run)
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision":
        S = S - N_PATCHES            # total context = patches + tokens
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    specs = {
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "targets": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
    }
    if cfg.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, N_PATCHES, cfg.d_model), jnp.bfloat16)
    return specs


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                         dp: int) -> int:
    """Pick accumulation depth so per-microbatch activations fit HBM while
    the microbatch still shards over the data axes."""
    total = cfg.total_params
    want = 8 if total > 1e11 else (4 if total > 2e10 else 2)
    return max(1, min(want, shape.global_batch // dp))


# ---------------------------------------------------------------------------
# CLI driver: end-to-end training on the local host mesh
# ---------------------------------------------------------------------------

def main() -> None:
    import argparse
    from dataclasses import replace

    from repro.checkpoint import CheckpointStore
    from repro.data import DataConfig, ShardedLoader
    from repro.dist import sharding as sh
    from repro.launch.mesh import make_host_mesh
    from repro.train import LoopConfig, run_loop

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b")
    ap.add_argument("--preset", default="tiny",
                    choices=("tiny", "100m", "full"))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--fusion", default="off")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    elif args.preset == "100m":
        cfg = replace(cfg.reduced(), n_layers=8, d_model=512, n_heads=8,
                      n_kv_heads=min(8, max(1, cfg.n_kv_heads)),
                      head_dim=64, d_ff=2048 if cfg.d_ff else 0,
                      vocab=32_000)
    model = LM(cfg)
    mesh = make_host_mesh()
    params = model.init(jax.random.PRNGKey(0))
    pspecs = sh.named(mesh, sh.param_specs(mesh, cfg, params))
    params = jax.tree_util.tree_map(jax.device_put, params, pspecs)

    tc = TrainConfig(n_microbatches=1, fusion=args.fusion)
    opt_state = adamw.init(params, tc.opt)
    step_fn = jax.jit(make_train_step(model, cfg, tc),
                      donate_argnums=(0, 1))

    store = CheckpointStore(args.ckpt_dir)
    start = 0
    if args.resume and store.latest_step() is not None:
        tree, extra = store.restore({"params": params, "opt": opt_state})
        params, opt_state, start = tree["params"], tree["opt"], extra["step"]
        print(f"resumed from step {start}")

    loader = ShardedLoader(
        DataConfig(seq_len=args.seq, global_batch=args.batch,
                   vocab=cfg.vocab, n_codebooks=cfg.n_codebooks),
        start_step=start)
    cfg_loop = LoopConfig(total_steps=args.steps,
                          checkpoint_every=args.ckpt_every, log_every=5)

    def log(step, loss, dt, metrics):
        print(f"step {step:5d} loss {loss:.4f} "
              f"({dt * 1e3:.0f} ms/step)", flush=True)

    params, opt_state, st = run_loop(step_fn, params, opt_state, loader,
                                     cfg_loop, store=store,
                                     start_step=start, on_metrics=log)
    loader.close()
    print(f"done: {st.step} steps, final loss "
          f"{st.losses[-1] if st.losses else float('nan'):.4f}, "
          f"stragglers={len(st.straggler_events)}, "
          f"skipped={len(st.skipped_steps)}")


if __name__ == "__main__":
    main()
