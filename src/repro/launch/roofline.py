"""Roofline analysis from compiled dry-run artifacts (§Roofline).

Three terms per (arch × shape × mesh), all *seconds per step, per chip*
(the SPMD module from the dry-run is the per-device program, so
cost_analysis FLOPs/bytes and parsed collective bytes are already
per-chip — equivalent to the total/(chips·peak) formulation):

  compute    = flops_per_device / PEAK_FLOPS
  memory     = bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

plus MODEL_FLOPS (6·N_active·tokens for training, 2·N_active·tokens for
decode) and the usefulness ratio MODEL/HLO that exposes remat and
routing overcompute.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
"""

from __future__ import annotations

import json
import warnings
from pathlib import Path

from repro.configs import SHAPES, all_configs
from repro.hw import TPU_V5E as _HW

PEAK_FLOPS = _HW.peak_flops        # TPU v5e bf16
HBM_BW = _HW.hbm_bw                # B/s
ICI_BW = _HW.ici_bw                # B/s per link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, devices: int,
                           n_microbatches_hint: int = 1) -> float:
    cfg = all_configs()[arch]
    shape = SHAPES[shape_name]
    n_act = cfg.active_params
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens / devices
    tokens = shape.global_batch            # one new token per sequence
    return 2.0 * n_act * tokens / devices


def model_bytes_per_device(arch: str, shape_name: str,
                           devices: int) -> float:
    """Analytic HBM-traffic floor (bytes per step per device), bf16.

    Weight streaming plus activation/KV traffic — the ``repro.hw``
    bandwidth model's volume side.  Training reads the weights forward
    and backward and writes gradients (3× weight bytes) and round-trips
    activations (write fwd, read bwd); prefill streams weights once and
    writes the KV cache; decode streams weights and reads the full KV
    cache per emitted token.  A floor, not an HLO count: no remat
    re-reads, no scratch traffic.
    """
    cfg = all_configs()[arch]
    shape = SHAPES[shape_name]
    bpe = 2.0                               # bf16
    wbytes = bpe * cfg.total_params / devices
    d, hd = cfg.d_model, cfg.hd
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch / devices
        act = bpe * tokens * d * cfg.n_layers
        return 3.0 * wbytes + 2.0 * act
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch / devices
        act = bpe * tokens * d * cfg.n_layers
        kv = 2.0 * bpe * tokens * cfg.n_kv_heads * hd * cfg.n_layers
        return wbytes + act + kv
    seqs = shape.global_batch / devices     # decode: one token per sequence
    kv = (2.0 * bpe * seqs * shape.seq_len * cfg.n_kv_heads * hd
          * cfg.n_layers)
    return wbytes + kv


PROBE_DIR = Path(__file__).resolve().parents[3] / "experiments" / "costing"


def _probe(arch: str, shape: str):
    p = PROBE_DIR / f"{arch}__{shape}.json"
    return json.loads(p.read_text()) if p.exists() else None


def analyze(rec: dict) -> dict:
    """Three-term roofline.  FLOPs/bytes come from the unrolled-probe
    extrapolation (scan-trip honest); collectives from the trip-corrected
    parse of the production HLO; everything per device per step."""
    devices = rec["devices"]
    probe = _probe(rec["arch"], rec["shape"])
    if probe is not None:
        flops_dev = probe["total_flops"] / devices
        bytes_dev = probe["total_bytes"] / devices
        source = "probe"
    else:
        # cost_analysis on a scanned program under-counts by ~the trip
        # count; raw numbers would make the roofline silently wrong, so
        # the miss normalizes to the repro.hw analytic model instead.
        flops_dev = model_flops_per_device(rec["arch"], rec["shape"],
                                           devices)
        bytes_dev = model_bytes_per_device(rec["arch"], rec["shape"],
                                           devices)
        source = "analytic"
        warnings.warn(
            f"no unrolled-probe artifact for {rec['arch']}×{rec['shape']}: "
            "FLOPs/bytes normalized to the repro.hw analytic model "
            "(cost_source='analytic'); run repro.launch.costing to "
            "regenerate probes", RuntimeWarning, stacklevel=2)
    coll = rec.get("collective_bytes_per_device_trip_corrected",
                   rec["collective_bytes_per_device"])
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll["total"] / ICI_BW
    mf = model_flops_per_device(rec["arch"], rec["shape"], devices)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = mf / max(flops_dev, 1.0)
    # roofline fraction: useful-model-compute time over the bound
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return dict(rec, terms=terms, dominant=dom, model_flops=mf,
                useful_ratio=useful, roofline_fraction=frac,
                flops_per_device_corrected=flops_dev,
                bytes_per_device_corrected=bytes_dev,
                cost_source=source)


SUGGEST = {
    "compute": "cut HLO overcompute (remat policy, MoE dense→ragged "
               "dispatch) or raise arithmetic intensity",
    "memory": "fuse bandwidth-bound chains / reuse KV reads "
              "(larger per-step batch, bf16 states)",
    "collective": "re-shard to cut all-gather volume (smaller TP span, "
                  "FSDP prefetch overlap, gradient compression)",
}


def load_all(mesh: str | None = None, fusion: str | None = None,
             variant: str = "baseline", layout: str = "fixed"):
    recs = []
    for p in sorted(RESULTS_DIR.glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        if (fusion or "off") != rec.get("fusion", "off"):
            continue
        if rec.get("variant", "baseline") != variant:
            continue
        if rec.get("layout", "fixed") != layout:
            continue
        recs.append(analyze(rec))
    return recs


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["terms"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} "
            f"| {t['collective']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return "\n".join(rows)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--fusion", default="off")
    args = ap.parse_args()
    recs = load_all(args.mesh, args.fusion)
    if not recs:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    print(table(recs))
    print()
    worst = sorted((r for r in recs if r["mesh"] == "pod16x16"),
                   key=lambda r: r["roofline_fraction"])
    if worst:
        print("worst roofline fractions (single pod):")
        for r in worst[:5]:
            print(f"  {r['arch']} × {r['shape']}: "
                  f"{r['roofline_fraction']:.3f} ({r['dominant']}-bound"
                  f" → {SUGGEST[r['dominant']]})")


if __name__ == "__main__":
    main()
