"""Production mesh construction.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use).
"""

from __future__ import annotations

import jax

from repro.dist.compat import auto_axis_types


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)),
                         devices=jax.devices()[:n])


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Axes used for fully-sharded parameter storage (everything except
    the tensor-parallel axis) — single source of truth in repro.dist."""
    from repro.dist import sharding
    return sharding.fsdp_axes(mesh)


def dp_size(mesh) -> int:
    out = 1
    for a in fsdp_axes(mesh):
        out *= mesh.shape[a]
    return out
