"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production 16×16 single-pod mesh and the 2×16×16 multi-pod
mesh, printing memory and cost analyses (the roofline inputs).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
      --shape train_4k --mesh both [--layout auto]
"""

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=("pod", "multipod", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fusion", default="off")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-winning variants: act=dp for "
                         "train/prefill, TP-only params + grouped GQA "
                         "for decode")
    ap.add_argument("--layout", default="fixed", choices=("fixed", "auto"),
                    help="auto: lower under the planner-searched layout "
                         "(repro.dist.planner) instead of the fixed rules")
    args = ap.parse_args()

    # the 512-host-device override must precede any jax backend init —
    # behind the main() guard (import-time flag mutation breaks any
    # host that imported jax first)
    from repro.launch import ensure_host_device_count
    ensure_host_device_count(512)

    from repro.configs import all_configs, cells
    from repro.launch.dryrun_lib import run_cell
    from repro.launch.mesh import make_production_mesh

    if args.all:
        todo = cells(all_configs())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod2x16x16",
                       make_production_mesh(multi_pod=True)))

    from repro.configs import SHAPES
    failures = 0
    for arch, shape in todo:
        variant, vtag = None, ""
        if args.optimized:
            if SHAPES[shape].kind in ("train", "prefill"):
                variant, vtag = {"act": "dp"}, "opt"
            else:
                variant = {"serve_params": True, "gqa_grouped": True}
                vtag = "opt"
        for mesh_name, mesh in meshes:
            tag = f"{arch} × {shape} × {mesh_name}"
            try:
                rec = run_cell(arch, shape, mesh, mesh_name,
                               fusion=args.fusion, force=args.force,
                               variant=variant, variant_tag=vtag,
                               layout=args.layout)
                mem = rec["memory"]
                print(f"OK   {tag}: "
                      f"flops/dev={rec['flops_per_device']:.3e} "
                      f"bytes/dev={rec['bytes_per_device']:.3e} "
                      f"coll/dev={rec['collective_bytes_per_device']['total']:.3e} "
                      f"args={_gb(mem['argument_bytes'])} "
                      f"temp={_gb(mem['temp_bytes'])} "
                      f"(lower {rec['time_lower_s']}s, "
                      f"compile {rec['time_compile_s']}s)", flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


def _gb(x):
    return f"{x / 1e9:.2f}GB" if x is not None else "n/a"


if __name__ == "__main__":
    sys.exit(main())
