"""§Perf hillclimb driver: lower+compile the three chosen cells under each
optimization variant on the production single-pod mesh, recording
variant-tagged dry-run stats (and flop probes where compute changes).

``--layout auto`` re-runs the arms under the planner-searched layout
(``repro.dist.planner``) instead of the fixed PR-1 sharding rules;
explicit variant keys (``act``, ``serve_params``) still win over the
planner's choices, so each arm measures exactly what it names.

The ``fusion: "gen"`` arm routes the CE loss through the staged fusion
pipeline (``launch/train._fused_lse``: trace → plan → compile once per
shape); since PR 3 its *backward* pass is the planned gradient DAG via
the operator's custom_vjp, so the arm measures generated fused operators
in both directions of the train step.

  PYTHONPATH=src python -m repro.launch.hillclimb [--layout auto]
"""

from __future__ import annotations

import traceback

CELLS = {
    # (arch, shape): [(variant_tag, variant_dict), ...]
    ("yi-34b", "prefill_32k"): [
        ("actdp", {"act": "dp"}),
        ("actsp", {"act": "sp"}),
        ("actdp-servep", {"act": "dp", "serve_params": True}),
    ],
    ("grok-1-314b", "train_4k"): [
        ("actdp", {"act": "dp"}),
        ("actdp-capmoe", {"act": "dp", "moe_impl": "capacity"}),
    ],
    ("olmoe-1b-7b", "train_4k"): [
        ("actdp", {"act": "dp"}),
        ("actdp-capmoe", {"act": "dp", "moe_impl": "capacity"}),
        ("actdp-fusedloss", {"act": "dp", "fusion": "gen"}),
    ],
    # bonus: decode memory/collective lever
    ("yi-34b", "decode_32k"): [
        ("servep", {"serve_params": True}),
        ("servep-gqagrp", {"serve_params": True, "gqa_grouped": True}),
    ],
}

#: variants whose FLOPs/bytes change (need probes, run separately under a
#: small device count): (arch, shape, tag, variant)
PROBE_VARIANTS = [
    ("grok-1-314b", "train_4k", "capmoe", {"moe_impl": "capacity"}),
    ("olmoe-1b-7b", "train_4k", "capmoe", {"moe_impl": "capacity"}),
    ("yi-34b", "decode_32k", "gqagrp", {"gqa_grouped": True}),
]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="fixed", choices=("fixed", "auto"))
    args = ap.parse_args()

    # the 512-host-device override must land before any jax backend init,
    # so it runs behind the main() guard (merely importing this module
    # must not fork the process's device count)
    from repro.launch import ensure_host_device_count
    ensure_host_device_count(512)

    from repro.launch.dryrun_lib import run_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    for (arch, shape), variants in CELLS.items():
        for tag, variant in variants:
            try:
                fusion = variant.get("fusion", "off")
                rec = run_cell(arch, shape, mesh, "pod16x16",
                               fusion=fusion, variant=variant,
                               variant_tag=tag, layout=args.layout)
                coll = rec["collective_bytes_per_device_trip_corrected"]
                print(f"OK   {arch} × {shape} [{tag}]: "
                      f"coll/dev={coll['total']:.3e} "
                      f"rawflops={rec['flops_per_device']:.3e} "
                      f"rawbytes={rec['bytes_per_device']:.3e}",
                      flush=True)
            except Exception as e:
                print(f"FAIL {arch} × {shape} [{tag}]: "
                      f"{type(e).__name__}: {e}", flush=True)
                traceback.print_exc()


if __name__ == "__main__":
    main()
