"""Serving step factories: prefill and single-token decode (the functions
the decode_*/long_* dry-run cells lower), plus a simple batched engine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import LM
from repro.models.lm import N_PATCHES


def make_prefill_step(model: LM, cfg: ModelConfig):
    def prefill_step(params, tokens, cache, prefix_emb=None):
        logits, cache, _ = model.apply(params, tokens,
                                       prefix_emb=prefix_emb, caches=cache)
        return logits[:, -1:], cache
    return prefill_step


def make_serve_step(model: LM, cfg: ModelConfig):
    """One new token against a populated KV cache — the roofline unit for
    decode shapes."""
    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return serve_step


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision":
        S = S - N_PATCHES
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    specs = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    if cfg.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, N_PATCHES, cfg.d_model), jnp.bfloat16)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
    return {"token": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_specs_abstract(model: LM, shape: ShapeConfig):
    """ShapeDtypeStruct tree of the decode cache (no allocation)."""
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    return cache
