"""Launch stack: mesh construction, dry-run costing, roofline/layout
analysis, and the train/serve/hillclimb drivers."""

import os


def ensure_host_device_count(n: int = 512) -> None:
    """Make XLA fake ``n`` host devices for production-mesh dry-runs.
    Appends to any operator-provided ``XLA_FLAGS`` (unrelated flags
    survive; an explicit device-count override wins) and must run
    before the first jax backend initialization — this module imports
    nothing that touches jax, so entrypoints can call it first."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
