"""Dry-run machinery: lower + compile every (arch × shape × mesh) cell and
extract memory / FLOP / collective statistics for the roofline analysis.

Importable without touching jax device state — the 512-device XLA flag is
set by the thin ``dryrun.py`` entrypoint (and by tests with smaller
counts) *before* importing this module.
"""

from __future__ import annotations

import json
import re
import time
from pathlib import Path
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.dist import sharding as sh
from repro.launch import serve as serve_lib
from repro.launch import train as train_lib
from repro.launch.mesh import dp_size
from repro.models import LM
from repro.optim import adamw

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in post-SPMD HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            if f" {kind}(" not in line and f" {kind}-start(" not in line:
                continue
            lhs = line.split("=", 1)
            if len(lhs) != 2:
                continue
            total = 0.0
            for dt, dims in _SHAPE_RE.findall(lhs[1].split(kind)[0]):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                total += n * _DTYPE_BYTES[dt]
            out[kind] += total
            counts[kind] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------

def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across JAX versions: older releases
    return a one-element list of dicts, newer ones the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def abstract_params(model: LM):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_lib.train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return serve_lib.prefill_specs(cfg, shape)
    return serve_lib.decode_specs(cfg, shape)


def apply_variant(cfg, variant: Optional[dict]):
    """Apply §Perf variant config overrides (act/fusion keys are handled
    by the lowering wrapper, the rest are ModelConfig fields)."""
    if not variant:
        return cfg
    import dataclasses
    fields = {k: v for k, v in variant.items()
              if k not in ("act", "fusion", "serve_params", "n_mb")}
    return dataclasses.replace(cfg, **fields) if fields else cfg


def resolve_layout(arch: str, shape_name: str, mesh,
                   variant: Optional[dict], layout: str) -> Optional[dict]:
    """``layout="auto"``: merge the searched layout (``dist/planner``)
    into the variant dict — explicit variant keys win, and any planner
    failure falls back to the PR-1 fixed rules (variant unchanged)."""
    if layout != "auto":
        return variant
    from repro.dist import planner
    cfg = apply_variant(get_config(arch), variant)
    return planner.auto_variant(mesh, cfg, SHAPES[shape_name], variant)


def lower_cell(arch: str, shape_name: str, mesh, *,
               fusion: str = "off",
               variant: Optional[dict] = None,
               layout: str = "fixed") -> tuple:
    """Build (jitted_fn, abstract args) for one cell on ``mesh``."""
    variant = resolve_layout(arch, shape_name, mesh, variant, layout)
    cfg = apply_variant(get_config(arch), variant)
    shape = SHAPES[shape_name]
    model = LM(cfg)
    params_abs = abstract_params(model)
    serve = bool(variant and variant.get("serve_params"))
    pspecs = sh.named(mesh, sh.param_specs(mesh, cfg, params_abs,
                                           serve=serve))

    if shape.kind == "train":
        dp = dp_size(mesh)
        n_mb = (variant or {}).get(
            "n_mb", train_lib.default_microbatches(cfg, shape, dp))
        tc = train_lib.TrainConfig(n_microbatches=n_mb, fusion=fusion)
        step = train_lib.make_train_step(model, cfg, tc)
        opt_abs = jax.eval_shape(
            lambda p: adamw.init(p, tc.opt), params_abs)
        ospecs = {"m": sh.param_specs(mesh, cfg, params_abs),
                  "v": sh.param_specs(mesh, cfg, params_abs),
                  "count": P()}
        batch_abs = train_lib.train_batch_specs(cfg, shape)
        bspecs = jax.tree_util.tree_map(
            lambda s: sh.batch_spec(mesh, cfg, s.shape[0],
                                    len(s.shape) - 1), batch_abs)
        jitted = jax.jit(step,
                         in_shardings=(pspecs, sh.named(mesh, ospecs),
                                       sh.named(mesh, bspecs)),
                         donate_argnums=(0, 1))
        return jitted, (params_abs, opt_abs, batch_abs)

    if shape.kind == "prefill":
        pre = serve_lib.make_prefill_step(model, cfg)
        cache_abs = serve_lib.cache_specs_abstract(model, shape)
        cspecs = sh.cache_specs(mesh, cfg, shape, cache_abs)
        batch_abs = serve_lib.prefill_specs(cfg, shape)
        bspecs = jax.tree_util.tree_map(
            lambda s: sh.batch_spec(mesh, cfg, s.shape[0],
                                    len(s.shape) - 1), batch_abs)

        def fn(params, tokens, cache, **kw):
            return pre(params, tokens, cache, **kw)

        args = dict(batch_abs)
        tokens_abs = args.pop("tokens")
        jitted = jax.jit(
            lambda params, tokens, cache: pre(params, tokens, cache),
            in_shardings=(pspecs, sh.named(mesh, bspecs["tokens"]),
                          sh.named(mesh, cspecs)),
            donate_argnums=(2,))
        return jitted, (params_abs, tokens_abs, cache_abs)

    # decode / long_decode
    step = serve_lib.make_serve_step(model, cfg)
    cache_abs = serve_lib.cache_specs_abstract(model, shape)
    cspecs = sh.cache_specs(mesh, cfg, shape, cache_abs)
    dspecs = serve_lib.decode_specs(cfg, shape)
    tok_spec = sh.batch_spec(mesh, cfg, shape.global_batch,
                             len(dspecs["token"].shape) - 1)
    jitted = jax.jit(step,
                     in_shardings=(pspecs, sh.named(mesh, cspecs),
                                   NamedSharding(mesh, tok_spec),
                                   NamedSharding(mesh, P())),
                     donate_argnums=(1,))
    return jitted, (params_abs, cache_abs, dspecs["token"], dspecs["pos"])


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, *,
             fusion: str = "off", save: bool = True,
             force: bool = False, variant: Optional[dict] = None,
             variant_tag: str = "", layout: str = "fixed") -> dict:
    """Lower + compile one cell; return (and persist) its statistics.
    ``layout="auto"`` lowers under the planner-searched layout."""
    tag = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__fusion-{fusion}" if fusion != "off" else "") + (
        f"__{variant_tag}" if variant_tag else "") + (
        f"__layout-{layout}" if layout != "fixed" else "")
    out_path = RESULTS_DIR / f"{tag}.json"
    if save and out_path.exists() and not force:
        return json.loads(out_path.read_text())

    resolved = resolve_layout(arch, shape_name, mesh, variant, layout)
    # honesty marker: "auto" that fell back (or added nothing) lowers the
    # fixed baseline — record that so auto-vs-fixed comparisons can't
    # silently read baseline numbers as planner-searched results
    layout_applied = layout == "auto" and resolved != dict(variant or {})
    variant = resolved
    t0 = time.perf_counter()
    jitted, args = lower_cell(arch, shape_name, mesh, fusion=fusion,
                              variant=variant)
    import contextlib
    ctx = contextlib.nullcontext()
    if variant and variant.get("act"):
        from repro.dist.sharding import activation_rules
        ctx = activation_rules(mesh, variant["act"])
    with ctx:
        if isinstance(args, tuple):
            lowered = jitted.lower(*args)
        else:
            lowered = jitted.lower(**args)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from repro.launch.costing import corrected_collectives
    coll_corr = corrected_collectives(hlo)

    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": n_dev, "fusion": fusion, "layout": layout,
        "layout_applied": layout_applied,
        "variant": variant_tag or "baseline",
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        "collective_bytes_per_device_trip_corrected": coll_corr,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
    }
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
    return rec
