"""Shared serving error taxonomy.

Both servers (:class:`~repro.serve.fusion.FusionServer` and
:class:`~repro.serve.engine.Engine`) reject and fail requests through
this one hierarchy, so clients catch ``FusionServeError`` and switch on
the subtype regardless of which engine served them.  Admission-time
errors (closed server, bad operands, backpressure, quarantine) are
raised at ``submit`` and the request is never enqueued; runtime errors
(deadline, exhausted retries, non-finite outputs) resolve the request's
future exceptionally — a submitted request always ends in exactly one
of: a result, or one typed error."""

from __future__ import annotations


class FusionServeError(RuntimeError):
    """Root of the serving error taxonomy."""


class ServerClosedError(FusionServeError):
    """The server has been closed (or has no workers to drain the
    queue).  At ``submit``: the request was not enqueued.  On a future:
    the request was still queued when ``close()`` drained the queue."""


class AdmissionError(FusionServeError, ValueError):
    """The request can never be served as posed (prompt too long,
    ``max_new`` ≤ 0, operands not matching the region signature).
    Subclasses ``ValueError`` for backward compatibility with the
    pre-taxonomy ``Engine.submit`` contract."""


class QueueFullError(FusionServeError):
    """Bounded-queue backpressure: the admission queue is at
    ``max_queue`` and the request was rejected, not enqueued.  Clients
    should shed load or retry with backoff."""


class DeadlineExceededError(FusionServeError):
    """The request's deadline passed before a worker could finish it
    (checked at dequeue and at every degradation-ladder step; an
    execution already in flight runs to completion)."""


class PlanQuarantinedError(FusionServeError):
    """The request's plan digest is quarantined by the circuit breaker
    after repeated failures; rejected at submit until the breaker's
    cooldown elapses and a probe request closes it again."""


class PlanCompileError(FusionServeError):
    """Trace/plan/compile failed for the request's region at its shape
    class — no executable exists on any ladder tier.  Repeated compile
    failures trip the build circuit breaker (→
    :class:`PlanQuarantinedError` on subsequent submits)."""


class RequestFailedError(FusionServeError):
    """Terminal runtime failure: every degradation tier the retry
    budget allowed was exhausted without producing a result.  The
    original cause is chained as ``__cause__``."""


class NonFiniteOutputError(FusionServeError):
    """The request's outputs contained NaN/Inf (servers constructed
    with ``check_finite=True`` verify every tier's outputs; a
    non-finite result degrades down the ladder and, if every tier
    reproduces it, fails with this)."""
