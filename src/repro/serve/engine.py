"""Batched serving engine: prefill + decode with a shared KV cache pool.

Single-host reference implementation of the production loop: fixed-size
batch slots, greedy/temperature sampling, per-slot stop handling, and a
continuous-batching admission queue (new requests fill freed slots at
step boundaries).  The jitted inner steps are the same functions the
dry-run lowers for the decode_*/long_* cells.
"""

from __future__ import annotations

import queue
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.models import LM
from .errors import (AdmissionError, DeadlineExceededError,
                     QueueFullError)

__all__ = ["AdmissionError", "DeadlineExceededError", "QueueFullError",
           "Engine", "Request"]


@dataclass
class Request:
    prompt: np.ndarray               # (P,) int32
    max_new: int = 16
    temperature: float = 0.0
    out: list = field(default_factory=list)
    done: bool = False
    #: per-request deadline (seconds from submit; None: engine default).
    #: An expired request finishes with ``done=True`` and ``error`` set
    #: to DeadlineExceededError instead of silently decoding forever.
    deadline_s: Optional[float] = None
    error: Optional[Exception] = None
    _deadline_at: Optional[float] = field(default=None, repr=False)


class Engine:
    """``mesh``/``layout`` opt into sharded serving: ``layout="auto"``
    asks the planner (``repro.dist.planner``) for the cost-optimal
    decode layout of this (config × slots × max_len) cell and shards
    params + KV cache accordingly; ``"fixed"`` (and any planner failure)
    uses the PR-1 serving rule — TP-only params, batch/head-sharded
    cache.  ``mesh=None`` keeps the single-host unsharded path."""

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0, mesh=None,
                 layout: str = "fixed", max_queue: int = 0,
                 default_deadline_s: Optional[float] = None):
        self.cfg = cfg
        self.max_queue = max(0, int(max_queue))
        self.default_deadline_s = default_deadline_s
        self.model = LM(cfg)
        self.max_len = max_len
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.cache = self.model.init_cache(batch_slots, max_len)
        self.layout = None
        if mesh is not None:
            params = self._shard(mesh, layout, params, batch_slots)
        self.params = params
        self._prefill = jax.jit(make_prefill_step(self.model, cfg))
        self._decode = jax.jit(make_serve_step(self.model, cfg))
        self._queue: "queue.Queue[Request]" = queue.Queue(
            maxsize=self.max_queue)
        self._key = jax.random.PRNGKey(seed)

    def _shard(self, mesh, layout: str, params, batch_slots: int):
        from repro.configs.base import ShapeConfig
        from repro.dist import planner, sharding as sh

        shape = ShapeConfig("engine_decode", self.max_len, batch_slots,
                            "decode")
        serve = True                      # PR-1 fixed serving rule
        if layout == "auto":
            from dataclasses import replace
            sig = planner.signature_of(mesh)
            fb = replace(planner.fixed_layout(self.cfg, shape, sig),
                         serve_params=True)   # failure → TP-only serving
            lay = planner.plan_layout(mesh, self.cfg, shape, fallback=fb)
            self.layout = lay
            serve = lay.serve_params
        pspecs = sh.named(mesh, sh.param_specs(mesh, self.cfg, params,
                                               serve=serve))
        params = jax.tree_util.tree_map(jax.device_put, params, pspecs)
        cspecs = sh.named(mesh, sh.cache_specs(mesh, self.cfg, shape,
                                               self.cache))
        self.cache = jax.tree_util.tree_map(jax.device_put, self.cache,
                                            cspecs)
        return params

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue ``req`` for the next free slot.  Rejects impossible
        requests with :class:`AdmissionError` *here* — the decode loop
        assumes every admitted request fits (``pos < max_len - 1`` must
        hold after prefill for at least one decode step).  A full
        bounded queue (``max_queue`` > 0) rejects with
        :class:`QueueFullError`; the request's deadline (``deadline_s``
        or the engine default) starts counting at submit."""
        if req.max_new <= 0:
            raise AdmissionError(
                f"max_new must be >= 1, got {req.max_new}")
        P = len(req.prompt)
        if P == 0:
            raise AdmissionError("empty prompt")
        if P > self.max_len - 1:
            raise AdmissionError(
                f"prompt length {P} exceeds the cache budget: max_len="
                f"{self.max_len} leaves room for at most {self.max_len - 1} "
                "prompt tokens plus one decode step")
        deadline = req.deadline_s if req.deadline_s is not None \
            else self.default_deadline_s
        if deadline is not None:
            req._deadline_at = time.perf_counter() + float(deadline)
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            raise QueueFullError(
                f"admission queue is full ({self.max_queue} requests); "
                "shed load or retry with backoff") from None

    @staticmethod
    def _expired(req: Request) -> bool:
        return req._deadline_at is not None and \
            time.perf_counter() > req._deadline_at

    def _fail_deadline(self, req: Request) -> None:
        req.error = DeadlineExceededError(
            f"deadline passed after {len(req.out)} of {req.max_new} "
            "tokens")
        req.done = True

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is not None:
                continue
            while not self._queue.empty():
                req = self._queue.get()
                if self._expired(req):   # expired while queued: no slot
                    self._fail_deadline(req)
                    continue
                self.slots[i] = req
                P = len(req.prompt)
                # prefill slot (batch-1 prefill into slot i's cache rows)
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                sub = self._slot_cache(i)
                _, new_cache = self._prefill(self.params, toks, sub)
                self._write_slot_cache(i, new_cache)
                self.pos[i] = P
                break

    def _slot_cache(self, i: int):
        def slot(leaf):
            # batch dim is axis 1 for stacked (G, B, ...) leaves, else 0
            ax = 1 if leaf.ndim >= 2 and leaf.shape[0] == self._groups() \
                else 0
            return jax.lax.dynamic_slice_in_dim(leaf, i, 1, axis=ax)
        return jax.tree_util.tree_map(slot, self.cache)

    def _write_slot_cache(self, i: int, sub) -> None:
        def write(full, part):
            ax = 1 if full.ndim >= 2 and full.shape[0] == self._groups() \
                else 0
            return jax.lax.dynamic_update_slice_in_dim(full, part, i,
                                                       axis=ax)
        self.cache = jax.tree_util.tree_map(write, self.cache, sub)

    def _groups(self) -> int:
        return self.model.n_groups

    # -- stepping ------------------------------------------------------------
    def step(self) -> None:
        """One decode step for every occupied slot (continuous batching:
        admission happens between steps)."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        last = np.zeros((len(self.slots), 1), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            prev = s.out[-1] if s.out else s.prompt[-1]
            last[i, 0] = prev
        # decode advances every slot at its own position: step per slot
        for i in active:
            req = self.slots[i]
            if self._expired(req):       # deadline: evict at the boundary
                self._fail_deadline(req)
                self.slots[i] = None
                continue
            tok = jnp.asarray(last[i:i + 1], jnp.int32)
            sub = self._slot_cache(i)
            nxt, logits, sub = self._decode(self.params, sub, tok,
                                            int(self.pos[i]))
            self._write_slot_cache(i, sub)
            if req.temperature > 0:
                self._key, k = jax.random.split(self._key)
                nxt = jax.random.categorical(
                    k, logits[:, -1] / req.temperature)[None]
            tok_out = int(np.asarray(nxt).reshape(-1)[0])
            req.out.append(tok_out)
            self.pos[i] += 1
            if len(req.out) >= req.max_new or self.pos[i] >= self.max_len - 1:
                req.done = True
                self.slots[i] = None

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self._queue.empty() and all(s is None for s in self.slots):
                return
            self.step()
