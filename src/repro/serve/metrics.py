"""Serving metrics: latency percentiles, queue depth, batch occupancy,
resilience counters, and plan-cache snapshots for the fused-plan server.

Everything here is plain-python and thread-safe: worker threads record
per-request latencies and per-batch occupancy into bounded reservoirs
(ring buffers — a long-lived server must not accumulate unbounded
history), and :meth:`ServerMetrics.snapshot` exports the whole state as
a JSON-able dict.  :meth:`ServerMetrics.report` is the ``explain()``-
style nested report the load harness prints and ``BENCH_fusion.json``
derives its serving rows from.

Glossary (the keys ``snapshot()`` exports):

``requests``
    ``submitted`` / ``completed`` / ``failed`` (resolved with a typed
    execution error) / ``rejected`` (typed admission error at
    ``submit`` time — never enqueued) / ``deadline_exceeded`` /
    ``cancelled`` (still queued at ``close()``).
``latency_us``
    Submit-to-result wall latency percentiles (``p50``/``p95``/``p99``),
    mean, and the reservoir count they were computed over.
``batches``
    ``count`` (dispatches), ``batched_requests`` (requests that shared
    a dispatch with at least one other), ``padded_requests`` (requests
    zero-padded up to their shape class), ``occupancy_mean`` /
    ``occupancy_max`` (requests per dispatch), ``pad_fallbacks``
    (buckets that degraded to exact-shape batching because padding was
    proven unsafe for the plan's outputs), ``failed_dispatches``
    (tier-0 dispatches that raised — their requests then walk the
    degradation ladder, so a failed dispatch is *not* a failed
    request).
``queue``
    Current depth and the high-water mark.
``buckets``
    Per-bucket counters keyed by the structural plan digest: requests,
    batches, compiles and compile seconds.
``resilience``
    The self-healing ledger: ``rejected`` by reason (``backpressure`` /
    ``quarantined``), ``degraded`` requests per ladder tier (``exact``
    / ``per_op``), ``bisections``, ``nonfinite_detected``,
    ``retries_exhausted``, ``workers`` (``crashes`` / ``respawns`` /
    ``requeued_requests``), and ``breaker`` transition counts
    (``opens`` / ``probes`` / ``closes``).
``runtime_fallbacks``
    Bounded ledger of explicit run-time degradations — the run-time
    extension of the plan-time ``record_fallback`` discipline: one
    ``{site, tier, reason, count}`` row per distinct downgrade, so no
    degradation is silent.
``cache``
    :func:`repro.core.plan_cache_stats` and
    :func:`repro.core.whole_plan_cache_stats` snapshots (hit/miss/
    eviction/capacity/build-time), i.e. plan-cache lifecycle under
    churn.
"""

from __future__ import annotations

import threading
from collections import OrderedDict, deque
from dataclasses import asdict
from typing import Iterable, Optional

import numpy as np

#: bounded history kept per reservoir (latencies, occupancies)
RESERVOIR_SIZE = 8192
#: per-bucket counter records kept (LRU past this; drops are counted)
BUCKET_STATS_CAPACITY = 1024
#: distinct runtime-fallback rows kept (LRU past this)
FALLBACK_LEDGER_CAPACITY = 256


def percentiles(values: Iterable[float],
                qs: tuple[float, ...] = (50.0, 95.0, 99.0)) -> dict:
    """``{"p50": ..., "p95": ..., "p99": ...}`` over ``values`` (empty
    input yields zeros) — shared by the metrics layer and the load
    harness so both report identical definitions."""
    vals = np.asarray(list(values), dtype=np.float64)
    if vals.size == 0:
        return {f"p{q:g}": 0.0 for q in qs}
    return {f"p{q:g}": float(np.percentile(vals, q)) for q in qs}


class Reservoir:
    """Bounded, thread-compatible sample window (ring buffer)."""

    def __init__(self, size: int = RESERVOIR_SIZE) -> None:
        self._ring: "deque[float]" = deque(maxlen=size)
        self.count = 0            # total ever recorded (not just retained)

    def add(self, value: float) -> None:
        self._ring.append(float(value))
        self.count += 1

    def values(self) -> list[float]:
        return list(self._ring)

    def summary(self, qs: tuple[float, ...] = (50.0, 95.0, 99.0)) -> dict:
        vals = self.values()
        out = percentiles(vals, qs)
        out["mean"] = float(np.mean(vals)) if vals else 0.0
        out["max"] = float(np.max(vals)) if vals else 0.0
        out["count"] = self.count
        return out


class ServerMetrics:
    """Thread-safe counters + reservoirs for one :class:`FusionServer`."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self.batches = 0
        self.batched_requests = 0
        self.padded_requests = 0
        self.pad_fallbacks = 0
        self.failed_dispatches = 0
        self.compiles = 0
        self.compile_time_s = 0.0
        self.queue_depth = 0
        self.peak_queue_depth = 0
        self.latency_us = Reservoir()
        self.occupancy = Reservoir()
        self._buckets: "OrderedDict[str, dict]" = OrderedDict()
        self.dropped_buckets = 0
        # resilience ledger
        self.deadline_exceeded = 0
        self.cancelled = 0
        self.rejected_by_reason: dict[str, int] = {}
        self.degraded: dict[str, int] = {}
        self.bisections = 0
        self.nonfinite_detected = 0
        self.retries_exhausted = 0
        self.worker_crashes = 0
        self.worker_respawns = 0
        self.requeued_requests = 0
        self.breaker_events: dict[str, int] = {}
        self._fallbacks: "OrderedDict[tuple, dict]" = OrderedDict()
        self.dropped_fallbacks = 0

    # -- recording (called by the server) ------------------------------------
    def on_submit(self, depth: int) -> None:
        with self._lock:
            self.submitted += 1
            self.queue_depth = depth
            self.peak_queue_depth = max(self.peak_queue_depth, depth)

    def on_reject(self, reason: str = "admission") -> None:
        with self._lock:
            self.rejected += 1
            self.rejected_by_reason[reason] = \
                self.rejected_by_reason.get(reason, 0) + 1

    def on_compile(self, bucket: str, seconds: float,
                   pad_fallback: bool = False) -> None:
        with self._lock:
            self.compiles += 1
            self.compile_time_s += seconds
            if pad_fallback:
                self.pad_fallbacks += 1
            rec = self._bucket(bucket)
            rec["compiles"] += 1
            rec["compile_time_s"] += seconds

    def on_dispatch(self, bucket: str, size: int, padded: int,
                    depth: int, failed: bool = False) -> None:
        """One tier-0 dispatch (batched or single).  ``failed`` counts
        the *dispatch*; its requests are accounted when their futures
        resolve (``on_result``)."""
        with self._lock:
            self.batches += 1
            self.occupancy.add(size)
            self.queue_depth = depth
            if size > 1:
                self.batched_requests += size
            self.padded_requests += padded
            if failed:
                self.failed_dispatches += 1
            rec = self._bucket(bucket)
            rec["requests"] += size
            rec["batches"] += 1

    def on_result(self, bucket: str, latency_us: Optional[float],
                  failed: bool = False) -> None:
        """One request future resolved (result or typed error)."""
        with self._lock:
            if failed:
                self.failed += 1
            else:
                self.completed += 1
                if latency_us is not None:
                    self.latency_us.add(latency_us)

    def on_deadline(self, bucket: str = "") -> None:
        with self._lock:
            self.deadline_exceeded += 1

    def on_cancel(self) -> None:
        with self._lock:
            self.cancelled += 1

    def on_bisect(self) -> None:
        with self._lock:
            self.bisections += 1

    def on_nonfinite(self, bucket: str = "") -> None:
        with self._lock:
            self.nonfinite_detected += 1

    def on_degrade(self, tier: str, bucket: str = "") -> None:
        with self._lock:
            self.degraded[tier] = self.degraded.get(tier, 0) + 1

    def on_retries_exhausted(self, bucket: str = "") -> None:
        with self._lock:
            self.retries_exhausted += 1

    def on_worker_crash(self, kind: str = "") -> None:
        with self._lock:
            self.worker_crashes += 1

    def on_worker_respawn(self) -> None:
        with self._lock:
            self.worker_respawns += 1

    def on_requeue(self, n: int) -> None:
        with self._lock:
            self.requeued_requests += n

    def on_breaker(self, event: str) -> None:
        with self._lock:
            self.breaker_events[event] = \
                self.breaker_events.get(event, 0) + 1

    def on_runtime_fallback(self, site: str, reason: str,
                            tier: str = "") -> None:
        """Record one explicit run-time degradation — the serving-side
        mirror of ``CompiledPlan.record_fallback`` (EXE005: no silent
        fallbacks, at plan time or run time)."""
        with self._lock:
            key = (site, tier, reason)
            rec = self._fallbacks.get(key)
            if rec is None:
                rec = {"site": site, "tier": tier, "reason": reason,
                       "count": 0}
                self._fallbacks[key] = rec
                while len(self._fallbacks) > FALLBACK_LEDGER_CAPACITY:
                    self._fallbacks.popitem(last=False)
                    self.dropped_fallbacks += 1
            else:
                self._fallbacks.move_to_end(key)
            rec["count"] += 1

    def _bucket(self, key: str) -> dict:
        rec = self._buckets.get(key)
        if rec is None:
            rec = {"bucket": key, "requests": 0, "batches": 0,
                   "compiles": 0, "compile_time_s": 0.0}
            self._buckets[key] = rec
            while len(self._buckets) > BUCKET_STATS_CAPACITY:
                self._buckets.popitem(last=False)
                self.dropped_buckets += 1
        else:
            self._buckets.move_to_end(key)
        return rec

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able state dump (see the module docstring's glossary)."""
        from repro.core import plan_cache_stats, whole_plan_cache_stats
        with self._lock:
            occ = self.occupancy.summary(qs=(50.0,))
            snap = {
                "requests": {
                    "submitted": self.submitted,
                    "completed": self.completed,
                    "failed": self.failed,
                    "rejected": self.rejected,
                    "deadline_exceeded": self.deadline_exceeded,
                    "cancelled": self.cancelled,
                },
                "latency_us": self.latency_us.summary(),
                "batches": {
                    "count": self.batches,
                    "batched_requests": self.batched_requests,
                    "padded_requests": self.padded_requests,
                    "occupancy_mean": occ["mean"],
                    "occupancy_max": occ["max"],
                    "pad_fallbacks": self.pad_fallbacks,
                    "failed_dispatches": self.failed_dispatches,
                },
                "queue": {
                    "depth": self.queue_depth,
                    "peak_depth": self.peak_queue_depth,
                },
                "compiles": {
                    "count": self.compiles,
                    "time_s": round(self.compile_time_s, 6),
                },
                "resilience": {
                    "rejected": dict(self.rejected_by_reason),
                    "degraded": dict(self.degraded),
                    "bisections": self.bisections,
                    "nonfinite_detected": self.nonfinite_detected,
                    "retries_exhausted": self.retries_exhausted,
                    "workers": {
                        "crashes": self.worker_crashes,
                        "respawns": self.worker_respawns,
                        "requeued_requests": self.requeued_requests,
                    },
                    "breaker": dict(self.breaker_events),
                },
                "runtime_fallbacks": [dict(r)
                                      for r in self._fallbacks.values()],
                "dropped_fallbacks": self.dropped_fallbacks,
                "buckets": [dict(r) for r in self._buckets.values()],
                "dropped_buckets": self.dropped_buckets,
            }
        snap["cache"] = {
            "plan": asdict(plan_cache_stats()),
            "whole_plan": asdict(whole_plan_cache_stats()),
        }
        return snap

    def report(self, server: Optional[object] = None,
               top_keys: int = 8) -> dict:
        """``explain()``-style report: the snapshot plus the server's
        configuration, quarantined plans, and the hottest whole-plan
        cache keys."""
        from repro.core.codegen import WHOLE_PLAN_CACHE
        doc = {"serving": self.snapshot()}
        if server is not None:
            doc["server"] = {
                "workers": getattr(server, "workers", None),
                "max_batch": getattr(server, "max_batch", None),
                "pad_to": getattr(server, "pad_to", None),
                "max_queue": getattr(server, "max_queue", None),
                "retry_budget": getattr(server, "retry_budget", None),
                "entries": len(getattr(server, "_entries", ()) or ()),
            }
            breaker = getattr(server, "breaker", None)
            if breaker is not None:
                keys = breaker.snapshot()
                doc["server"]["breaker"] = {
                    "threshold": breaker.threshold,
                    "cooldown_s": breaker.cooldown_s,
                    "keys": keys,
                    "quarantined": [r for r in keys
                                    if r["state"] != "closed"],
                }
        doc["serving"]["cache"]["whole_plan_keys"] = \
            WHOLE_PLAN_CACHE.key_stats(top=top_keys)
        return doc
