"""Multi-tenant fused-plan serving: async continuous batching over
compiled whole plans.

The optimizer pays off only when compiled fusion plans are *reused* —
optimization and codegen cost amortize across invocations (the paper's
Fig. 11 argument).  :class:`FusionServer` is the traffic-facing form of
that claim: many concurrent clients submit fused-region invocations
(``submit(region, args) -> Future``), worker threads drain a shared
queue, and requests whose plans are structurally equal are executed as
*one* batched dispatch of the shared staged executable.

Request path::

    submit(region, args)
      └─ canonicalize operands → shape class (rows padded up to pad_to)
         └─ route: (region, class) → _PlanEntry  [trace→plan→compile,
            memoized; structurally-equal plans share one staged fn via
            the whole-plan cache]
            └─ enqueue ticket, bucketed by structural plan digest
    worker: pop head ticket, drain same-bucket tickets (≤ max_batch)
      └─ zero-pad each request to the bucket's shape class, stack on a
         new leading axis, ONE call of the jitted vmapped whole-plan fn
         └─ slice each request's outputs back to its true shape,
            resolve futures, record latency/occupancy metrics

**Shape bucketing & padding.**  Requests rarely share exact shapes, so
the leading ("row") dimension is padded up to the next multiple of
``pad_to`` and requests sharing the padded class batch together.
Zero-padding is only sound for some plans: a padded row flows through
``relu(1 - y*(X@w))`` as a garbage-but-confined row (sliced away on
return), but through ``(...).sum()`` it *pollutes the scalar*.  The
server runs a static **pad-safety analysis** over the traced HOP DAG —
propagating "padded rows are zero / finite garbage / possibly non-
finite" through every operator and rejecting any contraction over the
padded dimension whose operand is not provably zero (zero rows are
exact under ``sum``/``sum_sq``/``matmul`` contractions; ``mean``/
``min``/``max`` over padded rows never are).  Plans that fail the
analysis degrade to **exact-shape buckets** (only identical shapes
batch — still one dispatch per batch), recorded as ``pad_fallbacks`` in
the metrics.  Batch elements are vmapped, therefore independent: the
batched result equals per-request execution (tested to 1e-5).

**Plan-cache lifecycle.**  Entries are memoized per (region, shape
class, context); underneath, the bounded LRU
:class:`~repro.core.codegen.WholePlanCache` shares one jitted function
across structurally-equal plans and its per-key hit/miss/eviction/
build-time counters survive entry churn.  ``warm(regions)`` compiles
(and optionally executes) plans ahead of traffic;
``FusionServer(plan_cache_capacity=..., whole_plan_cache_capacity=...)``
bounds both global caches for long-lived processes.

Metrics (:mod:`repro.serve.metrics`): p50/p95/p99 latency, queue depth,
batch occupancy, per-bucket counters, and cache stats — exported by
``metrics.snapshot()`` / ``report()``.  The load harness
(``benchmarks/serving.py``) drives N simulated clients against the
l2svm/mlogreg scoring regions and records serving throughput and tail
latency in ``BENCH_fusion.json``.

**Fault tolerance** (``docs/robustness.md``).  The server assumes
compiles, dispatches, and worker threads *fail*: a failed batched
dispatch bisects so one poison request fails only its own future, then
re-executes down a **degradation ladder** (batched → exact-shape
staged → per-op ``staged=False``) under a per-request retry budget and
optional deadline; repeatedly-failing plan digests are quarantined by a
**circuit breaker** (closed → open → half-open probe);
``max_queue`` bounds the admission queue with typed
:class:`~repro.serve.errors.QueueFullError` backpressure; a crashed
worker thread requeues its in-flight batch and respawns.  Every
degradation is explicit and counted — the run-time extension of the
plan-time no-silent-fallback discipline (EXE005): the metrics layer
keeps a runtime-fallback ledger mirroring ``record_fallback``.  The
seeded chaos harness (:mod:`repro.faults`, ``tests/test_faults.py``)
exercises all of it deterministically; with no schedule installed each
fault point is a single global read, keeping resilience off the hot
path (``serving_hardened`` in ``benchmarks/serving.py`` gates that).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.core import ir
from repro.core.api import Compiled, Planned, _canon_shape, _canon_value
from repro.core.codegen import (PLAN_CACHE, WHOLE_PLAN_CACHE,
                                WholePlanCache)
from repro.core.context import FusionContext, current_context
from repro.kernels.blocksparse import BCSR, DictCompressed
from .errors import (AdmissionError, DeadlineExceededError,
                     FusionServeError, NonFiniteOutputError,
                     PlanCompileError, PlanQuarantinedError,
                     QueueFullError, RequestFailedError, ServerClosedError)
from .metrics import ServerMetrics

faults.register_site(
    "serve.batch_dispatch",
    "vmap-batched (or exact/per-op degraded) dispatch of one serving "
    "batch in a worker thread — the runtime execution site",
    kinds=("error", "nonfinite", "latency"),
    handler="degradation ladder: bisection isolates poison requests, "
            "failed work re-executes batched → exact-shape → per-op "
            "under the retry budget; repeated failures open the "
            "per-digest circuit breaker")

faults.register_site(
    "serve.worker",
    "worker loop body, after a batch is popped and before it executes",
    kinds=("crash", "latency"),
    handler="crash containment: in-flight tickets requeue at the front, "
            "a replacement thread spawns (worker_respawns metric), the "
            "pool never shrinks silently")


# --------------------------------------------------------------------------
# static pad-safety analysis
# --------------------------------------------------------------------------

_ZERO, _FIN, _NAN = 0, 1, 2          # padded-slice value severity
#: unary ops with f(0) finite but nonzero (padded zeros stop being zero)
_FIN_AT_ZERO = frozenset({"exp", "sigmoid", "softplus"})
#: unary ops that can turn finite garbage non-finite (domain edges)
_NAN_RISK = frozenset({"log", "recip", "sqrt", "log1p"})


@dataclass(frozen=True)
class PadReport:
    """Outcome of the pad-safety analysis for one traced region.

    ``safe`` — zero-padding the marked inputs' leading dimension and
    slicing every output back is exact; ``out_axes`` — per graph output,
    the axis carrying the padded dimension (``None``: the output never
    sees it and is exact as-is); ``reason`` — first violated rule when
    unsafe (drives the ``pad_fallbacks`` metric)."""
    safe: bool
    out_axes: tuple = ()
    reason: str = ""


def pad_safety(graph: ir.Graph, padded_inputs: frozenset) -> PadReport:
    """Decide whether zero-padding ``padded_inputs`` along axis 0 is
    exact for every output of ``graph`` (after slicing).

    Per node we track whether it carries the padded dimension (axis 0 or
    1 — transposes flip it) and what its padded slice provably holds:
    exactly **zero** (padding survives zero-preserving cell ops and
    anchors exact ``sum``/``matmul`` contractions), **finite garbage**
    (confined to the padded rows — safe until contracted), or
    **possibly non-finite** (``log(0)``, ``x/0`` … — also confined, but
    poisons any contraction, since ``0 · nan = nan``).  A contraction
    over the padded dimension (matmul inner dim, ``colsums``, full
    aggregates) is exact iff one side's padded slice is zero and the
    other is finite; ``mean``/``min``/``max`` over the padded dimension
    are never exact.  Anything the table doesn't cover fails closed."""
    state: dict[int, Optional[tuple[int, int]]] = {}

    def unsafe(node: ir.Node, why: str) -> PadReport:
        return PadReport(False, (),
                         f"%{node.nid} {node.op}: {why}")

    def fin(s) -> bool:          # finite padded slice (or real data)
        return s is None or s[1] <= _FIN

    for node in graph.nodes:
        op, ins = node.op, node.inputs
        sts = [state.get(i.nid) for i in ins]
        if op == "input":
            state[node.nid] = (0, _ZERO) if node.name in padded_inputs \
                else None
            continue
        if op == "lit":
            state[node.nid] = None
            continue
        if all(s is None for s in sts):
            state[node.nid] = None
            continue
        if op == "t":
            ax, sev = sts[0]
            state[node.nid] = (1 - ax, sev)
        elif op == "idx":
            if sts[0][0] == 1:
                return unsafe(node, "column slice of the padded axis")
            state[node.nid] = sts[0]
        elif op == "matmul":
            sa, sb = sts[0], sts[1]
            ta, tb = node.ta, node.tb
            a_contract = sa is not None and sa[0] == (0 if ta else 1)
            b_contract = sb is not None and sb[0] == (1 if tb else 0)
            if a_contract or b_contract:
                a_zero = a_contract and sa[1] == _ZERO
                b_zero = b_contract and sb[1] == _ZERO
                if not ((a_zero and fin(sb)) or (b_zero and fin(sa))):
                    return unsafe(node, "contraction over the padded "
                                        "dimension of a non-zero operand")
            row_pad = sa is not None and sa[0] == (1 if ta else 0)
            col_pad = sb is not None and sb[0] == (0 if tb else 1)
            if row_pad and col_pad:
                return unsafe(node, "both result axes would be padded")
            if not row_pad and not col_pad:
                state[node.nid] = None          # contracted away: exact
            else:
                src = sa if row_pad else sb
                sev = _NAN if any(s is not None and s[1] == _NAN
                                  for s in sts) else \
                    (_ZERO if src[1] == _ZERO else _FIN)
                state[node.nid] = (0 if row_pad else 1, sev)
        elif node.op in ir.AGG_OPS and "axis" in node.attrs:
            s = sts[0]
            reduced = {"full": (0, 1), "row": (1,), "col": (0,)}[
                node.attrs["axis"]]
            if s[0] in reduced:
                if op in ("sum", "sum_sq") and s[1] == _ZERO:
                    state[node.nid] = None      # zeros add nothing: exact
                else:
                    return unsafe(node, f"{op} over the padded dimension "
                                        "of a non-zero operand")
            else:
                state[node.nid] = s             # row-local: confined
        elif op in ir.UNARY_OPS:
            ax, sev = sts[0]
            if sev == _ZERO:
                sev = _ZERO if op in ir.SPARSE_SAFE_UNARY else \
                    (_FIN if op in _FIN_AT_ZERO else _NAN)
            elif sev == _FIN and op in _NAN_RISK:
                sev = _NAN
            state[node.nid] = (ax, sev)
        elif op in ir.BINARY_OPS:
            axes = {s[0] for s in sts if s is not None}
            if len(axes) != 1:
                return unsafe(node, "operands carry different padded axes")
            ax = axes.pop()
            sevs = [s[1] if s is not None else None for s in sts]
            if op in ("eq", "neq", "lt", "le", "gt", "ge"):
                sev = _FIN                       # 0/1 output
            elif op == "mul":
                if (sevs[0] == _ZERO and fin(sts[1])) or \
                        (sevs[1] == _ZERO and fin(sts[0])):
                    sev = _ZERO
                elif _NAN in sevs:
                    sev = _NAN
                else:
                    sev = _FIN
            elif op in ("div", "pow"):
                sev = _NAN                       # 0/0, x/0, 0**-1 …
            else:                                # add/sub/min/max
                if sevs[0] == _ZERO and sevs[1] == _ZERO:
                    sev = _ZERO
                else:
                    sev = _NAN if _NAN in sevs else _FIN
            state[node.nid] = (ax, sev)
        elif op in ir.TERNARY_OPS:
            axes = {s[0] for s in sts if s is not None}
            if len(axes) != 1:
                return unsafe(node, "operands carry different padded axes")
            sev = _NAN if any(s is not None and s[1] == _NAN
                              for s in sts) else _FIN
            state[node.nid] = (axes.pop(), sev)
        else:                                    # diagv, unknown ops
            return unsafe(node, "no padding rule for this operator")

    out_axes = tuple(state[o.nid][0] if state.get(o.nid) is not None
                     else None for o in graph.outputs)
    return PadReport(True, out_axes)


# --------------------------------------------------------------------------
# shape classes
# --------------------------------------------------------------------------

def _shape_class(shapes: dict[str, tuple[int, int]],
                 pad_to: int) -> Optional[tuple[dict, frozenset, int]]:
    """Padded shape class for one request's canonical operand shapes.

    The "batch rows" dimension ``m`` is the largest leading dimension
    that does **not** also appear as any operand's column dimension —
    column dimensions are feature/contraction axes (``w`` in
    ``hinge(X(m,64), w(64,1), y(m,1))`` leads with the feature dim 64;
    excluding column dims picks ``m`` rows, not features).  ``m``
    rounds up to the next multiple of ``pad_to`` and every operand led
    by ``m`` pads with it.  Returns ``(padded shapes, padded operand
    names, m)``, or None when no unambiguous batch dimension exists
    (all leading dims ≤ 1 or double as column dims — e.g. square
    matrices); those requests batch only with exact shape twins."""
    if pad_to <= 1 or not shapes:
        return None
    col_dims = {c for _r, c in shapes.values()}
    cands = {r for r, _c in shapes.values() if r > 1 and r not in col_dims}
    if not cands:
        return None
    m = max(cands)
    big = -(-m // pad_to) * pad_to
    padded = {n: ((big, c) if r == m else (r, c))
              for n, (r, c) in shapes.items()}
    names = frozenset(n for n, (r, _c) in shapes.items() if r == m)
    return padded, names, m


def _pow2_at_least(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


def _uncanon_np(v: np.ndarray):
    """Host-side half of the canonicalization round-trip (mirrors
    ``repro.core.api._uncanon_output`` for NumPy results): (n, 1)
    columns → 1-D, (1, 1) → 0-D."""
    if v.shape == (1, 1):
        return v.reshape(())
    if v.ndim == 2 and v.shape[1] == 1:
        return v.reshape(-1)
    return v


# --------------------------------------------------------------------------
# circuit breaker
# --------------------------------------------------------------------------

class CircuitBreaker:
    """Per-key (plan digest / build key) failure quarantine.

    State machine per key: **closed** (normal; consecutive failures
    count up) → **open** after ``threshold`` consecutive failures (every
    ``allow`` rejects) → **half_open** once ``cooldown_s`` elapses (one
    probe request is admitted; concurrent requests keep rejecting) →
    **closed** on probe success / back to **open** on probe failure.
    Success in any state resets the failure count."""

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 metrics: Optional[ServerMetrics] = None) -> None:
        self.threshold = max(1, int(threshold))
        self.cooldown_s = float(cooldown_s)
        self.metrics = metrics
        self._lock = threading.Lock()
        self._keys: dict[str, dict] = {}

    def _rec(self, key: str) -> dict:
        rec = self._keys.get(key)
        if rec is None:
            rec = {"state": "closed", "fails": 0, "opened_at": 0.0,
                   "probing": False, "opens": 0, "label": ""}
            self._keys[key] = rec
        return rec

    def allow(self, key: str) -> tuple[bool, str]:
        """(admit?, state).  Transitions open → half_open after the
        cooldown and marks the admitted request as the probe."""
        with self._lock:
            rec = self._keys.get(key)
            if rec is None or rec["state"] == "closed":
                return True, "closed"
            now = time.perf_counter()
            if rec["state"] == "open":
                if now - rec["opened_at"] < self.cooldown_s:
                    return False, "open"
                rec["state"] = "half_open"
                rec["probing"] = False
                if self.metrics is not None:
                    self.metrics.on_breaker("probes")
            if rec["probing"]:                  # one probe at a time
                return False, "half_open"
            rec["probing"] = True
            return True, "half_open"

    def cancel_probe(self, key: str) -> None:
        """The admitted probe was never executed (e.g. rejected later
        in submit): release the probe slot."""
        with self._lock:
            rec = self._keys.get(key)
            if rec is not None:
                rec["probing"] = False

    def record_success(self, key: str) -> None:
        with self._lock:
            rec = self._keys.get(key)
            if rec is None:
                return                          # untracked: stay silent
            closed = rec["state"] != "closed"
            rec.update(state="closed", fails=0, probing=False)
            if closed and self.metrics is not None:
                self.metrics.on_breaker("closes")

    def record_failure(self, key: str, label: str = "") -> None:
        with self._lock:
            rec = self._rec(key)
            if label:
                rec["label"] = label
            rec["fails"] += 1
            rec["probing"] = False
            opened = False
            if rec["state"] == "half_open":     # failed probe: re-open
                opened = True
            elif rec["state"] == "closed" and \
                    rec["fails"] >= self.threshold:
                opened = True
            if opened:
                rec["state"] = "open"
                rec["opened_at"] = time.perf_counter()
                rec["opens"] += 1
                if self.metrics is not None:
                    self.metrics.on_breaker("opens")

    def state(self, key: str) -> str:
        with self._lock:
            rec = self._keys.get(key)
            return rec["state"] if rec is not None else "closed"

    def snapshot(self) -> list[dict]:
        """Per-key breaker state for reports — quarantined plans are
        the entries with ``state != "closed"``."""
        with self._lock:
            return [{"key": k, "state": r["state"], "fails": r["fails"],
                     "opens": r["opens"], "label": r["label"]}
                    for k, r in self._keys.items()]


def _all_finite(out) -> bool:
    if isinstance(out, tuple):
        return all(_all_finite(o) for o in out)
    return bool(np.isfinite(np.asarray(out)).all())


# --------------------------------------------------------------------------
# entries & tickets
# --------------------------------------------------------------------------

@dataclass
class _PlanEntry:
    """One compiled (region × shape class × context) unit: the batching
    currency.  ``digest`` is the structural whole-plan signature —
    entries from *different* region objects with equal digests land in
    the same batch bucket and share one jitted executable."""
    label: str
    compiled: Compiled
    planned: Planned
    call_order: list[str]
    class_shapes: dict[str, tuple[int, int]]
    padded_names: frozenset
    out_axes: tuple
    n_outputs: int
    batchable: bool
    digest: str
    pad_safe: bool
    batched_fn: Optional[object] = field(default=None, repr=False)
    #: build-ladder outcome: "batched" | "exact" | "per_op"
    build_tier: str = "batched"
    per_op_fn: Optional[Compiled] = field(default=None, repr=False)

    @property
    def bucket_key(self) -> tuple:
        # unbatchable entries never co-batch: bucket by identity
        return ("plan", self.digest, tuple(sorted(self.class_shapes.items()))) \
            if self.batchable else ("entry", id(self))

    def per_op(self) -> Compiled:
        """The bottom ladder tier: per-operator interpreted dispatch
        (``staged=False``) — no whole-plan jit involved.  Built lazily
        on first degradation; a racing duplicate build is benign (the
        operator-level plan cache is shared)."""
        if self.per_op_fn is None:
            self.per_op_fn = self.planned.compile(staged=False)
        return self.per_op_fn


@dataclass
class _Ticket:
    entry: _PlanEntry
    pos: list                      # canonical arrays, call_order, unpadded
    kw: dict                       # original operands (unbatchable path)
    m: int                         # true leading dim (0: nothing padded)
    padded: bool
    vector_world: bool
    future: Future
    t_submit: float
    deadline: Optional[float] = None   # absolute perf_counter, or None
    budget: int = 8                    # remaining re-execution charges


# --------------------------------------------------------------------------
# the server
# --------------------------------------------------------------------------

class FusionServer:
    """Async multi-tenant server over compiled fused plans.

    Parameters
    ----------
    workers
        Queue-draining threads.  JAX releases the GIL inside XLA
        executions, so >1 worker overlaps independent buckets.
        ``workers=0`` builds a warm-only server (``warm()`` /
        ``warmed_plans()`` work; ``submit`` raises).
    max_batch
        Requests per batched dispatch.  Batch sizes are padded up to
        powers of two (≤ ``max_batch``) so the vmapped executable
        compiles O(log max_batch) shapes per bucket, not one per
        occupancy.  ``max_batch=1`` is the per-request-dispatch
        baseline the load harness compares against.
    pad_to
        Leading-dimension quantum of the shape classes (`0`/`1`
        disables padding: only exact shapes batch).
    context
        :class:`FusionContext` every request plans under (default: the
        scoped context at construction).  Layout-bearing contexts and
        sparse operands are served unbatched (vmap cannot cross
        ``shard_map``).
    plan_cache_capacity / whole_plan_cache_capacity
        Optional resize of the two global LRU plan caches — the
        lifecycle knob for long-lived processes churning through many
        plan structures.
    max_queue
        Bound on the admission queue (0: unbounded).  A full queue
        rejects at ``submit`` with :class:`QueueFullError` — typed
        backpressure instead of unbounded memory growth.
    default_deadline_s
        Deadline applied to every request that does not pass its own
        ``deadline_s`` to ``submit`` (None: no deadline).  Expired
        requests resolve with :class:`DeadlineExceededError` at dequeue
        and at every degradation step; an execution already in flight
        runs to completion.
    retry_budget
        Re-execution charges per request: each bisection half-dispatch
        and each ladder tier costs one.  Exhaustion resolves the future
        with :class:`RequestFailedError` (cause chained).
    check_finite
        Verify every tier's outputs are finite; NaN/Inf results degrade
        down the ladder and, if reproduced at the bottom, fail with
        :class:`NonFiniteOutputError`.  Off by default (host-side
        ``isfinite`` scan per output).
    breaker_threshold / breaker_cooldown_s
        Circuit-breaker tuning: consecutive tier-0 failures before a
        plan digest is quarantined, and how long before a half-open
        probe is admitted.  ``server.breaker.snapshot()`` lists
        quarantined plans; so does ``metrics.report(server)``.
    """

    def __init__(self, *, workers: int = 2, max_batch: int = 16,
                 pad_to: int = 64, context: Optional[FusionContext] = None,
                 plan_cache_capacity: Optional[int] = None,
                 whole_plan_cache_capacity: Optional[int] = None,
                 autostart: bool = True,
                 max_queue: int = 0,
                 default_deadline_s: Optional[float] = None,
                 retry_budget: int = 8,
                 check_finite: bool = False,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0):
        self.workers = int(workers)
        self.max_batch = max(1, int(max_batch))
        self.pad_to = max(0, int(pad_to))
        self.max_queue = max(0, int(max_queue))
        self.default_deadline_s = default_deadline_s
        self.retry_budget = max(0, int(retry_budget))
        self.check_finite = bool(check_finite)
        self._ctx = context if context is not None else current_context()
        if plan_cache_capacity is not None:
            PLAN_CACHE.resize(plan_cache_capacity)
        if whole_plan_cache_capacity is not None:
            WHOLE_PLAN_CACHE.resize(whole_plan_cache_capacity)
        self.metrics = ServerMetrics()
        self.breaker = CircuitBreaker(threshold=breaker_threshold,
                                      cooldown_s=breaker_cooldown_s,
                                      metrics=self.metrics)
        self._queue: "deque[_Ticket]" = deque()
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._entry_lock = threading.RLock()
        self._entries: "OrderedDict[tuple, _PlanEntry]" = OrderedDict()
        self._routes: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._threads: list[threading.Thread] = []
        self._started = False
        if autostart and self.workers > 0:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Spin up the worker threads (idempotent)."""
        if self._started or self.workers <= 0:
            return
        self._started = True
        for i in range(self.workers):
            t = threading.Thread(target=self._worker_loop,
                                 name=f"fusion-serve-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def close(self, timeout: float = 10.0) -> None:
        """Stop the workers, reject new submissions, and resolve every
        still-queued ticket with :class:`ServerClosedError` — a
        submitted request's future never stays pending forever."""
        with self._cv:
            self._closed = True
            self._stop = True
            self._cv.notify_all()
        # a crashing worker may respawn a replacement concurrently with
        # close(); join until the thread list stops changing
        for _ in range(4):
            with self._cv:
                threads = list(self._threads)
            if not threads:
                break
            for t in threads:
                t.join(timeout=timeout)
            with self._cv:
                self._threads = [t for t in self._threads if t.is_alive()]
                if not self._threads:
                    break
        with self._cv:
            leftover, self._queue = list(self._queue), deque()
        for t in leftover:
            if not t.future.done():
                t.future.set_exception(ServerClosedError(
                    "FusionServer closed while the request was queued"))
                self.metrics.on_cancel()

    def __enter__(self) -> "FusionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission -----------------------------------------------------------
    def submit(self, region, *args, deadline_s: Optional[float] = None,
               retries: Optional[int] = None, **kwargs) -> Future:
        """Enqueue one invocation of ``region`` (a ``fused`` wrapper) on
        the given operands; returns a :class:`concurrent.futures.Future`
        resolving to the same values and shapes ``region(*args,
        **kwargs)`` would return (same 1-D/0-D canonicalization
        round-trip), materialized as host NumPy arrays — results cross
        the batch boundary through the host anyway, and re-wrapping each
        request's slice as a device array would cost one dispatch per
        request, which is exactly the overhead batching exists to
        amortize.  Typed :class:`FusionServeError`\\ s are raised *here*
        — a request that cannot be served is never enqueued.

        ``deadline_s`` / ``retries`` override the server's
        ``default_deadline_s`` / ``retry_budget`` per request (they are
        control parameters, not operands — a region operand with either
        name must be passed positionally)."""
        if self._closed:
            self.metrics.on_reject()
            raise ServerClosedError("submit on a closed FusionServer")
        if not self._started:
            self.metrics.on_reject()
            raise ServerClosedError(
                "FusionServer has no running workers (workers=0 or not "
                "started); call start() or construct with autostart=True")
        names = getattr(region, "names", None)
        if names is None or not hasattr(region, "trace"):
            self.metrics.on_reject()
            raise FusionServeError(
                f"submit expects a fused region (repro.core.Fused), got "
                f"{type(region).__name__}")
        bound = dict(zip(names, args))
        bound.update(kwargs)
        if set(bound) != set(names):
            self.metrics.on_reject()
            missing = set(names) - set(bound)
            extra = set(bound) - set(names)
            raise AdmissionError(
                f"operands do not match region signature {names}: "
                f"missing {sorted(missing)}, unexpected {sorted(extra)}")
        try:
            shapes = {n: _canon_shape(n, v)[0] for n, v in bound.items()}
            vector_world = any(_canon_shape(n, v)[1] < 2
                               for n, v in bound.items())
        except TypeError as e:          # FusionInputError subclasses this
            self.metrics.on_reject()
            raise AdmissionError(str(e)) from e
        if self.max_queue and len(self._queue) >= self.max_queue:
            # early check outside the lock keeps the breaker's probe
            # accounting clean; the authoritative check is at enqueue
            self.metrics.on_reject("backpressure")
            raise QueueFullError(
                f"admission queue is full ({self.max_queue} requests); "
                "shed load or retry with backoff")
        entry, m, was_padded = self._route(region, bound, shapes)
        allowed, state = self.breaker.allow(entry.digest)
        if not allowed:
            self.metrics.on_reject("quarantined")
            raise PlanQuarantinedError(
                f"plan {entry.digest} ({entry.label}) is quarantined by "
                f"the circuit breaker (state={state}); retry after the "
                f"cooldown ({self.breaker.cooldown_s}s)")
        deadline = deadline_s if deadline_s is not None \
            else self.default_deadline_s
        budget = retries if retries is not None else self.retry_budget
        if entry.batchable or entry.padded_names:
            # materialize host copies here, in the client's thread —
            # worker time is the serving bottleneck, submit time is not.
            # padded-class entries need them even when unbatchable (a
            # degraded build serves the class per-request at class
            # shapes: same zero-fill marshalling, batch of one)
            pos = [np.asarray(_canon_value(n, bound[n]), np.float32)
                   for n in entry.call_order]
        else:
            pos = []
        now = time.perf_counter()
        ticket = _Ticket(entry=entry, pos=pos, kw=bound, m=m,
                         padded=was_padded, vector_world=vector_world,
                         future=Future(), t_submit=now,
                         deadline=None if deadline is None
                         else now + float(deadline),
                         budget=max(0, int(budget)))
        with self._cv:
            if self.max_queue and len(self._queue) >= self.max_queue:
                self.breaker.cancel_probe(entry.digest)
                self.metrics.on_reject("backpressure")
                raise QueueFullError(
                    f"admission queue is full ({self.max_queue} "
                    "requests); shed load or retry with backoff")
            self._queue.append(ticket)
            depth = len(self._queue)
            self._cv.notify()
        self.metrics.on_submit(depth)
        return ticket.future

    # -- routing: request shapes → compiled entry ----------------------------
    def _route(self, region, bound: dict,
               shapes: dict) -> tuple[_PlanEntry, int, bool]:
        fmts = tuple(
            "bcsr" if isinstance(bound[n], BCSR) else
            "dict" if isinstance(bound[n], DictCompressed) else "dense"
            for n in region.names)
        rkey = (id(region), tuple(shapes[n] for n in region.names), fmts,
                self._ctx.key())
        with self._entry_lock:
            hit = self._routes.get(rkey)
            if hit is not None:
                self._routes.move_to_end(rkey)
                return hit
            route = self._build_route(region, bound, shapes, fmts)
            self._routes[rkey] = route
            while len(self._routes) > 4096:
                self._routes.popitem(last=False)
            return route

    def _build_route(self, region, bound, shapes, fmts):
        batchable = (self._ctx.layout is None
                     and all(f == "dense" for f in fmts)
                     and self.max_batch > 1)
        cls = _shape_class(shapes, self.pad_to) if batchable else None
        pad_fallback = False
        if cls is not None:
            padded_shapes, padded_names, _m = cls
            # always analyze: a boundary-exact request (padding a no-op
            # for it) still joins a class that later requests pad into
            try:
                traced = region.trace(**{
                    n: jax.ShapeDtypeStruct(padded_shapes[n], jnp.float32)
                    for n in region.names})
                report = pad_safety(traced.graph, padded_names)
            except Exception:       # padding broke trace-time shape rules
                report = PadReport(False, (), "trace failed at padded shapes")
            if not report.safe:
                pad_fallback = True
                cls = None
        if cls is not None:
            class_shapes, padded_names, m = cls
        else:
            class_shapes, padded_names, m = dict(shapes), frozenset(), 0
        entry = self._entry(region, bound, class_shapes, padded_names,
                            fmts, batchable, pad_fallback)
        was_padded = bool(padded_names) and class_shapes != shapes
        return entry, m, was_padded

    def _entry(self, region, bound, class_shapes, padded_names, fmts,
               batchable, pad_fallback) -> _PlanEntry:
        ekey = (id(region), tuple(sorted(class_shapes.items())), fmts,
                self._ctx.key())
        hit = self._entries.get(ekey)
        if hit is not None:
            return hit
        name = getattr(region.fn, "__name__", "<expr>")
        dims = "/".join(f"{r}x{c}" for r, c in
                        (class_shapes[n] for n in region.names))
        label = f"{name}[{dims}]"
        # build circuit breaker: a compile failure that recurs on every
        # retry must not cost a full rebuild per submit
        bkey = "build:" + WholePlanCache.key_digest(ekey)
        allowed, state = self.breaker.allow(bkey)
        if not allowed:
            self.metrics.on_reject("quarantined")
            raise PlanQuarantinedError(
                f"plan compile for {label} is quarantined after repeated "
                f"build failures (state={state}); retry after the "
                f"cooldown ({self.breaker.cooldown_s}s)")
        t0 = time.perf_counter()
        operands = {}
        for n in region.names:
            v = bound[n]
            if isinstance(v, (BCSR, DictCompressed)):
                operands[n] = v              # trace reads shape + density
            else:
                operands[n] = jax.ShapeDtypeStruct(class_shapes[n],
                                                   jnp.float32)
        try:
            traced = region.trace(**operands)
            planned = traced.plan(context=self._ctx)
        except Exception as e:
            self.breaker.record_failure(bkey, label=label)
            raise PlanCompileError(
                f"trace/plan failed for {label}: {e}") from e
        if padded_names:
            report = pad_safety(traced.graph, padded_names)
            assert report.safe, "pad-checked class re-verified unsafe"
            out_axes = report.out_axes
        else:
            out_axes = tuple(None for _ in traced.graph.outputs)
        # build ladder: batched whole-plan → exact-shape staged → per-op
        # (staged=False).  Each degradation is recorded in the runtime-
        # fallback ledger; total build failure opens the build breaker.
        compiled = batched_fn = None
        build_tier = "batched" if batchable else "exact"
        if batchable:
            try:
                compiled = planned.compile()
                batched_fn = compiled.batched()
            except Exception as e:           # noqa: BLE001 — degrade
                self.metrics.on_runtime_fallback(
                    "plan.jit_build",
                    f"batched whole-plan build failed for {label} "
                    f"({type(e).__name__}: {e}); serving exact-shape "
                    "per-request", tier="exact")
                compiled, batchable, build_tier = None, False, "exact"
        if compiled is None:
            try:
                compiled = planned.compile()
            except Exception as e:           # noqa: BLE001 — degrade
                self.metrics.on_runtime_fallback(
                    "plan.jit_build",
                    f"staged compile failed for {label} "
                    f"({type(e).__name__}: {e}); serving per-op "
                    "staged=False", tier="per_op")
                try:
                    compiled = planned.compile(staged=False)
                    build_tier = "per_op"
                except Exception as e2:
                    self.breaker.record_failure(bkey, label=label)
                    raise PlanCompileError(
                        f"no executable exists for {label} on any ladder "
                        f"tier: {e2}") from e2
        self.breaker.record_success(bkey)
        digest = WholePlanCache.key_digest(compiled.plan_key())
        entry = _PlanEntry(
            label=label, compiled=compiled, planned=planned,
            call_order=compiled.input_order, class_shapes=class_shapes,
            padded_names=padded_names, out_axes=out_axes,
            n_outputs=len(traced.graph.outputs), batchable=batchable,
            digest=digest, pad_safe=not pad_fallback,
            build_tier=build_tier)
        if build_tier == "per_op":
            entry.per_op_fn = compiled
        if batchable:
            entry.batched_fn = batched_fn
        self._entries[ekey] = entry
        self.metrics.on_compile(digest, time.perf_counter() - t0,
                                pad_fallback=pad_fallback)
        return entry

    # -- warming -------------------------------------------------------------
    def warm(self, regions, execute: bool = True,
             batch_sizes: tuple = (1,)) -> dict:
        """Compile plans ahead of traffic.  ``regions`` is an iterable of
        ``(region, operands)`` pairs — operands as arrays or
        ``ShapeDtypeStruct``\\ s (each distinct shape class to serve
        should be warmed).  ``execute=True`` additionally runs each
        entry on zeros — batchable entries once per batch size in
        ``batch_sizes`` (the vmapped executable compiles per
        power-of-two batch class; warming ``(1, 2, ..., max_batch)``
        keeps every XLA build out of the serving path), unbatchable
        entries once through the plain compiled call.  Returns a
        warming report (per-entry label/digest + cache stats)."""
        rows = []
        for region, operands in regions:
            names = getattr(region, "names", None)
            if names is None or set(operands) != set(names):
                raise FusionServeError(
                    f"warm: operands do not match region signature {names}")
            shapes = {n: _canon_shape(n, v)[0]
                      for n, v in operands.items()}
            entry, _m, _p = self._route(region, operands, shapes)
            block = lambda o: jax.tree_util.tree_map(
                lambda a: a.block_until_ready()
                if hasattr(a, "block_until_ready") else a, o)
            if execute and entry.batchable:
                for b in batch_sizes:
                    bc = _pow2_at_least(int(b), self.max_batch)
                    zeros = [jnp.zeros((bc,) + tuple(entry.class_shapes[n]),
                                       jnp.float32)
                             for n in entry.call_order]
                    block(entry.batched_fn(*zeros))
            elif execute and not any(
                    isinstance(v, (BCSR, DictCompressed))
                    for v in operands.values()):
                zeros = {n: jnp.zeros(entry.class_shapes[n], jnp.float32)
                         for n in entry.call_order}
                block(entry.compiled(**zeros))
            rows.append({"label": entry.label, "digest": entry.digest,
                         "batchable": entry.batchable,
                         "pad_safe": entry.pad_safe})
        from dataclasses import asdict
        from repro.core import whole_plan_cache_stats
        return {"entries": rows,
                "whole_plan_cache": asdict(whole_plan_cache_stats())}

    def warmed_plans(self) -> list[tuple[str, Planned]]:
        """(label, Planned) for every compiled entry — the hook
        ``tools/fusionlint.py --serving`` uses to strict-verify exactly
        the plans the serving path executes."""
        with self._entry_lock:
            return [(e.label, e.planned) for e in self._entries.values()]

    # -- worker --------------------------------------------------------------
    def _worker_loop(self) -> None:
        batch: list[_Ticket] = []
        try:
            while True:
                with self._cv:
                    while not self._queue and not self._stop:
                        self._cv.wait(timeout=0.1)
                    if not self._queue:
                        if self._stop:
                            return
                        continue
                    head = self._queue.popleft()
                    batch = [head]
                    if self.max_batch > 1:
                        rest: "deque[_Ticket]" = deque()
                        bk = head.entry.bucket_key
                        while self._queue:
                            t = self._queue.popleft()
                            if len(batch) < self.max_batch and \
                                    t.entry.bucket_key == bk:
                                batch.append(t)
                            else:
                                rest.append(t)
                        self._queue.extend(rest)
                    depth = len(self._queue)
                faults.fault_point("serve.worker")
                self._execute(batch, depth)
                batch = []
        except BaseException as e:        # noqa: BLE001 — crash: respawn
            self._on_worker_crash(batch, e)

    def _on_worker_crash(self, inflight: list[_Ticket], err) -> None:
        """Crash containment: requeue the dead worker's unresolved
        tickets at the queue front and spawn a replacement thread — the
        pool never shrinks silently."""
        self.metrics.on_worker_crash(type(err).__name__)
        me = threading.current_thread()
        replacement = None
        with self._cv:
            pending = [t for t in inflight if not t.future.done()]
            self._queue.extendleft(reversed(pending))
            if pending:
                self.metrics.on_requeue(len(pending))
            try:
                self._threads.remove(me)
            except ValueError:
                pass
            if not self._stop:
                replacement = threading.Thread(
                    target=self._worker_loop, name=me.name, daemon=True)
                self._threads.append(replacement)
                self.metrics.on_worker_respawn()
            self._cv.notify_all()
        if replacement is not None:
            replacement.start()

    # -- execution: tier-0 dispatch, bisection, degradation ladder -----------
    def _execute(self, batch: list[_Ticket], depth: int) -> None:
        batch = [t for t in batch if not self._expire(t)]
        if not batch:
            return
        entry = batch[0].entry
        if entry.batchable:
            self._dispatch(entry, batch, depth)
        else:
            for t in batch:              # isolation: one future per try
                self._single(t, depth)

    def _expire(self, t: _Ticket) -> bool:
        """Deadline check at dequeue and at every ladder step."""
        if t.future.done():
            return True
        if t.deadline is not None and time.perf_counter() > t.deadline:
            t.future.set_exception(DeadlineExceededError(
                f"deadline passed before {t.entry.label} finished"))
            self.metrics.on_deadline(t.entry.digest)
            return True
        return False

    def _charge(self, t: _Ticket, cause: Exception) -> bool:
        """Spend one re-execution charge; False (and a terminal typed
        error on the future) when the budget is exhausted."""
        if t.future.done():
            return False
        if t.budget <= 0:
            err = RequestFailedError(
                f"retry budget exhausted for {t.entry.label}: "
                f"{type(cause).__name__}: {cause}")
            err.__cause__ = cause
            t.future.set_exception(err)
            self.metrics.on_retries_exhausted(t.entry.digest)
            self.metrics.on_result(t.entry.digest, None, failed=True)
            return False
        t.budget -= 1
        return True

    def _dispatch(self, entry: _PlanEntry, batch: list[_Ticket],
                  depth: int) -> None:
        """Tier 0: one batched vmapped dispatch.  Failure bisects the
        batch (poison-request isolation: a bad operand fails only its
        own future) and sends singletons down the degradation ladder."""
        try:
            rule = faults.fault_point("serve.batch_dispatch")
            per = self._run_batched(entry, batch)
            if rule is not None:         # injected nonfinite: poison
                per = [faults.poison(p) for p in per]
        except Exception as e:            # noqa: BLE001 — ladder
            self.metrics.on_dispatch(entry.digest, len(batch), 0, depth,
                                     failed=True)
            self.breaker.record_failure(entry.digest, label=entry.label)
            if len(batch) == 1:
                t = batch[0]
                if not self._expire(t) and self._charge(t, e):
                    self._degrade(t, e, depth)
                return
            self.metrics.on_bisect()
            self.metrics.on_runtime_fallback(
                "serve.batch_dispatch",
                f"batched dispatch of {len(batch)} requests failed "
                f"({type(e).__name__}); bisecting to isolate the poison "
                "request", tier="bisect")
            mid = len(batch) // 2
            for half in (batch[:mid], batch[mid:]):
                half = [t for t in half
                        if not self._expire(t) and self._charge(t, e)]
                if half:
                    self._dispatch(entry, half, depth)
            return
        self.breaker.record_success(entry.digest)
        now = time.perf_counter()
        self.metrics.on_dispatch(entry.digest, len(batch),
                                 sum(1 for t in batch if t.padded), depth)
        for t, outs in zip(batch, per):
            if t.future.done():
                continue
            if self.check_finite and not _all_finite(outs):
                err = NonFiniteOutputError(
                    f"batched result for {t.entry.label} is non-finite")
                self.metrics.on_nonfinite(entry.digest)
                if self._charge(t, err):
                    self._degrade(t, err, depth)
                continue
            t.future.set_result(outs)
            self.metrics.on_result(entry.digest,
                                   (now - t.t_submit) * 1e6)

    def _single(self, t: _Ticket, depth: int) -> None:
        """Unbatchable (sparse / layout / degraded-build) path: tier 0
        is the exact-shape staged call; failures continue at per-op."""
        if self._expire(t):
            return
        try:
            faults.fault_point("serve.batch_dispatch")
            out = self._run_tier(t, t.entry.compiled)
            if self.check_finite and not _all_finite(out):
                self.metrics.on_nonfinite(t.entry.digest)
                raise NonFiniteOutputError(
                    f"result for {t.entry.label} is non-finite")
        except Exception as e:            # noqa: BLE001 — ladder
            self.metrics.on_dispatch(t.entry.digest, 1, 0, depth,
                                     failed=True)
            self.breaker.record_failure(t.entry.digest,
                                        label=t.entry.label)
            if self._charge(t, e):
                self._degrade(t, e, depth, tiers=("per_op",))
            return
        self.breaker.record_success(t.entry.digest)
        self.metrics.on_dispatch(t.entry.digest, 1, 0, depth)
        t.future.set_result(out)
        self.metrics.on_result(t.entry.digest,
                               (time.perf_counter() - t.t_submit) * 1e6)

    def _degrade(self, t: _Ticket, cause: Exception, depth: int,
                 tiers: tuple = ("exact", "per_op")) -> None:
        """Walk the remaining ladder tiers for one request.  Every
        degradation is recorded in the runtime-fallback ledger — the
        run-time extension of ``record_fallback`` — and charged against
        the retry budget.  The bottom of the ladder is a typed terminal
        error chaining the original cause."""
        entry = t.entry
        for i, tier in enumerate(tiers):
            if self._expire(t):
                return
            if i > 0 and not self._charge(t, cause):
                return
            try:
                if tier == "exact":
                    out = self._run_tier(t, entry.compiled)
                else:
                    out = self._run_tier(t, entry.per_op())
                if self.check_finite and not _all_finite(out):
                    self.metrics.on_nonfinite(entry.digest)
                    raise NonFiniteOutputError(
                        f"{tier} result for {entry.label} is non-finite")
            except Exception as e:        # noqa: BLE001 — next tier
                cause = e
                continue
            self.metrics.on_degrade(tier, entry.digest)
            self.metrics.on_runtime_fallback(
                "serve.batch_dispatch",
                f"request re-executed at tier '{tier}' after "
                f"{type(cause).__name__}", tier=tier)
            t.future.set_result(out)
            self.metrics.on_result(entry.digest,
                                   (time.perf_counter() - t.t_submit) * 1e6)
            return
        if t.future.done():
            return
        if isinstance(cause, NonFiniteOutputError):
            t.future.set_exception(cause)
        else:
            err = RequestFailedError(
                f"every degradation tier failed for {entry.label}: "
                f"{type(cause).__name__}: {cause}")
            err.__cause__ = cause
            t.future.set_exception(err)
        self.metrics.on_result(entry.digest, None, failed=True)

    def _run_tier(self, t: _Ticket, fn):
        """Run one request through ``fn`` — a Compiled at the entry's
        class shapes (staged exact tier or per-op tier).  Padded-class
        tickets marshal exactly like one row of the batched path:
        zero-fill up to class shapes (the pad-safety analysis already
        proved that exact) and slice the outputs back.  Exact-shape
        tickets pass their operands straight through — the Compiled
        call handles canonicalization and the 1-D/0-D round trip."""
        entry = t.entry
        if not t.pos:
            out = fn(**t.kw)
            if isinstance(out, tuple):
                return tuple(np.asarray(o) for o in out)
            return np.asarray(out)
        kwargs = {}
        for i, name in enumerate(entry.call_order):
            r, c = entry.class_shapes[name]
            v = t.pos[i]
            if v.shape != (r, c):
                buf = np.zeros((r, c), np.float32)
                buf[:v.shape[0], :v.shape[1]] = v
                v = buf
            kwargs[name] = v
        out = fn(**kwargs)
        outs = out if isinstance(out, tuple) else (out,)
        vals = []
        for k, o in enumerate(outs):
            v = np.asarray(o)
            ax = entry.out_axes[k]
            if ax == 0 and t.m and v.ndim >= 1 and v.shape[0] != t.m:
                v = v[:t.m]
            elif ax == 1 and t.m and v.ndim >= 2 and v.shape[1] != t.m:
                v = v[:, :t.m]
            vals.append(_uncanon_np(v) if t.vector_world else v)
        return vals[0] if len(vals) == 1 else tuple(vals)

    def _run_batched(self, entry: _PlanEntry,
                     batch: list[_Ticket]) -> list:
        # Marshalling runs in NumPy on purpose: per-request jnp.pad/
        # jnp.stack/slice would issue ~4 small XLA dispatches per
        # request — more than the batching saves.  One zero-filled host
        # buffer per operand (zero fill IS the padding) and a single
        # device transfer keeps the worker at O(#operands) dispatches
        # per batch regardless of occupancy.
        B = len(batch)
        Bc = _pow2_at_least(B, self.max_batch)
        stacked = []
        for i, name in enumerate(entry.call_order):
            r, c = entry.class_shapes[name]
            buf = np.empty((Bc, r, c), np.float32)
            for j, t in enumerate(batch):
                v = t.pos[i]
                vr, vc = v.shape
                buf[j, :vr, :vc] = v
                if vr < r:
                    buf[j, vr:, :] = 0.0     # the zero fill IS the padding
                if vc < c:
                    buf[j, :vr, vc:] = 0.0
            if Bc > B:                       # batch-axis padding
                buf[B:] = buf[0]
            stacked.append(buf)              # jit device_puts once per arg
        outs = entry.batched_fn(*stacked)
        outs_np = [np.asarray(outs[k]) for k in range(entry.n_outputs)]
        per = []
        for j, t in enumerate(batch):
            vals = []
            for k in range(entry.n_outputs):
                v = outs_np[k][j]
                ax = entry.out_axes[k]
                if ax == 0 and t.m and v.shape[0] != t.m:
                    v = v[:t.m]
                elif ax == 1 and t.m and v.shape[1] != t.m:
                    v = v[:, :t.m]
                vals.append(_uncanon_np(v) if t.vector_world else v)
            per.append(vals[0] if len(vals) == 1 else tuple(vals))
        return per
