"""Multi-tenant fused-plan serving: async continuous batching over
compiled whole plans.

The optimizer pays off only when compiled fusion plans are *reused* —
optimization and codegen cost amortize across invocations (the paper's
Fig. 11 argument).  :class:`FusionServer` is the traffic-facing form of
that claim: many concurrent clients submit fused-region invocations
(``submit(region, args) -> Future``), worker threads drain a shared
queue, and requests whose plans are structurally equal are executed as
*one* batched dispatch of the shared staged executable.

Request path::

    submit(region, args)
      └─ canonicalize operands → shape class (rows padded up to pad_to)
         └─ route: (region, class) → _PlanEntry  [trace→plan→compile,
            memoized; structurally-equal plans share one staged fn via
            the whole-plan cache]
            └─ enqueue ticket, bucketed by structural plan digest
    worker: pop head ticket, drain same-bucket tickets (≤ max_batch)
      └─ zero-pad each request to the bucket's shape class, stack on a
         new leading axis, ONE call of the jitted vmapped whole-plan fn
         └─ slice each request's outputs back to its true shape,
            resolve futures, record latency/occupancy metrics

**Shape bucketing & padding.**  Requests rarely share exact shapes, so
the leading ("row") dimension is padded up to the next multiple of
``pad_to`` and requests sharing the padded class batch together.
Zero-padding is only sound for some plans: a padded row flows through
``relu(1 - y*(X@w))`` as a garbage-but-confined row (sliced away on
return), but through ``(...).sum()`` it *pollutes the scalar*.  The
server runs a static **pad-safety analysis** over the traced HOP DAG —
propagating "padded rows are zero / finite garbage / possibly non-
finite" through every operator and rejecting any contraction over the
padded dimension whose operand is not provably zero (zero rows are
exact under ``sum``/``sum_sq``/``matmul`` contractions; ``mean``/
``min``/``max`` over padded rows never are).  Plans that fail the
analysis degrade to **exact-shape buckets** (only identical shapes
batch — still one dispatch per batch), recorded as ``pad_fallbacks`` in
the metrics.  Batch elements are vmapped, therefore independent: the
batched result equals per-request execution (tested to 1e-5).

**Plan-cache lifecycle.**  Entries are memoized per (region, shape
class, context); underneath, the bounded LRU
:class:`~repro.core.codegen.WholePlanCache` shares one jitted function
across structurally-equal plans and its per-key hit/miss/eviction/
build-time counters survive entry churn.  ``warm(regions)`` compiles
(and optionally executes) plans ahead of traffic;
``FusionServer(plan_cache_capacity=..., whole_plan_cache_capacity=...)``
bounds both global caches for long-lived processes.

Metrics (:mod:`repro.serve.metrics`): p50/p95/p99 latency, queue depth,
batch occupancy, per-bucket counters, and cache stats — exported by
``metrics.snapshot()`` / ``report()``.  The load harness
(``benchmarks/serving.py``) drives N simulated clients against the
l2svm/mlogreg scoring regions and records serving throughput and tail
latency in ``BENCH_fusion.json``.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.core.api import Compiled, Planned, _canon_shape, _canon_value
from repro.core.codegen import (PLAN_CACHE, WHOLE_PLAN_CACHE,
                                WholePlanCache)
from repro.core.context import FusionContext, current_context
from repro.kernels.blocksparse import BCSR, DictCompressed
from .metrics import ServerMetrics


class FusionServeError(RuntimeError):
    """Typed serving error raised at ``submit``/``warm`` time (bad
    region object, unknown operands, closed server) — requests that
    cannot be admitted are rejected here, never enqueued."""


class ServerClosedError(FusionServeError):
    """The server has been closed (or has no workers to drain the
    queue); the request was not enqueued."""


# --------------------------------------------------------------------------
# static pad-safety analysis
# --------------------------------------------------------------------------

_ZERO, _FIN, _NAN = 0, 1, 2          # padded-slice value severity
#: unary ops with f(0) finite but nonzero (padded zeros stop being zero)
_FIN_AT_ZERO = frozenset({"exp", "sigmoid", "softplus"})
#: unary ops that can turn finite garbage non-finite (domain edges)
_NAN_RISK = frozenset({"log", "recip", "sqrt", "log1p"})


@dataclass(frozen=True)
class PadReport:
    """Outcome of the pad-safety analysis for one traced region.

    ``safe`` — zero-padding the marked inputs' leading dimension and
    slicing every output back is exact; ``out_axes`` — per graph output,
    the axis carrying the padded dimension (``None``: the output never
    sees it and is exact as-is); ``reason`` — first violated rule when
    unsafe (drives the ``pad_fallbacks`` metric)."""
    safe: bool
    out_axes: tuple = ()
    reason: str = ""


def pad_safety(graph: ir.Graph, padded_inputs: frozenset) -> PadReport:
    """Decide whether zero-padding ``padded_inputs`` along axis 0 is
    exact for every output of ``graph`` (after slicing).

    Per node we track whether it carries the padded dimension (axis 0 or
    1 — transposes flip it) and what its padded slice provably holds:
    exactly **zero** (padding survives zero-preserving cell ops and
    anchors exact ``sum``/``matmul`` contractions), **finite garbage**
    (confined to the padded rows — safe until contracted), or
    **possibly non-finite** (``log(0)``, ``x/0`` … — also confined, but
    poisons any contraction, since ``0 · nan = nan``).  A contraction
    over the padded dimension (matmul inner dim, ``colsums``, full
    aggregates) is exact iff one side's padded slice is zero and the
    other is finite; ``mean``/``min``/``max`` over the padded dimension
    are never exact.  Anything the table doesn't cover fails closed."""
    state: dict[int, Optional[tuple[int, int]]] = {}

    def unsafe(node: ir.Node, why: str) -> PadReport:
        return PadReport(False, (),
                         f"%{node.nid} {node.op}: {why}")

    def fin(s) -> bool:          # finite padded slice (or real data)
        return s is None or s[1] <= _FIN

    for node in graph.nodes:
        op, ins = node.op, node.inputs
        sts = [state.get(i.nid) for i in ins]
        if op == "input":
            state[node.nid] = (0, _ZERO) if node.name in padded_inputs \
                else None
            continue
        if op == "lit":
            state[node.nid] = None
            continue
        if all(s is None for s in sts):
            state[node.nid] = None
            continue
        if op == "t":
            ax, sev = sts[0]
            state[node.nid] = (1 - ax, sev)
        elif op == "idx":
            if sts[0][0] == 1:
                return unsafe(node, "column slice of the padded axis")
            state[node.nid] = sts[0]
        elif op == "matmul":
            sa, sb = sts[0], sts[1]
            ta, tb = node.ta, node.tb
            a_contract = sa is not None and sa[0] == (0 if ta else 1)
            b_contract = sb is not None and sb[0] == (1 if tb else 0)
            if a_contract or b_contract:
                a_zero = a_contract and sa[1] == _ZERO
                b_zero = b_contract and sb[1] == _ZERO
                if not ((a_zero and fin(sb)) or (b_zero and fin(sa))):
                    return unsafe(node, "contraction over the padded "
                                        "dimension of a non-zero operand")
            row_pad = sa is not None and sa[0] == (1 if ta else 0)
            col_pad = sb is not None and sb[0] == (0 if tb else 1)
            if row_pad and col_pad:
                return unsafe(node, "both result axes would be padded")
            if not row_pad and not col_pad:
                state[node.nid] = None          # contracted away: exact
            else:
                src = sa if row_pad else sb
                sev = _NAN if any(s is not None and s[1] == _NAN
                                  for s in sts) else \
                    (_ZERO if src[1] == _ZERO else _FIN)
                state[node.nid] = (0 if row_pad else 1, sev)
        elif node.op in ir.AGG_OPS and "axis" in node.attrs:
            s = sts[0]
            reduced = {"full": (0, 1), "row": (1,), "col": (0,)}[
                node.attrs["axis"]]
            if s[0] in reduced:
                if op in ("sum", "sum_sq") and s[1] == _ZERO:
                    state[node.nid] = None      # zeros add nothing: exact
                else:
                    return unsafe(node, f"{op} over the padded dimension "
                                        "of a non-zero operand")
            else:
                state[node.nid] = s             # row-local: confined
        elif op in ir.UNARY_OPS:
            ax, sev = sts[0]
            if sev == _ZERO:
                sev = _ZERO if op in ir.SPARSE_SAFE_UNARY else \
                    (_FIN if op in _FIN_AT_ZERO else _NAN)
            elif sev == _FIN and op in _NAN_RISK:
                sev = _NAN
            state[node.nid] = (ax, sev)
        elif op in ir.BINARY_OPS:
            axes = {s[0] for s in sts if s is not None}
            if len(axes) != 1:
                return unsafe(node, "operands carry different padded axes")
            ax = axes.pop()
            sevs = [s[1] if s is not None else None for s in sts]
            if op in ("eq", "neq", "lt", "le", "gt", "ge"):
                sev = _FIN                       # 0/1 output
            elif op == "mul":
                if (sevs[0] == _ZERO and fin(sts[1])) or \
                        (sevs[1] == _ZERO and fin(sts[0])):
                    sev = _ZERO
                elif _NAN in sevs:
                    sev = _NAN
                else:
                    sev = _FIN
            elif op in ("div", "pow"):
                sev = _NAN                       # 0/0, x/0, 0**-1 …
            else:                                # add/sub/min/max
                if sevs[0] == _ZERO and sevs[1] == _ZERO:
                    sev = _ZERO
                else:
                    sev = _NAN if _NAN in sevs else _FIN
            state[node.nid] = (ax, sev)
        elif op in ir.TERNARY_OPS:
            axes = {s[0] for s in sts if s is not None}
            if len(axes) != 1:
                return unsafe(node, "operands carry different padded axes")
            sev = _NAN if any(s is not None and s[1] == _NAN
                              for s in sts) else _FIN
            state[node.nid] = (axes.pop(), sev)
        else:                                    # diagv, unknown ops
            return unsafe(node, "no padding rule for this operator")

    out_axes = tuple(state[o.nid][0] if state.get(o.nid) is not None
                     else None for o in graph.outputs)
    return PadReport(True, out_axes)


# --------------------------------------------------------------------------
# shape classes
# --------------------------------------------------------------------------

def _shape_class(shapes: dict[str, tuple[int, int]],
                 pad_to: int) -> Optional[tuple[dict, frozenset, int]]:
    """Padded shape class for one request's canonical operand shapes.

    The "batch rows" dimension ``m`` is the largest leading dimension
    that does **not** also appear as any operand's column dimension —
    column dimensions are feature/contraction axes (``w`` in
    ``hinge(X(m,64), w(64,1), y(m,1))`` leads with the feature dim 64;
    excluding column dims picks ``m`` rows, not features).  ``m``
    rounds up to the next multiple of ``pad_to`` and every operand led
    by ``m`` pads with it.  Returns ``(padded shapes, padded operand
    names, m)``, or None when no unambiguous batch dimension exists
    (all leading dims ≤ 1 or double as column dims — e.g. square
    matrices); those requests batch only with exact shape twins."""
    if pad_to <= 1 or not shapes:
        return None
    col_dims = {c for _r, c in shapes.values()}
    cands = {r for r, _c in shapes.values() if r > 1 and r not in col_dims}
    if not cands:
        return None
    m = max(cands)
    big = -(-m // pad_to) * pad_to
    padded = {n: ((big, c) if r == m else (r, c))
              for n, (r, c) in shapes.items()}
    names = frozenset(n for n, (r, _c) in shapes.items() if r == m)
    return padded, names, m


def _pow2_at_least(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


def _uncanon_np(v: np.ndarray):
    """Host-side half of the canonicalization round-trip (mirrors
    ``repro.core.api._uncanon_output`` for NumPy results): (n, 1)
    columns → 1-D, (1, 1) → 0-D."""
    if v.shape == (1, 1):
        return v.reshape(())
    if v.ndim == 2 and v.shape[1] == 1:
        return v.reshape(-1)
    return v


# --------------------------------------------------------------------------
# entries & tickets
# --------------------------------------------------------------------------

@dataclass
class _PlanEntry:
    """One compiled (region × shape class × context) unit: the batching
    currency.  ``digest`` is the structural whole-plan signature —
    entries from *different* region objects with equal digests land in
    the same batch bucket and share one jitted executable."""
    label: str
    compiled: Compiled
    planned: Planned
    call_order: list[str]
    class_shapes: dict[str, tuple[int, int]]
    padded_names: frozenset
    out_axes: tuple
    n_outputs: int
    batchable: bool
    digest: str
    pad_safe: bool
    batched_fn: Optional[object] = field(default=None, repr=False)

    @property
    def bucket_key(self) -> tuple:
        # unbatchable entries never co-batch: bucket by identity
        return ("plan", self.digest, tuple(sorted(self.class_shapes.items()))) \
            if self.batchable else ("entry", id(self))


@dataclass
class _Ticket:
    entry: _PlanEntry
    pos: list                      # canonical arrays, call_order, unpadded
    kw: dict                       # original operands (unbatchable path)
    m: int                         # true leading dim (0: nothing padded)
    padded: bool
    vector_world: bool
    future: Future
    t_submit: float


# --------------------------------------------------------------------------
# the server
# --------------------------------------------------------------------------

class FusionServer:
    """Async multi-tenant server over compiled fused plans.

    Parameters
    ----------
    workers
        Queue-draining threads.  JAX releases the GIL inside XLA
        executions, so >1 worker overlaps independent buckets.
        ``workers=0`` builds a warm-only server (``warm()`` /
        ``warmed_plans()`` work; ``submit`` raises).
    max_batch
        Requests per batched dispatch.  Batch sizes are padded up to
        powers of two (≤ ``max_batch``) so the vmapped executable
        compiles O(log max_batch) shapes per bucket, not one per
        occupancy.  ``max_batch=1`` is the per-request-dispatch
        baseline the load harness compares against.
    pad_to
        Leading-dimension quantum of the shape classes (`0`/`1`
        disables padding: only exact shapes batch).
    context
        :class:`FusionContext` every request plans under (default: the
        scoped context at construction).  Layout-bearing contexts and
        sparse operands are served unbatched (vmap cannot cross
        ``shard_map``).
    plan_cache_capacity / whole_plan_cache_capacity
        Optional resize of the two global LRU plan caches — the
        lifecycle knob for long-lived processes churning through many
        plan structures.
    """

    def __init__(self, *, workers: int = 2, max_batch: int = 16,
                 pad_to: int = 64, context: Optional[FusionContext] = None,
                 plan_cache_capacity: Optional[int] = None,
                 whole_plan_cache_capacity: Optional[int] = None,
                 autostart: bool = True):
        self.workers = int(workers)
        self.max_batch = max(1, int(max_batch))
        self.pad_to = max(0, int(pad_to))
        self._ctx = context if context is not None else current_context()
        if plan_cache_capacity is not None:
            PLAN_CACHE.resize(plan_cache_capacity)
        if whole_plan_cache_capacity is not None:
            WHOLE_PLAN_CACHE.resize(whole_plan_cache_capacity)
        self.metrics = ServerMetrics()
        self._queue: "deque[_Ticket]" = deque()
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._entry_lock = threading.RLock()
        self._entries: "OrderedDict[tuple, _PlanEntry]" = OrderedDict()
        self._routes: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._threads: list[threading.Thread] = []
        self._started = False
        if autostart and self.workers > 0:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Spin up the worker threads (idempotent)."""
        if self._started or self.workers <= 0:
            return
        self._started = True
        for i in range(self.workers):
            t = threading.Thread(target=self._worker_loop,
                                 name=f"fusion-serve-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def close(self, timeout: float = 10.0) -> None:
        """Drain the queue, stop the workers, reject new submissions."""
        with self._cv:
            self._closed = True
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads = []

    def __enter__(self) -> "FusionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission -----------------------------------------------------------
    def submit(self, region, *args, **kwargs) -> Future:
        """Enqueue one invocation of ``region`` (a ``fused`` wrapper) on
        the given operands; returns a :class:`concurrent.futures.Future`
        resolving to the same values and shapes ``region(*args,
        **kwargs)`` would return (same 1-D/0-D canonicalization
        round-trip), materialized as host NumPy arrays — results cross
        the batch boundary through the host anyway, and re-wrapping each
        request's slice as a device array would cost one dispatch per
        request, which is exactly the overhead batching exists to
        amortize.  Typed :class:`FusionServeError`\\ s are raised *here*
        — a request that cannot be served is never enqueued."""
        if self._closed:
            self.metrics.on_reject()
            raise ServerClosedError("submit on a closed FusionServer")
        if not self._started:
            self.metrics.on_reject()
            raise ServerClosedError(
                "FusionServer has no running workers (workers=0 or not "
                "started); call start() or construct with autostart=True")
        names = getattr(region, "names", None)
        if names is None or not hasattr(region, "trace"):
            self.metrics.on_reject()
            raise FusionServeError(
                f"submit expects a fused region (repro.core.Fused), got "
                f"{type(region).__name__}")
        bound = dict(zip(names, args))
        bound.update(kwargs)
        if set(bound) != set(names):
            self.metrics.on_reject()
            missing = set(names) - set(bound)
            extra = set(bound) - set(names)
            raise FusionServeError(
                f"operands do not match region signature {names}: "
                f"missing {sorted(missing)}, unexpected {sorted(extra)}")
        try:
            shapes = {n: _canon_shape(n, v)[0] for n, v in bound.items()}
            vector_world = any(_canon_shape(n, v)[1] < 2
                               for n, v in bound.items())
        except TypeError as e:          # FusionInputError subclasses this
            self.metrics.on_reject()
            raise FusionServeError(str(e)) from e
        entry, m, was_padded = self._route(region, bound, shapes)
        if entry.batchable:
            # materialize host copies here, in the client's thread —
            # worker time is the serving bottleneck, submit time is not
            pos = [np.asarray(_canon_value(n, bound[n]), np.float32)
                   for n in entry.call_order]
        else:
            pos = []
        ticket = _Ticket(entry=entry, pos=pos, kw=bound, m=m,
                         padded=was_padded, vector_world=vector_world,
                         future=Future(), t_submit=time.perf_counter())
        with self._cv:
            self._queue.append(ticket)
            depth = len(self._queue)
            self._cv.notify()
        self.metrics.on_submit(depth)
        return ticket.future

    # -- routing: request shapes → compiled entry ----------------------------
    def _route(self, region, bound: dict,
               shapes: dict) -> tuple[_PlanEntry, int, bool]:
        fmts = tuple(
            "bcsr" if isinstance(bound[n], BCSR) else
            "dict" if isinstance(bound[n], DictCompressed) else "dense"
            for n in region.names)
        rkey = (id(region), tuple(shapes[n] for n in region.names), fmts,
                self._ctx.key())
        with self._entry_lock:
            hit = self._routes.get(rkey)
            if hit is not None:
                self._routes.move_to_end(rkey)
                return hit
            route = self._build_route(region, bound, shapes, fmts)
            self._routes[rkey] = route
            while len(self._routes) > 4096:
                self._routes.popitem(last=False)
            return route

    def _build_route(self, region, bound, shapes, fmts):
        batchable = (self._ctx.layout is None
                     and all(f == "dense" for f in fmts)
                     and self.max_batch > 1)
        cls = _shape_class(shapes, self.pad_to) if batchable else None
        pad_fallback = False
        if cls is not None:
            padded_shapes, padded_names, _m = cls
            # always analyze: a boundary-exact request (padding a no-op
            # for it) still joins a class that later requests pad into
            try:
                traced = region.trace(**{
                    n: jax.ShapeDtypeStruct(padded_shapes[n], jnp.float32)
                    for n in region.names})
                report = pad_safety(traced.graph, padded_names)
            except Exception:       # padding broke trace-time shape rules
                report = PadReport(False, (), "trace failed at padded shapes")
            if not report.safe:
                pad_fallback = True
                cls = None
        if cls is not None:
            class_shapes, padded_names, m = cls
        else:
            class_shapes, padded_names, m = dict(shapes), frozenset(), 0
        entry = self._entry(region, bound, class_shapes, padded_names,
                            fmts, batchable, pad_fallback)
        was_padded = bool(padded_names) and class_shapes != shapes
        return entry, m, was_padded

    def _entry(self, region, bound, class_shapes, padded_names, fmts,
               batchable, pad_fallback) -> _PlanEntry:
        ekey = (id(region), tuple(sorted(class_shapes.items())), fmts,
                self._ctx.key())
        hit = self._entries.get(ekey)
        if hit is not None:
            return hit
        t0 = time.perf_counter()
        operands = {}
        for n in region.names:
            v = bound[n]
            if isinstance(v, (BCSR, DictCompressed)):
                operands[n] = v              # trace reads shape + density
            else:
                operands[n] = jax.ShapeDtypeStruct(class_shapes[n],
                                                   jnp.float32)
        traced = region.trace(**operands)
        planned = traced.plan(context=self._ctx)
        compiled = planned.compile()
        if padded_names:
            report = pad_safety(traced.graph, padded_names)
            assert report.safe, "pad-checked class re-verified unsafe"
            out_axes = report.out_axes
        else:
            out_axes = tuple(None for _ in traced.graph.outputs)
        digest = WholePlanCache.key_digest(compiled.plan_key())
        name = getattr(region.fn, "__name__", "<expr>")
        dims = "/".join(f"{r}x{c}" for r, c in
                        (class_shapes[n] for n in region.names))
        entry = _PlanEntry(
            label=f"{name}[{dims}]", compiled=compiled, planned=planned,
            call_order=compiled.input_order, class_shapes=class_shapes,
            padded_names=padded_names, out_axes=out_axes,
            n_outputs=len(traced.graph.outputs), batchable=batchable,
            digest=digest, pad_safe=not pad_fallback)
        if batchable:
            entry.batched_fn = compiled.batched()
        self._entries[ekey] = entry
        self.metrics.on_compile(digest, time.perf_counter() - t0,
                                pad_fallback=pad_fallback)
        return entry

    # -- warming -------------------------------------------------------------
    def warm(self, regions, execute: bool = True,
             batch_sizes: tuple = (1,)) -> dict:
        """Compile plans ahead of traffic.  ``regions`` is an iterable of
        ``(region, operands)`` pairs — operands as arrays or
        ``ShapeDtypeStruct``\\ s (each distinct shape class to serve
        should be warmed).  ``execute=True`` additionally runs each
        entry on zeros — batchable entries once per batch size in
        ``batch_sizes`` (the vmapped executable compiles per
        power-of-two batch class; warming ``(1, 2, ..., max_batch)``
        keeps every XLA build out of the serving path), unbatchable
        entries once through the plain compiled call.  Returns a
        warming report (per-entry label/digest + cache stats)."""
        rows = []
        for region, operands in regions:
            names = getattr(region, "names", None)
            if names is None or set(operands) != set(names):
                raise FusionServeError(
                    f"warm: operands do not match region signature {names}")
            shapes = {n: _canon_shape(n, v)[0]
                      for n, v in operands.items()}
            entry, _m, _p = self._route(region, operands, shapes)
            block = lambda o: jax.tree_util.tree_map(
                lambda a: a.block_until_ready()
                if hasattr(a, "block_until_ready") else a, o)
            if execute and entry.batchable:
                for b in batch_sizes:
                    bc = _pow2_at_least(int(b), self.max_batch)
                    zeros = [jnp.zeros((bc,) + tuple(entry.class_shapes[n]),
                                       jnp.float32)
                             for n in entry.call_order]
                    block(entry.batched_fn(*zeros))
            elif execute and not any(
                    isinstance(v, (BCSR, DictCompressed))
                    for v in operands.values()):
                zeros = {n: jnp.zeros(entry.class_shapes[n], jnp.float32)
                         for n in entry.call_order}
                block(entry.compiled(**zeros))
            rows.append({"label": entry.label, "digest": entry.digest,
                         "batchable": entry.batchable,
                         "pad_safe": entry.pad_safe})
        from dataclasses import asdict
        from repro.core import whole_plan_cache_stats
        return {"entries": rows,
                "whole_plan_cache": asdict(whole_plan_cache_stats())}

    def warmed_plans(self) -> list[tuple[str, Planned]]:
        """(label, Planned) for every compiled entry — the hook
        ``tools/fusionlint.py --serving`` uses to strict-verify exactly
        the plans the serving path executes."""
        with self._entry_lock:
            return [(e.label, e.planned) for e in self._entries.values()]

    # -- worker --------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait(timeout=0.1)
                if not self._queue:
                    if self._stop:
                        return
                    continue
                head = self._queue.popleft()
                batch = [head]
                if self.max_batch > 1:
                    rest: "deque[_Ticket]" = deque()
                    bk = head.entry.bucket_key
                    while self._queue:
                        t = self._queue.popleft()
                        if len(batch) < self.max_batch and \
                                t.entry.bucket_key == bk:
                            batch.append(t)
                        else:
                            rest.append(t)
                    self._queue.extend(rest)
                depth = len(self._queue)
            self._execute(batch, depth)

    def _execute(self, batch: list[_Ticket], depth: int) -> None:
        entry = batch[0].entry
        try:
            if entry.batchable:
                per = self._run_batched(entry, batch)
            else:
                per = [self._run_single(t) for t in batch]
            now = time.perf_counter()
            lats = []
            for t, outs in zip(batch, per):
                t.future.set_result(outs)
                lats.append((now - t.t_submit) * 1e6)
            self.metrics.on_batch(
                entry.digest, len(batch),
                sum(1 for t in batch if t.padded), lats, depth)
        except Exception as e:            # noqa: BLE001 - resolve futures
            for t in batch:
                if not t.future.done():
                    t.future.set_exception(e)
            self.metrics.on_batch(entry.digest, len(batch), 0, [], depth,
                                  failed=True)

    def _run_batched(self, entry: _PlanEntry,
                     batch: list[_Ticket]) -> list:
        # Marshalling runs in NumPy on purpose: per-request jnp.pad/
        # jnp.stack/slice would issue ~4 small XLA dispatches per
        # request — more than the batching saves.  One zero-filled host
        # buffer per operand (zero fill IS the padding) and a single
        # device transfer keeps the worker at O(#operands) dispatches
        # per batch regardless of occupancy.
        B = len(batch)
        Bc = _pow2_at_least(B, self.max_batch)
        stacked = []
        for i, name in enumerate(entry.call_order):
            r, c = entry.class_shapes[name]
            buf = np.empty((Bc, r, c), np.float32)
            for j, t in enumerate(batch):
                v = t.pos[i]
                vr, vc = v.shape
                buf[j, :vr, :vc] = v
                if vr < r:
                    buf[j, vr:, :] = 0.0     # the zero fill IS the padding
                if vc < c:
                    buf[j, :vr, vc:] = 0.0
            if Bc > B:                       # batch-axis padding
                buf[B:] = buf[0]
            stacked.append(buf)              # jit device_puts once per arg
        outs = entry.batched_fn(*stacked)
        outs_np = [np.asarray(outs[k]) for k in range(entry.n_outputs)]
        per = []
        for j, t in enumerate(batch):
            vals = []
            for k in range(entry.n_outputs):
                v = outs_np[k][j]
                ax = entry.out_axes[k]
                if ax == 0 and t.m and v.shape[0] != t.m:
                    v = v[:t.m]
                elif ax == 1 and t.m and v.shape[1] != t.m:
                    v = v[:, :t.m]
                vals.append(_uncanon_np(v) if t.vector_world else v)
            per.append(vals[0] if len(vals) == 1 else tuple(vals))
        return per

    @staticmethod
    def _run_single(t: _Ticket):
        # unbatchable (sparse / layout) path: the Compiled call handles
        # canonicalization, layout constraints, and the round-trip
        # itself; results land on the host like the batched path's
        out = t.entry.compiled(**t.kw)
        if isinstance(out, tuple):
            return tuple(np.asarray(o) for o in out)
        return np.asarray(out)
