from .engine import Engine, Request
from .errors import (AdmissionError, DeadlineExceededError,
                     FusionServeError, NonFiniteOutputError,
                     PlanCompileError, PlanQuarantinedError,
                     QueueFullError, RequestFailedError, ServerClosedError)
from .fusion import (CircuitBreaker, FusionServer, PadReport, pad_safety)
from .metrics import Reservoir, ServerMetrics, percentiles

__all__ = [
    "Engine", "Request",
    "FusionServer", "CircuitBreaker",
    # one error taxonomy for both servers (serve/errors.py)
    "FusionServeError", "ServerClosedError", "AdmissionError",
    "QueueFullError", "DeadlineExceededError", "PlanQuarantinedError",
    "PlanCompileError", "RequestFailedError", "NonFiniteOutputError",
    "PadReport", "pad_safety",
    "ServerMetrics", "Reservoir", "percentiles",
]
