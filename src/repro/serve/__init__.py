from .engine import Engine, Request
