from .engine import AdmissionError, Engine, Request
from .fusion import (FusionServeError, FusionServer, PadReport,
                     ServerClosedError, pad_safety)
from .metrics import Reservoir, ServerMetrics, percentiles

__all__ = [
    "Engine", "Request", "AdmissionError",
    "FusionServer", "FusionServeError", "ServerClosedError",
    "PadReport", "pad_safety",
    "ServerMetrics", "Reservoir", "percentiles",
]
