from .loop import LoopConfig, LoopState, resume, run_loop
