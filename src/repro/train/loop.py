"""Fault-tolerant training loop.

Production posture for 1000+ nodes:
  * periodic **async checkpoints** with atomic commit (no corrupt latest);
  * **preemption-safe restart**: data cursor = step counter (stateless
    loader), optimizer/params restored with elastic re-sharding;
  * **straggler detection**: per-step wall-time EWMA; a step slower than
    ``straggler_factor``× the EWMA raises a flag that the fleet controller
    consumes (here: logged + counted, and the policy is unit-tested);
  * NaN/overflow guard: skip-and-log bad steps rather than poisoning the
    optimizer state.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    skip_nonfinite: bool = True


@dataclass
class LoopState:
    step: int = 0
    ewma_step_time: Optional[float] = None
    straggler_events: list = field(default_factory=list)
    skipped_steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)


def run_loop(train_step: Callable, params, opt_state, loader,
             cfg: LoopConfig, store: Optional[CheckpointStore] = None,
             start_step: int = 0,
             on_metrics: Optional[Callable] = None) -> tuple:
    """Returns (params, opt_state, LoopState)."""
    st = LoopState(step=start_step)
    while st.step < cfg.total_steps:
        batch = next(loader)
        host_batch = {k: v for k, v in batch.items() if k != "step"}
        t0 = time.perf_counter()
        new_params, new_opt, metrics = train_step(params, opt_state,
                                                  host_batch)
        loss = float(jax.device_get(metrics["loss"]))
        dt = time.perf_counter() - t0

        # ---- straggler detection -----------------------------------------
        if st.ewma_step_time is not None \
                and dt > cfg.straggler_factor * st.ewma_step_time:
            st.straggler_events.append((st.step, dt, st.ewma_step_time))
        st.ewma_step_time = (dt if st.ewma_step_time is None else
                             (1 - cfg.ewma_alpha) * st.ewma_step_time
                             + cfg.ewma_alpha * dt)

        # ---- bad-step guard -----------------------------------------------
        if cfg.skip_nonfinite and not np.isfinite(loss):
            st.skipped_steps.append(st.step)
        else:
            params, opt_state = new_params, new_opt
            st.losses.append(loss)

        st.step += 1
        if on_metrics and st.step % cfg.log_every == 0:
            on_metrics(st.step, loss, dt, metrics)
        if store is not None and st.step % cfg.checkpoint_every == 0:
            store.save(st.step, {"params": params, "opt": opt_state},
                       extra={"step": st.step})
    if store is not None:
        store.save(st.step, {"params": params, "opt": opt_state},
                   extra={"step": st.step}, blocking=True)
    return params, opt_state, st


def resume(store: CheckpointStore, params_like, opt_like,
           shardings=None) -> tuple:
    """Restart path: returns (params, opt_state, start_step) from the
    latest checkpoint, re-sharded onto the current mesh (elastic)."""
    tree, extra = store.restore({"params": params_like, "opt": opt_like},
                                shardings=shardings)
    return tree["params"], tree["opt"], int(extra["step"])
