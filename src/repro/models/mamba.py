"""Mamba selective-SSM layer (Jamba's recurrent block).

Training/prefill run the selective scan with ``lax.scan`` over sequence
chunks (compact HLO, O(L) work); decode is a single O(1) state update.
The paper's fusion templates do not apply to the loop-carried recurrence
itself (DESIGN.md §6) — but the gate/projection chains around it are
standard Cell fusion sites.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def mamba_params(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N, K = cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    si = di ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (K, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, 2 * N + 1), dtype) * si,
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)).astype(dtype)),
        "D": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[3], (di, d), dtype) * si,
    }


def _ssm_step(h, inputs):
    """h: (B, di, N); one selective-scan step."""
    dA, dBx, C = inputs                       # (B,di,N), (B,di,N), (B,N)
    h = h * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, C)
    return h, y


def _selective_scan(u, dt, B_, C_, A, h0):
    """u, dt: (B, L, di); B_, C_: (B, L, N); A: (di, N); h0: (B, di, N).
    Returns (y (B, L, di), hL)."""
    dA = jnp.exp(dt[..., None] * A)                       # (B,L,di,N)
    dBx = dt[..., None] * B_[:, :, None, :] * u[..., None]

    def step(h, xs):
        return _ssm_step(h, xs)

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
          jnp.moveaxis(C_, 1, 0))
    hL, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hL


def mamba(x: jnp.ndarray, p: dict, cfg: ModelConfig, *,
          state: dict | None = None):
    """Full-sequence Mamba.  x: (B, L, d).  Returns (out, new_state)."""
    B, L, d = x.shape
    di = cfg.ssm_expand * d
    N, K = cfg.ssm_state, cfg.ssm_conv
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, L, di)
    # causal depthwise conv
    pad = jnp.zeros((B, K - 1, di), u.dtype)
    uc = jnp.concatenate([pad, u], axis=1)
    u = sum(uc[:, i:i + L] * p["conv_w"][i] for i in range(K)) + p["conv_b"]
    u = jax.nn.silu(u)
    # input-dependent SSM parameters
    xdbc = u @ p["x_proj"]                                # (B, L, 2N+1)
    B_ = xdbc[..., :N].astype(jnp.float32)
    C_ = xdbc[..., N:2 * N].astype(jnp.float32)
    dt = jax.nn.softplus(xdbc[..., 2 * N:] + p["dt_bias"][None, None, -1]
                         ).astype(jnp.float32)            # (B, L, 1)
    dt = jnp.broadcast_to(dt, (B, L, di))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h0 = (state["h"] if state is not None
          else jnp.zeros((B, di, N), jnp.float32))
    y, hL = _selective_scan(u.astype(jnp.float32), dt, B_, C_, A, h0)
    y = y.astype(x.dtype) + u * p["D"]
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    # conv state: the last K-1 *raw* inputs (uc = [pad(K-1), u_raw(L)])
    new_state = ({"h": hL, "conv": uc[:, L:]}
                 if state is not None else None)
    return out, new_state


def mamba_decode(x: jnp.ndarray, p: dict, cfg: ModelConfig, state: dict):
    """One-token Mamba step.  x: (B, 1, d); state: {h (B,di,N),
    conv (B, K-1, di)}."""
    B, _, d = x.shape
    di = cfg.ssm_expand * d
    N, K = cfg.ssm_state, cfg.ssm_conv
    xz = x[:, 0] @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, di)
    conv_buf = jnp.concatenate([state["conv"], u[:, None]], axis=1)
    u = sum(conv_buf[:, i] * p["conv_w"][i] for i in range(K)) + p["conv_b"]
    u = jax.nn.silu(u)
    xdbc = u @ p["x_proj"]
    B_ = xdbc[..., :N].astype(jnp.float32)
    C_ = xdbc[..., N:2 * N].astype(jnp.float32)
    dt = jax.nn.softplus(xdbc[..., 2 * N:] + p["dt_bias"][None, -1]
                         ).astype(jnp.float32)
    dt = jnp.broadcast_to(dt, (B, di))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h = state["h"] * jnp.exp(dt[..., None] * A) \
        + dt[..., None] * B_[:, None, :] * u.astype(jnp.float32)[..., None]
    y = jnp.einsum("bdn,bn->bd", h, C_).astype(x.dtype) + u * p["D"]
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out[:, None], {"h": h, "conv": conv_buf[:, 1:]}


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)}
