"""xLSTM (mLSTM) block — matrix-memory recurrent cell with exponential
gating and stabilizer state (arXiv:2405.04517).

Train/prefill: ``lax.scan`` over time; decode: O(1) state update per
token.  State per head: C (hd×hd) matrix memory, n (hd) normalizer,
m (scalar) stabilizer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def xlstm_params(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ks = jax.random.split(key, 6)
    s, si = d ** -0.5, di ** -0.5
    H = cfg.n_heads
    return {
        "up": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "wq": jax.random.normal(ks[1], (di, di), dtype) * si,
        "wk": jax.random.normal(ks[2], (di, di), dtype) * si,
        "wv": jax.random.normal(ks[3], (di, di), dtype) * si,
        "wif": jax.random.normal(ks[4], (di, 2 * H), dtype) * si,
        "down": jax.random.normal(ks[5], (di, d), dtype) * si,
    }


def _cell_step(state, inputs):
    """state: (C (B,H,hd,hd), n (B,H,hd), m (B,H));
    inputs: q,k,v (B,H,hd), i,f pre-activations (B,H)."""
    C, n, m = state
    q, k, v, ipre, fpre = inputs
    logf = -jax.nn.softplus(-fpre)                 # log sigmoid(f)
    m_new = jnp.maximum(logf + m, ipre)
    i_g = jnp.exp(ipre - m_new)[..., None]
    f_g = jnp.exp(logf + m - m_new)[..., None]
    C = f_g[..., None] * C + i_g[..., None] * (v[..., :, None]
                                               * k[..., None, :])
    n = f_g * n + i_g * k
    h_num = jnp.einsum("bhij,bhj->bhi", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)),
                        jnp.exp(-m_new))[..., None]
    h = h_num / h_den
    return (C, n, m_new), h


def mlstm(x: jnp.ndarray, p: dict, cfg: ModelConfig, *,
          state=None):
    """x: (B, L, d) → (B, L, d); returns (out, final_state)."""
    B, L, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    hd = di // H
    xz = x @ p["up"]
    u, z = jnp.split(xz, 2, axis=-1)               # (B, L, di)
    q = (u @ p["wq"]).reshape(B, L, H, hd) * hd ** -0.5
    k = (u @ p["wk"]).reshape(B, L, H, hd) * hd ** -0.5
    v = (u @ p["wv"]).reshape(B, L, H, hd)
    gif = (u @ p["wif"]).astype(jnp.float32)       # (B, L, 2H)
    ipre, fpre = gif[..., :H], gif[..., H:]

    if state is None:
        st = (jnp.zeros((B, H, hd, hd), jnp.float32),
              jnp.zeros((B, H, hd), jnp.float32),
              jnp.zeros((B, H), jnp.float32))
    else:
        st = (state["C"], state["n"], state["m"])
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (q, k, v)) + (jnp.moveaxis(ipre, 1, 0),
                                      jnp.moveaxis(fpre, 1, 0))
    stL, hs = jax.lax.scan(_cell_step, st, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, di).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["down"]
    new_state = ({"C": stL[0], "n": stL[1], "m": stL[2]}
                 if state is not None else None)
    return out, new_state


def mlstm_decode(x: jnp.ndarray, p: dict, cfg: ModelConfig, state: dict):
    out, st = mlstm(x, p, cfg, state=state)
    return out, st


def init_xlstm_state(cfg: ModelConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}
