"""GQA attention with RoPE, causal/sliding-window masking, KV cache."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def attn_params(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(kq, (d, cfg.n_heads * hd), dtype) * s,
        "wk": jax.random.normal(kk, (d, cfg.n_kv_heads * hd), dtype) * s,
        "wv": jax.random.normal(kv, (d, cfg.n_kv_heads * hd), dtype) * s,
        "wo": jax.random.normal(ko, (cfg.n_heads * hd, d), dtype)
        * (cfg.n_heads * hd) ** -0.5,
    }


def _mask(q_pos, k_pos, window: int):
    """causal (+ sliding window) mask: (B, Sq, Sk) bool keep."""
    keep = k_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        keep &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return keep


def attention(x: jnp.ndarray, p: dict, cfg: ModelConfig, *,
              positions: jnp.ndarray, window: int = 0,
              cache: Optional[dict] = None):
    """Full-sequence attention (train/prefill).  Returns (out, new_cache):
    when ``cache`` is given (prefill), K/V are written into it.

    When ``cfg.attn_chunk`` divides the sequence, scores are computed
    chunk-at-a-time with an online softmax (flash-attention structure) so
    the S×S matrix never materializes — the memory-roofline fix for 32k+
    contexts."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    from repro.dist.sharding import constrain
    q = constrain((x @ p["wq"]).reshape(B, S, H, hd), "bthd")
    k = constrain((x @ p["wk"]).reshape(B, S, KV, hd), "bthd")
    v = constrain((x @ p["wv"]).reshape(B, S, KV, hd), "bthd")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    rep = H // KV
    kq = jnp.repeat(k, rep, axis=2)
    vq = jnp.repeat(v, rep, axis=2)
    C = cfg.attn_chunk
    if C and S > C and S % C == 0:
        out = _chunked_attention(q, kq, vq, positions, window)
    else:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                            preferred_element_type=jnp.float32) * hd ** -0.5
        keep = _mask(positions, positions, window)[:, None]
        scores = jnp.where(keep, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, vq)
    out = out.reshape(B, S, H * hd) @ p["wo"]

    new_cache = None
    if cache is not None:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
        }
    return out, new_cache


def _chunked_attention(q, k, v, positions, window: int):
    """Online-softmax attention over KV chunks (flash structure).

    q,k,v: (B, S, H, hd); causal (+ optional sliding window).  Each chunk
    step is rematerialized in the backward pass (the flash recompute),
    bounding train-time residuals to O(S·C) per layer."""
    B, S, H, hd = q.shape
    C = _chunk_of(S)
    scale = hd ** -0.5
    n_chunks = S // C
    kc = k.reshape(B, n_chunks, C, H, hd)
    vc = v.reshape(B, n_chunks, C, H, hd)
    pc = positions.reshape(B, n_chunks, C)

    def body(carry, chunk):
        m, l, acc = carry
        kj, vj, pj, j = chunk
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        keep = pj[:, None, :] <= positions[:, :, None]
        if window:
            keep &= pj[:, None, :] > positions[:, :, None] - window
        s = jnp.where(keep[:, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p_ = jnp.exp(s - m_safe[..., None])
        p_ = jnp.where(keep[:, None], p_, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p_, axis=-1)
        acc_new = acc * corr[..., None] \
            + jnp.einsum("bhqk,bkhd->bhqd", p_.astype(vj.dtype), vj
                         ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, H, S), -jnp.inf, jnp.float32),
            jnp.zeros((B, H, S), jnp.float32),
            jnp.zeros((B, H, S, hd), jnp.float32))
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.moveaxis(pc, 1, 0), jnp.arange(n_chunks))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)     # (B, S, H, hd)


def _chunk_of(S: int, target: int = 1024) -> int:
    c = min(S, target)
    while S % c:
        c -= 1
    return c


def decode_attention(x: jnp.ndarray, p: dict, cfg: ModelConfig, *,
                     cache: dict, pos: jnp.ndarray, window: int = 0):
    """Single-token attention against the KV cache.
    x: (B, 1, d); pos: scalar int32 (current position).  Returns
    (out (B,1,d), updated cache).

    ``cfg.gqa_grouped`` computes scores with the grouped-head einsum —
    the KV cache is read once instead of materializing an H/KV× repeated
    copy (the §Perf memory-term optimization for GQA decode)."""
    B, S1, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = cache["k"].shape[1]
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = rope((x @ p["wq"]).reshape(B, 1, H, hd), posb, cfg.rope_theta)
    k = rope((x @ p["wk"]).reshape(B, 1, KV, hd), posb, cfg.rope_theta)
    v = (x @ p["wv"]).reshape(B, 1, KV, hd)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))

    k_pos = jnp.arange(S, dtype=jnp.int32)
    keep = k_pos <= pos
    if window:
        keep &= k_pos > pos - window

    rep = H // KV
    if cfg.gqa_grouped and rep > 1:
        qg = q.reshape(B, 1, KV, rep, hd)
        scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ck,
                            preferred_element_type=jnp.float32) * hd ** -0.5
        scores = jnp.where(keep[None, None, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bgrqk,bkgd->bqgrd", w, cv)
        out = out.reshape(B, 1, H * hd) @ p["wo"]
        return out, {"k": ck, "v": cv}

    kq = jnp.repeat(ck, rep, axis=2)          # (B, S, H, hd)
    vq = jnp.repeat(cv, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    scores = jnp.where(keep[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vq)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, {"k": ck, "v": cv}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }
