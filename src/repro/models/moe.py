"""Mixture-of-Experts layers: token-choice top-k routing.

Two interchangeable implementations (numerics identical):

* ``dense`` — masked all-expert compute combined by gate weights.  Shards
  cleanly (experts or ff over the 'model' axis) and compiles everywhere;
  costs E/k× extra FLOPs — visible in the roofline's MODEL/HLO ratio and
  the target of a §Perf iteration.
* ``ragged`` — sort-by-expert + ``lax.ragged_dot`` grouped GEMMs
  (dropless); FLOPs ∝ k, the optimized arm.

The router chain (softmax → top-k gate normalization) is a Row-template
fusion site.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def moe_params(key, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kg, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {"router": jax.random.normal(kg, (d, e), dtype) * s_in,
         "w1": jax.random.normal(k1, (e, d, f), dtype) * s_in,
         "w2": jax.random.normal(k2, (e, f, d), dtype) * s_out}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k3, (e, d, f), dtype) * s_in
    return p


def _gates(x, router, k):
    """(T, E) normalized top-k gate weights + aux load-balance loss."""
    logits = (x @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    gates = jnp.sum(jax.nn.one_hot(topi, probs.shape[-1],
                                   dtype=probs.dtype)
                    * topv[..., None], axis=1)           # (T, E)
    # Switch-style load-balance aux loss
    e = probs.shape[-1]
    frac = jnp.mean(gates > 0, axis=0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
    return gates.astype(x.dtype), topv, topi, aux


def _act(cfg):
    return jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu


def moe_dense(x: jnp.ndarray, p: dict, cfg: ModelConfig):
    """x: (T, d) → (T, d).  All experts compute, gates combine."""
    gates, _, _, aux = _gates(x, p["router"], cfg.top_k)
    h = jnp.einsum("td,edf->tef", x, p["w1"])
    if "w3" in p:
        h = _act(cfg)(h) * jnp.einsum("td,edf->tef", x, p["w3"])
    else:
        h = _act(cfg)(h)
    y = jnp.einsum("tef,efd->ted", h, p["w2"])
    out = jnp.einsum("ted,te->td", y, gates)
    return out.astype(x.dtype), aux


def moe_ragged(x: jnp.ndarray, p: dict, cfg: ModelConfig):
    """Dropless sort-based routing with grouped (ragged) GEMMs."""
    T, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    _, topv, topi, aux = _gates(x, p["router"], k)
    flat_e = topi.reshape(-1)                      # (T*k,)
    flat_w = topv.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e)
    inv = jnp.argsort(order)
    xs = jnp.repeat(x, k, axis=0)[order]           # (T*k, d) sorted
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)
    h = jax.lax.ragged_dot(xs, p["w1"], group_sizes)
    if "w3" in p:
        h = _act(cfg)(h) * jax.lax.ragged_dot(xs, p["w3"], group_sizes)
    else:
        h = _act(cfg)(h)
    y = jax.lax.ragged_dot(h, p["w2"], group_sizes)
    y = y[inv] * flat_w[:, None]
    out = jnp.sum(y.reshape(T, k, d), axis=1)
    return out.astype(x.dtype), aux


def moe_capacity(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                 capacity_factor: float = 1.25):
    """GShard-style capacity dispatch: sort (token, slot) pairs by expert,
    scatter into a (E, C, d) buffer, run per-expert batched GEMMs, gather
    back.  FLOPs = E·C·(GEMMs) ∝ k·capacity_factor — the §Perf optimized
    arm vs the E/k-overcompute of ``moe_dense`` (tokens beyond capacity
    drop to the residual path, standard Switch/GShard semantics)."""
    T, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    _, topv, topi, aux = _gates(x, p["router"], k)
    flat_e = topi.reshape(-1)                       # (T*k,)
    flat_w = topv.reshape(-1).astype(x.dtype)
    tok_of = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e)
    ranked_e = flat_e[order]
    ranked_tok = tok_of[order]
    ranked_w = flat_w[order]
    # position within expert group: running index minus group start
    starts = jnp.searchsorted(ranked_e, jnp.arange(e), side="left")
    pos_in_grp = jnp.arange(T * k) - starts[ranked_e]

    C = max(1, int(T * k / e * capacity_factor))
    keep = pos_in_grp < C
    slot = jnp.where(keep, ranked_e * C + pos_in_grp, e * C)  # overflow bin
    buf = jnp.zeros((e * C + 1, d), x.dtype).at[slot].set(x[ranked_tok])
    buf = buf[:e * C].reshape(e, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    if "w3" in p:
        h = _act(cfg)(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    else:
        h = _act(cfg)(h)
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(e * C, d)

    contrib = jnp.where(keep[:, None], y[jnp.minimum(slot, e * C - 1)]
                        * ranked_w[:, None], 0.0)
    out = jnp.zeros((T, d), x.dtype).at[ranked_tok].add(contrib)
    return out.astype(x.dtype), aux


def moe_a2a(x: jnp.ndarray, p: dict, cfg: ModelConfig,
            capacity_factor: float = 1.25):
    """Expert-parallel dispatch with explicit ``shard_map`` + all_to_all.

    The capacity dispatch's scatter/gather are *device-local* (no GSPMD
    inference on data-dependent indices), and tokens travel to their
    expert's shard via one all_to_all over the EP ('model') axis each
    way — the production fix for the collective blow-up measured on the
    GSPMD capacity arm (EXPERIMENTS.md §Perf Cell 2/3 it2).

    Requires E % ep == 0 (olmoe 64/16, jamba 16/16).  Activates only
    inside ``activation_rules`` (the mesh carrier); otherwise falls back
    to the local capacity dispatch.
    """
    from repro.dist import sharding as shlib
    rules = shlib.current_rules()
    if rules is None:
        return moe_capacity(x, p, cfg, capacity_factor)
    mesh, _mode = rules
    if not shlib.moe_expert_parallel(mesh, cfg):
        return moe_capacity(x, p, cfg, capacity_factor)
    EP = shlib.TP_AXIS              # experts travel over the TP axis
    e, k, d = cfg.n_experts, cfg.top_k, x.shape[-1]
    fsdp = shlib.fsdp_axes(mesh)
    # shard_map would reject a token count that doesn't split over the
    # data axes — degrade like every other rule instead of erroring
    if not fsdp or x.shape[0] % shlib.axis_size(mesh, fsdp) != 0:
        return moe_capacity(x, p, cfg, capacity_factor)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    has_w3 = "w3" in p

    def local(x_loc, router, w1, w2, w3):
        # x_loc: (T_loc, d); expert weights: local shard (E/ep, d, f)
        T_loc = x_loc.shape[0]
        _, topv, topi, aux = _gates(x_loc, router, k)
        flat_e = topi.reshape(-1)
        flat_w = topv.reshape(-1).astype(x_loc.dtype)
        tok_of = jnp.repeat(jnp.arange(T_loc), k)
        order = jnp.argsort(flat_e)
        ranked_e, ranked_tok = flat_e[order], tok_of[order]
        ranked_w = flat_w[order]
        starts = jnp.searchsorted(ranked_e, jnp.arange(e), side="left")
        pos = jnp.arange(T_loc * k) - starts[ranked_e]
        C = max(1, int(T_loc * k / e * capacity_factor))
        keep = pos < C
        slot = jnp.where(keep, ranked_e * C + pos, e * C)
        buf = jnp.zeros((e * C + 1, d), x_loc.dtype) \
            .at[slot].set(x_loc[ranked_tok])
        buf = buf[:e * C].reshape(e, C, d)
        # ship each expert's rows to its owner: (E, C, d) → (E/ep, ep·C, d)
        buf = jax.lax.all_to_all(buf, EP, split_axis=0, concat_axis=1,
                                 tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, w1)
        if has_w3:
            h = _act(cfg)(h) * jnp.einsum("ecd,edf->ecf", buf, w3)
        else:
            h = _act(cfg)(h)
        y = jnp.einsum("ecf,efd->ecd", h, w2)
        # ship results home: (E/ep, ep·C, d) → (E, C, d)
        y = jax.lax.all_to_all(y, EP, split_axis=1, concat_axis=0,
                               tiled=True)
        y = y.reshape(e * C, d)
        contrib = jnp.where(keep[:, None],
                            y[jnp.minimum(slot, e * C - 1)]
                            * ranked_w[:, None], 0.0)
        out = jnp.zeros((T_loc, d), x_loc.dtype).at[ranked_tok].add(contrib)
        # aux is identical across 'model' (x replicated there); average
        # over the data shards
        for a in fsdp:
            aux = jax.lax.pmean(aux, a)
        return out.astype(x_loc.dtype), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(fsdp, None), P(None, None),
                  P(EP, None, None), P(EP, None, None),
                  P(EP, None, None)),
        out_specs=(P(fsdp, None), P()),
        check_rep=False)
    return fn(x, p["router"], p["w1"], p["w2"],
              p["w3"] if has_w3 else p["w1"])


def moe(x: jnp.ndarray, p: dict, cfg: ModelConfig):
    """x: (B, S, d) → (B, S, d), plus load-balance aux scalar."""
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    fn = {"ragged": moe_ragged, "capacity": moe_capacity,
          "dense": moe_dense, "a2a": moe_a2a}[cfg.moe_impl]
    out, aux = fn(flat, p, cfg)
    return out.reshape(B, S, d), aux
