"""Shared model layers: norms, MLPs, embeddings — pure JAX.

Where marked, the elementwise/normalization chains are first-class fusion
sites for the paper's planner (`repro.core`): the train driver can route
them through ``@fused`` (Cell/Row templates); the default path is plain
jnp, which XLA fuses — both execute identical CNode programs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def norm(x: jnp.ndarray, scale: jnp.ndarray, kind: str = "rmsnorm",
         bias: Optional[jnp.ndarray] = None, eps: float = 1e-6,
         fusion: Optional[str] = None):
    """Row-template chain: per-row second-moment + scale.

    ``fusion`` routes the rmsnorm chain through the paper's planner as a
    staged fused operator (mode string, e.g. "gen"); the default path is
    plain jnp, which XLA fuses — both execute identical CNode programs."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm" and fusion is not None:
        flat = xf.reshape(-1, x.shape[-1])
        out = _fused_rmsnorm(flat, scale.astype(jnp.float32).reshape(1, -1),
                             eps, fusion).reshape(xf.shape)
    elif kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * (1.0 + scale.astype(jnp.float32))
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) \
            * scale.astype(jnp.float32)
        if bias is not None:
            out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def _fused_rmsnorm(flat: jnp.ndarray, scale_row: jnp.ndarray, eps: float,
                   mode: str) -> jnp.ndarray:
    """Staged fused rmsnorm over (rows, d): planned once per (shape, mode);
    differentiable through the operator's planned-backward custom_vjp."""
    from repro.core import fused, ir

    if not hasattr(_fused_rmsnorm, "_fn"):
        @fused
        def _rms(X, s, eps_s):
            ms = (X ** 2).rowmeans()
            return X * ir.sqrt(ms + eps_s).unary("recip") * (1.0 + s)
        _fused_rmsnorm._fn = _rms
        _fused_rmsnorm._ops = {}
    key = (tuple(flat.shape), mode)
    op = _fused_rmsnorm._ops.get(key)
    if op is None:
        eps_spec = jax.ShapeDtypeStruct((1, 1), jnp.float32)
        op = _fused_rmsnorm._fn.trace(flat, scale_row, eps_spec) \
                               .plan(mode=mode).compile()
        _fused_rmsnorm._ops[key] = op
    return op(flat, scale_row, jnp.full((1, 1), eps, jnp.float32))


def mlp(x: jnp.ndarray, p: dict, kind: str) -> jnp.ndarray:
    """Dense MLP; the activation chain is a Cell-template fusion site."""
    from repro.dist.sharding import constrain
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = constrain(act(x @ p["w1"]) * (x @ p["w3"]), "btf")
        return h @ p["w2"]
    if kind == "gelu":
        return constrain(jax.nn.gelu(x @ p["w1"]), "btf") @ p["w2"]
    if kind == "relu2":
        h = jnp.maximum(x @ p["w1"], 0.0)
        return constrain(h * h, "btf") @ p["w2"]
    raise ValueError(kind)


def mlp_params(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {"w1": jax.random.normal(k1, (d, f), dtype) * s_in,
         "w2": jax.random.normal(k2, (f, d), dtype) * s_out}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k3, (d, f), dtype) * s_in
    return p


def norm_params(cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype)}


def apply_norm(x, p, cfg: ModelConfig):
    return norm(x, p["scale"], cfg.norm_type, p.get("bias"))
